"""SqueezeAttention (ICLR 2025) on TPU: 2D KV-cache management as a
first-class feature of a multi-pod JAX serving/training framework.

Subpackages: core (the paper's algorithm), models (all assigned
architecture families), kernels (Pallas TPU), serving, training, data,
checkpoint, configs, launch, analysis.
"""

__version__ = "1.0.0"
