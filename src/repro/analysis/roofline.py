"""Three-term roofline from a compiled dry-run artifact.

Hardware model (TPU v5e-class, per chip — constants from the assignment):
    peak bf16 compute : 197 TFLOP/s
    HBM bandwidth     : 819 GB/s
    ICI link bandwidth: ~50 GB/s per link

Terms (seconds, per executed step, aggregated over the mesh):
    compute    = HLO_FLOPs   / (chips * PEAK_FLOPS)
    memory     = HLO_bytes   / (chips * HBM_BW)
    collective = wire_bytes  / (chips * ICI_BW)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()`` (whole-program,
already per-partition in SPMD — we multiply back to global).  wire_bytes
comes from the HLO collective parse; all-reduce counts 2x (ring reduce +
broadcast phases).

Also reported: MODEL_FLOPS = 6*N*D (training) or 2*N*D (inference) with
N = active params, D = tokens — the "useful FLOPs" — and the ratio
MODEL_FLOPS / HLO_FLOPs which exposes remat/dispatch waste.
"""
from __future__ import annotations

import dataclasses

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes / s / chip
ICI_BW = 50e9                # bytes / s / link / chip


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_global: float
    bytes_global: float
    wire_bytes_global: float
    model_flops: float

    @property
    def t_compute(self) -> float:
        return self.flops_global / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.bytes_global / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.wire_bytes_global / (self.chips * ICI_BW)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flop_ratio(self) -> float:
        return self.model_flops / self.flops_global if self.flops_global else 0.0

    @property
    def mfu_bound(self) -> float:
        """MFU ceiling implied by the dominant term."""
        if self.t_bound <= 0:
            return 0.0
        return self.model_flops / (self.t_bound * self.chips * PEAK_FLOPS)

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "hlo_flops": self.flops_global,
            "useful_flop_ratio": self.useful_flop_ratio,
            "mfu_bound": self.mfu_bound,
        }


def model_flops(cfg, case, kv_slots_total: int = 0) -> float:
    """Analytic useful FLOPs for one step of this (arch, shape).

    train:   6 * N_active * tokens  (fwd+bwd)
    prefill: 2 * N_active * tokens + attention O(S^2) term
    decode:  2 * N_active * batch + 2 * cache-read attention term
    """
    n_active = cfg.n_active_params()
    B, S = case.global_batch, case.seq_len
    hd, Hq = cfg.hd, cfg.n_heads
    if case.kind == "train":
        base = 6.0 * n_active * B * S
        attn = 6.0 * B * cfg.n_layers * Hq * S * S * hd * 2 / 2  # causal half
        return base + (attn if cfg.has_attention else 0.0)
    if case.kind == "prefill":
        base = 2.0 * n_active * B * S
        attn = 2.0 * B * cfg.n_layers * Hq * S * S * hd * 2 / 2
        return base + (attn if cfg.has_attention else 0.0)
    # decode: one token
    base = 2.0 * n_active * B
    attn = 2.0 * B * Hq * hd * 2 * max(kv_slots_total, 0)
    return base + attn


def wire_bytes(colls: dict) -> float:
    """Collective-parse dict -> wire bytes (all-reduce rings move ~2x)."""
    total = 0.0
    for kind, b in colls.items():
        if kind in ("total", "count"):
            continue
        total += b * (2.0 if kind == "all-reduce" else 1.0)
    return total


def from_cost_analysis(arch, shape, mesh_name, chips, cost: dict,
                       wire_bytes_per_partition: float, mflops: float,
                       per_partition: bool = True) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    mult = chips if per_partition else 1
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_global=flops * mult,
        bytes_global=nbytes * mult,
        wire_bytes_global=wire_bytes_per_partition * mult,
        model_flops=mflops,
    )
