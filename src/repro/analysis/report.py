"""Render the §Dry-run / §Roofline markdown tables from dry-run JSONs.

    PYTHONPATH=src python -m repro.analysis.report [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(dirpath: str):
    recs = []
    for p in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(p) as fh:
            recs.append(json.load(fh))
    return recs


def _fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:8.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:7.2f}ms"
    return f"{x*1e6:7.1f}us"


def _fmt_b(x: float) -> str:
    for unit, div in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if x >= div:
            return f"{x/div:7.2f}{unit}"
    return f"{x:7.0f}B"


def roofline_table(recs, mesh="single", kv_mode="full") -> str:
    rows = [r for r in recs if r["mesh"] == mesh and r["kv_mode"] == kv_mode]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    hdr = ("| arch | shape | t_compute | t_memory | t_collective | bottleneck "
           "| MODEL_FLOPs | HLO_FLOPs | useful | MFU-bound |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        rl = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_s(rl['t_compute_s'])} "
            f"| {_fmt_s(rl['t_memory_s'])} | {_fmt_s(rl['t_collective_s'])} "
            f"| **{rl['bottleneck']}** | {rl['model_flops']:.3e} "
            f"| {rl['hlo_flops']:.3e} | {rl['useful_flop_ratio']*100:5.1f}% "
            f"| {rl['mfu_bound']*100:5.1f}% |\n")
    return "".join(out)


def dryrun_table(recs) -> str:
    rows = sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"],
                                       r["kv_mode"]))
    hdr = ("| arch | shape | mesh | kv | compile | args/dev | temp/dev "
           "| out/dev | collective bytes (/dev) | #colls |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        mem = r["memory_analysis"]
        colls = r["collectives"]
        ncoll = sum(colls.get("count", {}).values())
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['kv_mode']} "
            f"| {r['compile_s']:.1f}s | {_fmt_b(mem.get('argument_size_in_bytes', 0))} "
            f"| {_fmt_b(mem.get('temp_size_in_bytes', 0))} "
            f"| {_fmt_b(mem.get('output_size_in_bytes', 0))} "
            f"| {_fmt_b(colls.get('total', 0))} | {ncoll} |\n")
    return "".join(out)


def summarize(recs) -> str:
    """One-line stats for quick triage."""
    by_bn = {}
    for r in recs:
        if r["mesh"] != "single" or r["kv_mode"] != "full":
            continue
        by_bn.setdefault(r["roofline"]["bottleneck"], []).append(
            f"{r['arch']}/{r['shape']}")
    lines = [f"combos: {len(recs)}"]
    for k, v in sorted(by_bn.items()):
        lines.append(f"  {k}-bound ({len(v)}): {', '.join(v)}")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--kv-mode", default="full")
    args = ap.parse_args()
    recs = load(args.dir)
    print("## Dry-run\n")
    print(dryrun_table(recs))
    print("\n## Roofline (single-pod, kv=%s)\n" % args.kv_mode)
    print(roofline_table(recs, args.mesh, args.kv_mode))
    print("\n## Summary\n")
    print(summarize(recs))


if __name__ == "__main__":
    main()
