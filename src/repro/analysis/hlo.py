"""Parse collective traffic out of compiled HLO text.

`compiled.cost_analysis()` has no collective-byte entry, so we scan the HLO
for all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
ops and sum their result-shape bytes (a standard proxy for per-op traffic;
for all-reduce the wire cost is ~2x the shape in a ring, which we account for
in the roofline's collective model).
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# e.g.:  %ag = bf16[2,512,288]{2,1,0} all-gather(...)
#        ROOT %tuple = (f32[8,16]{1,0}, f32[]) all-reduce(...)
_OP_RE = re.compile(
    r"=\s*(?P<shapes>\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"(?P<op>" + "|".join(_COLLECTIVES) + r")\b(?P<rest>[^\n]*)")

_SHAPE_RE = re.compile(r"(?P<dt>[a-z0-9]+)\[(?P<dims>[0-9,]*)\]")


def _shape_bytes(dt: str, dims: str) -> int:
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def collective_bytes(hlo_text: str) -> dict:
    """Sum result bytes per collective kind.  Returns
    {kind: bytes, ..., 'total': int, 'count': {kind: n}}."""
    per = defaultdict(int)
    cnt = defaultdict(int)
    for m in _OP_RE.finditer(hlo_text):
        op = m.group("op")
        # async pairs: count the -done (real result shape), skip the -start
        if m.group("rest").startswith("-start"):
            continue
        total = 0
        for sm in _SHAPE_RE.finditer(m.group("shapes")):
            total += _shape_bytes(sm.group("dt"), sm.group("dims"))
        per[op] += total
        cnt[op] += 1
    out = dict(per)
    out["total"] = sum(per.values())
    out["count"] = dict(cnt)
    return out


def duplicate_fusion_count(hlo_text: str) -> int:
    """Rough remat indicator: number of computations appearing >1x by name
    stem (e.g. 'fused_computation.123' sharing a stem)."""
    stems = defaultdict(int)
    for m in re.finditer(r"%([a-zA-Z_][\w.-]*)\s*=", hlo_text):
        stem = re.sub(r"[.\d]+$", "", m.group(1))
        stems[stem] += 1
    return sum(v - 1 for v in stems.values() if v > 1)
