"""Layer-importance observation study (the paper's Figure 2, faithfully).

The paper's heatmaps are **token-position x layer**: each row shows how one
input embedding evolves through the stack.  The training/prefill forward
averages over tokens (that's what Algorithm 1 consumes); this module
recomputes the full per-token matrix for the observation study, plus the
paper's A.3 analysis (stability of the important-layer set across tasks).

    PYTHONPATH=src python -m repro.analysis.observe --arch mistral-7b
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ModelConfig, init_params
from repro.models.attention import GLOBAL_WINDOW
from repro.models.norms import apply_norm
from repro.models.transformer import _attn_block, _ffn_block, _embed


def cos_sim_matrix(params, cfg: ModelConfig, tokens) -> np.ndarray:
    """[n_layers, S] cosine similarity per token position (batch-averaged).

    Runs the dense stack unscanned so per-token values can be collected
    without touching the production forward (small models only).
    """
    assert not (cfg.is_ssm_only or cfg.is_hybrid), "dense/moe observation"
    x = _embed(params, cfg, jnp.asarray(tokens), None)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    rows = []
    for i in range(cfg.n_layers):
        bp = jax.tree.map(lambda a: a[i], params["layers"])
        window = cfg.layer_window(i) or GLOBAL_WINDOW
        pre = x
        x, _, _, _, _ = _attn_block(bp, cfg, x, positions, None, window, False)
        af = pre.astype(jnp.float32)
        bf = x.astype(jnp.float32)
        cs = (af * bf).sum(-1) / (
            jnp.sqrt((af * af).sum(-1) * (bf * bf).sum(-1)) + 1e-8)
        rows.append(np.asarray(cs.mean(0)))            # [S]
        x, _ = _ffn_block(bp, cfg, x, None)
    return np.stack(rows)                               # [L, S]


def important_set(cos_by_layer: np.ndarray, p: float = 0.35) -> set:
    """Layer indices NOT in G3 (the kept-important set) via Algorithm 1."""
    from repro.core.allocation import allocate
    plan = allocate(cos_by_layer, 1024, p=p, bucket=1, min_budget=1)
    return {i for i, s in enumerate(plan.is_small) if not s}


def task_stability(params, cfg, n_tasks: int = 3, seq: int = 64) -> list:
    """A.3: how stable is the important-layer set across 'tasks' (here:
    prompt distributions with different structure)."""
    rng = np.random.default_rng(0)
    sets = []
    for task in range(n_tasks):
        toks = rng.integers(2, cfg.vocab_size, (4, seq))
        if task == 1:      # repetition-heavy
            toks[:, seq // 2:] = toks[:, :seq // 2]
        if task == 2:      # low-entropy
            toks = toks % 16 + 2
        mat = cos_sim_matrix(params, cfg, toks.astype(np.int32))
        sets.append(important_set(mat.mean(-1)))
    return sets


SHADES = " .:-=+*#%@"


def main():
    from repro.configs import get_reduced
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mistral-7b")
    ap.add_argument("--layers", type=int, default=10)
    ap.add_argument("--seq", type=int, default=48)
    args = ap.parse_args()

    cfg = dataclasses.replace(get_reduced(args.arch), n_layers=args.layers)
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    toks = rng.integers(2, cfg.vocab_size, (4, args.seq)).astype(np.int32)
    toks[:, args.seq // 2:] = toks[:, :args.seq // 2]
    mat = cos_sim_matrix(params, cfg, toks)
    lo, hi = mat.min(), mat.max()
    print(f"{args.arch}: token-position x layer cosine similarity "
          f"(dark = layer changes this token's embedding most)")
    for li in range(mat.shape[0]):
        bar = "".join(
            SHADES[len(SHADES) - 1 - int((v - lo) / max(hi - lo, 1e-9)
                                         * (len(SHADES) - 1))]
            for v in mat[li])
        print(f"  L{li:02d} |{bar}| mean={mat[li].mean():.3f}")

    sets = task_stability(params, cfg)
    inter = set.intersection(*sets)
    union = set.union(*sets)
    print(f"\nA.3 stability: important-set sizes {[len(s) for s in sets]}, "
          f"stable core {sorted(inter)} (jaccard "
          f"{len(inter) / max(len(union), 1):.2f})")


if __name__ == "__main__":
    main()
