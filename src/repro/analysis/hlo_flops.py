"""Trip-count-aware FLOP/byte analysis of compiled HLO text.

XLA's HloCostAnalysis visits each `while` body ONCE, so a model whose layers
are rolled into a `lax.scan` under-reports FLOPs by ~n_layers (and flash-
attention inner scans by another ~n_blocks).  The dry-run needs honest
roofline terms, so this module re-derives them from ``compiled.as_text()``:

  * split the module into named computations with per-op symbol tables;
  * FLOPs: every ``dot`` contributes 2 * |out| * K (K = product of the lhs
    contracting dims, resolved through the symbol table);
  * bytes: fusion-boundary traffic — each op at computation level counts its
    operands + result once (fusion internals excluded), which is the
    HBM-traffic model XLA's fused execution implies;
  * call graph: ``while`` bodies multiply by the ``known_trip_count`` XLA
    records in backend_config; ``conditional`` branches weight 1/n_branches
    (our decode step's two budget tiers each run for their share of layers);
    fusions recurse for FLOPs but stop bytes at the boundary.
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_ASSIGN = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_SHAPE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_DOT_ARGS = re.compile(r"\bdot\(([^)]*)\)")
_LHS_CDIMS = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_BODY = re.compile(r"body=%?([\w.\-]+)")
_CALLS = re.compile(r"calls=%?([\w.\-]+)")
_TO_APPLY = re.compile(r"to_apply=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")


def _elems(dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n


def _first_shape(text: str):
    m = _SHAPE.search(text)
    return m.groups() if m else None


def _all_shapes_bytes(text: str) -> int:
    return sum(_elems(d) * _DTYPE_BYTES.get(dt, 4)
               for dt, d in _SHAPE.findall(text))


@dataclasses.dataclass
class _Comp:
    flops: float = 0.0
    bytes_: float = 0.0
    calls: list = dataclasses.field(default_factory=list)  # (name, kind, w)


def _parse(text: str) -> tuple:
    comps: dict[str, _Comp] = {}
    entry = None
    cur = None
    cur_name = None
    symbols: dict[str, tuple] = {}

    for line in text.splitlines():
        h = _HDR.match(line)
        if h and "=" not in line.split("(")[0]:
            cur_name = h.group(2)
            cur = _Comp()
            comps[cur_name] = cur
            symbols = {}
            if h.group(1):
                entry = cur_name
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        a = _ASSIGN.match(line)
        if not a:
            continue
        name, rhs = a.group(1), a.group(2)
        shp = _first_shape(rhs.split("(")[0] if "(" in rhs else rhs)
        if shp:
            symbols[name] = shp

        # ---- bytes at fusion boundary (operands resolved via symbols) -------
        result_bytes = _all_shapes_bytes(rhs.split("(")[0]) if "(" in rhs \
            else _all_shapes_bytes(rhs)
        opnd_bytes = 0
        opnd_sizes = []
        arg_refs = []
        if "(" in rhs:
            args = rhs.split("(", 1)[1].split(")", 1)[0]
            arg_refs = re.findall(r"%([\w.\-]+)", args)
            for ref in arg_refs:
                s = symbols.get(ref)
                if s:
                    nb = _elems(s[1]) * _DTYPE_BYTES.get(s[0], 4)
                    opnd_bytes += nb
                    opnd_sizes.append(nb)
        free = (" parameter(" in rhs or " get-tuple-element(" in rhs
                or " tuple(" in rhs or " bitcast(" in rhs
                or " while(" in rhs or " conditional(" in rhs
                or " constant(" in rhs or " iota(" in rhs
                or rhs.startswith("tuple("))
        is_dus = ("dynamic-update-slice" in rhs or "dynamic_update_slice" in rhs
                  or "dynamic-update-slice" in name)
        is_ds = ((" dynamic-slice(" in rhs
                  or name.startswith("dynamic-slice")) and not is_dus)
        if is_ds:
            # reads only the sliced region (== result)
            cur.bytes_ += 2 * result_bytes
        elif is_dus:
            # XLA aliases DUS in place (also when wrapped in a fusion whose
            # root is the DUS): the big buffer doesn't round-trip; traffic =
            # the other operands read + the updated region written (~= the
            # largest non-aliased operand)
            aliased = max(opnd_sizes) if opnd_sizes else 0
            rest = opnd_bytes - aliased
            cur.bytes_ += 2 * rest
        elif not free:
            cur.bytes_ += result_bytes + opnd_bytes

        # ---- dot flops -------------------------------------------------------
        dm = _DOT_ARGS.search(rhs)
        if dm and shp:
            out_elems = _elems(shp[1])
            argnames = re.findall(r"%([\w.\-]+)", dm.group(1))
            cd = _LHS_CDIMS.search(rhs)
            k = 1
            if argnames and cd:
                lhs_shape = symbols.get(argnames[0])
                if lhs_shape:
                    lhs_dims = [int(x) for x in lhs_shape[1].split(",")
                                if x != ""]
                    for c in (int(x) for x in cd.group(1).split(",") if x != ""):
                        if c < len(lhs_dims):
                            k *= lhs_dims[c]
            cur.flops += 2.0 * out_elems * k
        elif " convolution(" in rhs and shp:
            cur.flops += 2.0 * _elems(shp[1]) * 128   # coarse (convs are stubs)

        # ---- call graph ------------------------------------------------------
        if " while(" in rhs:
            bm = _BODY.search(rhs)
            tm = _TRIP.search(rhs)
            if bm:
                cur.calls.append((bm.group(1), "while",
                                  int(tm.group(1)) if tm else 1))
        elif " conditional(" in rhs:
            brm = _BRANCHES.search(rhs)
            if brm:
                branches = [b.strip().lstrip("%")
                            for b in brm.group(1).split(",")]
                for b in branches:
                    cur.calls.append((b, "cond", 1.0 / max(len(branches), 1)))
        elif " fusion(" in rhs:
            cm = _CALLS.search(rhs)
            if cm:
                cur.calls.append((cm.group(1), "fusion", 1.0))
        elif _TO_APPLY.search(rhs) and (" reduce(" in rhs or " map(" in rhs
                                        or " scatter(" in rhs
                                        or " reduce-window(" in rhs
                                        or " select-and-scatter(" in rhs):
            pass      # elementwise appliers: negligible flops
        elif " call(" in rhs:
            cm = _TO_APPLY.search(rhs) or _CALLS.search(rhs)
            if cm:
                cur.calls.append((cm.group(1), "call", 1.0))
    return comps, entry


def analyze(text: str) -> dict:
    """Loop-aware per-partition {'flops', 'bytes'} from compiled HLO text."""
    comps, entry = _parse(text)
    if entry is None:
        if not comps:
            return {"flops": 0.0, "bytes": 0.0}
        entry = max(comps, key=lambda n: comps[n].flops + comps[n].bytes_)

    memo: dict[str, tuple] = {}

    def total(name: str, depth: int = 0):
        if name in memo:
            return memo[name]
        c = comps.get(name)
        if c is None or depth > 60:
            return 0.0, 0.0
        memo[name] = (0.0, 0.0)      # cycle guard
        f, b = c.flops, c.bytes_
        for callee, kind, w in c.calls:
            cf, cb = total(callee, depth + 1)
            if kind == "while":
                f += cf * w
                b += cb * w
            elif kind == "cond":
                f += cf * w
                b += cb * w
            elif kind == "fusion":
                f += cf            # bytes stop at fusion boundary
            else:
                f += cf
                b += cb
        memo[name] = (f, b)
        return f, b

    f, b = total(entry)
    return {"flops": f, "bytes": b}
