"""OLMo-1B [arXiv:2402.00838].

16 layers, d_model=2048, 16 heads (MHA, kv=16), head_dim=128, d_ff=8192
(SwiGLU), vocab 50304.  Non-parametric LayerNorm (no affine params).
"""
from repro.configs.common import reduce_for_smoke
from repro.models.config import ModelConfig

ARCH_ID = "olmo-1b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, arch_type="dense",
        n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
        d_ff=8192, vocab_size=50_304,
        norm_type="nonparametric_ln",
    )


def reduced() -> ModelConfig:
    return reduce_for_smoke(config())
