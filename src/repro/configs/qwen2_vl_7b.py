"""Qwen2-VL-7B language backbone [arXiv:2409.12191].

28 layers, d_model=3584, 28 heads / 4 KV heads (GQA), head_dim=128,
d_ff=18944, vocab 152064.  M-RoPE with (t,h,w) sections (16,24,24).
Vision encoder is a STUB per assignment: `input_specs()` supplies
precomputed patch embeddings + 3-D position ids (dynamic resolution).
"""
from repro.configs.common import reduce_for_smoke
from repro.models.config import ModelConfig

ARCH_ID = "qwen2-vl-7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, arch_type="vlm",
        n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, head_dim=128,
        d_ff=18_944, vocab_size=152_064,
        mrope_sections=(16, 24, 24), rope_theta=1_000_000.0,
        # requests arrive as precomputed patch embeddings; 64 patches is the
        # spec's nominal per-image budget (continuous serving admits them
        # through the embeds-native intake, serving/intake.py)
        frontend="vision_stub", frontend_tokens=64,
    )


def reduced() -> ModelConfig:
    return reduce_for_smoke(config())
