"""Llama-2-7B [arXiv:2307.09288] — paper experiment model (32K variant).

32 layers, d_model=4096, 32 heads (MHA), head_dim=128, d_ff=11008,
vocab 32000.
"""
from repro.configs.common import reduce_for_smoke
from repro.models.config import ModelConfig

ARCH_ID = "llama2-7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, arch_type="dense",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32, head_dim=128,
        d_ff=11_008, vocab_size=32_000,
    )


def reduced() -> ModelConfig:
    return reduce_for_smoke(config())
