"""Qwen3-235B-A22B MoE [hf:Qwen/Qwen3-30B-A3B family].

94 layers, d_model=4096, 64 heads / 4 KV heads (GQA), head_dim=128, qk-norm,
MoE with 128 experts top-8, per-expert d_ff=1536, vocab 151936.
"""
from repro.configs.common import reduce_for_smoke
from repro.models.config import ModelConfig

ARCH_ID = "qwen3-moe-235b-a22b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, arch_type="moe",
        n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
        d_ff=0, vocab_size=151_936,
        n_experts=128, experts_per_tok=8, moe_d_ff=1536,
        use_qk_norm=True, rope_theta=1_000_000.0,
    )


def reduced() -> ModelConfig:
    return reduce_for_smoke(config())
