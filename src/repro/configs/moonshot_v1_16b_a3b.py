"""Moonlight-16B-A3B (Moonshot AI) [hf:moonshotai/Moonlight-16B-A3B].

48 layers, d_model=2048, 16 heads (GQA kv=16 per assignment, head_dim=128),
MoE with 64 experts top-6, per-expert d_ff=1408, vocab 163840.
"""
from repro.configs.common import reduce_for_smoke
from repro.models.config import ModelConfig

ARCH_ID = "moonshot-v1-16b-a3b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, arch_type="moe",
        n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
        d_ff=0, vocab_size=163_840,
        n_experts=64, experts_per_tok=6, moe_d_ff=1408,
        rope_theta=50_000.0,
    )


def reduced() -> ModelConfig:
    return reduce_for_smoke(config())
