"""Mistral-7B [arXiv:2310.06825] — the paper's primary experiment model.

32 layers, d_model=4096, 32 heads / 8 KV heads, head_dim=128, d_ff=14336,
vocab 32000, sliding-window attention 4096 (the paper's best baseline policy
for this model).
"""
from repro.configs.common import reduce_for_smoke
from repro.models.config import ModelConfig

ARCH_ID = "mistral-7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, arch_type="dense",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=14_336, vocab_size=32_000,
        sliding_window=4096,
    )


def reduced() -> ModelConfig:
    return reduce_for_smoke(config())
