"""MusicGen-large decoder [arXiv:2306.05284].

48 layers, d_model=2048, 32 heads (MHA, kv=32), head_dim=64, d_ff=8192 (GELU,
LayerNorm), vocab 2048 (EnCodec codebook).  The EnCodec tokenizer/conv
frontend is a STUB per assignment: `input_specs()` supplies frame embeddings.
(Adaptation: RoPE replaces MusicGen's sinusoidal embeddings — positional
scheme is orthogonal to the KV-cache study; noted in DESIGN.md.)
"""
from repro.configs.common import reduce_for_smoke
from repro.models.config import ModelConfig

ARCH_ID = "musicgen-large"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, arch_type="audio",
        n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
        d_ff=8192, vocab_size=2048,
        norm_type="layernorm", mlp_type="gelu",
        # requests arrive as precomputed codec-frame embeddings; 50 frames
        # = one second of EnCodec conditioning at 50 Hz (admitted through
        # the embeds-native intake, serving/intake.py)
        frontend="audio_stub", frontend_tokens=50,
    )


def reduced() -> ModelConfig:
    return reduce_for_smoke(config())
