"""Mixtral 8x22B [arXiv:2401.04088].

56 layers, d_model=6144, 48 heads / 8 KV heads (GQA), head_dim=128, MoE with
8 experts top-2, per-expert d_ff=16384, vocab 32768, sliding-window attention
(4096) per assignment.
"""
from repro.configs.common import reduce_for_smoke
from repro.models.config import ModelConfig

ARCH_ID = "mixtral-8x22b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, arch_type="moe",
        n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
        d_ff=0, vocab_size=32_768,
        n_experts=8, experts_per_tok=2, moe_d_ff=16_384,
        sliding_window=4096, rope_theta=1_000_000.0,
    )


def reduced() -> ModelConfig:
    return reduce_for_smoke(config())
