"""Gemma 2 27B [arXiv:2408.00118].

46 layers, d_model=4608, 32 query heads / 16 KV heads (GQA), head_dim=128,
d_ff=36864, vocab 256000.  Alternating local (window 4096) / global attention,
tanh logit softcapping (attn 50.0, final 30.0), pre+post RMSNorms per block.
"""
from repro.configs.common import reduce_for_smoke
from repro.models.config import ModelConfig

ARCH_ID = "gemma2-27b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, arch_type="dense",
        n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16, head_dim=128,
        d_ff=36864, vocab_size=256_000,
        window_pattern="local_global", sliding_window=4096,
        attn_softcap=50.0, final_softcap=30.0, use_post_norms=True,
        rope_theta=10_000.0,
    )


def reduced() -> ModelConfig:
    return reduce_for_smoke(config())
