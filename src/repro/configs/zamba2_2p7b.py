"""Zamba2-2.7B [arXiv:2411.15242].

54 Mamba2 blocks, d_model=2560, ssm_state=64, plus a weight-SHARED attention
block (32 heads, kv=32, head_dim=80, d_ff=10240) applied every 6 mamba blocks
(9 invocations — each with its own KV cache, so SqueezeAttention's budgets
apply across invocations).  vocab 32000.
"""
from repro.configs.common import reduce_for_smoke
from repro.models.config import ModelConfig

ARCH_ID = "zamba2-2.7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, arch_type="hybrid",
        n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, head_dim=80,
        d_ff=10_240, vocab_size=32_000,
        ssm_state=64, ssm_expand=2, ssm_head_dim=64, attn_period=6,
    )


def reduced() -> ModelConfig:
    return reduce_for_smoke(config())
