"""Shared helpers for architecture configs: reduced smoke variants."""
from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig


def reduce_for_smoke(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Same family, CPU-runnable: <=2 super-layers, d_model<=512, <=4 experts.

    Keeps every structural flag (GQA ratio, softcaps, window pattern, qk-norm,
    M-RoPE, MoE routing, SSD, hybrid period) so the smoke test exercises the
    exact code paths of the full config.
    """
    n_heads = min(cfg.n_heads, 4)
    ratio = max(cfg.n_heads // max(cfg.n_kv_heads, 1), 1)
    n_kv = max(n_heads // min(ratio, n_heads), 1)
    head_dim = min(cfg.hd, 32)
    d_model = min(cfg.d_model, 128)
    upd = dict(
        n_layers=2,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=head_dim,
        d_ff=min(cfg.d_ff, 256) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        padded_vocab=0,      # production-only sharding concern
        sliding_window=min(cfg.sliding_window, 8) if cfg.sliding_window else None,
    )
    if cfg.is_moe:
        upd.update(n_experts=min(cfg.n_experts, 4),
                   experts_per_tok=min(cfg.experts_per_tok, 2),
                   moe_d_ff=min(cfg.moe_d_ff, 128))
    if cfg.ssm_state:
        upd.update(ssm_state=min(cfg.ssm_state, 16), ssm_head_dim=32,
                   ssm_chunk=8, d_model=128)
    if cfg.is_hybrid:
        upd.update(n_layers=4, attn_period=2)
    if cfg.mrope_sections is not None:
        hd = upd["head_dim"]
        upd.update(mrope_sections=(hd // 2 - 2 * (hd // 8), hd // 8, hd // 8))
    if cfg.frontend_tokens:
        # keep the embeds-native admission path exercised, at smoke scale
        upd.update(frontend_tokens=min(cfg.frontend_tokens, 8))
    upd.update(name=cfg.name + "-smoke", **overrides)
    return dataclasses.replace(cfg, **upd)
