"""Mamba2-1.3B [arXiv:2405.21060] — SSD (state-space duality), attention-free.

48 layers, d_model=2048, ssm_state=128, expand 2 (d_inner=4096, head_dim 64
-> 64 SSM heads), vocab 50280.  No KV cache exists; SqueezeAttention's
budget reallocation is INAPPLICABLE (DESIGN.md §4) — the architecture runs
with its O(1) recurrent state and the layer-importance measurement is still
reported for the observation study.
"""
from repro.configs.common import reduce_for_smoke
from repro.models.config import ModelConfig

ARCH_ID = "mamba2-1.3b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, arch_type="ssm",
        n_layers=48, d_model=2048, n_heads=1, n_kv_heads=1, head_dim=64,
        d_ff=0, vocab_size=50_280, padded_vocab=50_432,  # %256==0 (§Perf C1)
        ssm_state=128, ssm_expand=2, ssm_head_dim=64,
    )


def reduced() -> ModelConfig:
    return reduce_for_smoke(config())
