"""Qwen3-4B [hf:Qwen/Qwen3-8B family].

36 layers, d_model=2560, 32 heads / 8 KV heads (GQA), head_dim=128, qk-norm,
d_ff=9728 (SwiGLU), vocab 151936.
"""
from repro.configs.common import reduce_for_smoke
from repro.models.config import ModelConfig

ARCH_ID = "qwen3-4b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, arch_type="dense",
        n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=9728, vocab_size=151_936,
        use_qk_norm=True, rope_theta=1_000_000.0,
    )


def reduced() -> ModelConfig:
    return reduce_for_smoke(config())
