"""Architecture registry: ``--arch <id>`` resolution.

The 10 assigned architectures (public-literature pool) + the paper's own
experiment models.  Every entry exposes ``config()`` (exact published spec)
and ``reduced()`` (2-layer smoke variant of the same family).
"""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

_MODULES = {
    # ---- assigned pool -------------------------------------------------------
    "gemma2-27b": "gemma2_27b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "musicgen-large": "musicgen_large",
    "mixtral-8x22b": "mixtral_8x22b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "olmo-1b": "olmo_1b",
    "zamba2-2.7b": "zamba2_2p7b",
    "qwen3-4b": "qwen3_4b",
    "mamba2-1.3b": "mamba2_1p3b",
    # ---- the paper's own models ----------------------------------------------
    "mistral-7b": "mistral_7b",
    "llama2-7b": "llama2_7b",
}

ASSIGNED_ARCHS = tuple(list(_MODULES)[:10])
ALL_ARCHS = tuple(_MODULES)


def _mod(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; available: {', '.join(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    cfg = _mod(arch).config()
    cfg.validate()
    return cfg


def get_reduced(arch: str) -> ModelConfig:
    cfg = _mod(arch).reduced()
    cfg.validate()
    return cfg
