"""Checkpointing: pytree <-> flat .npz archives + JSON metadata.

No orbax offline, so: flatten the pytree with '/'-joined key paths, store as
one compressed npz per step, keep a small manifest for discovery/pruning.
Restores are exact (dtypes preserved, bf16 stored via uint16 view).
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

_BF16_SUFFIX = "__bf16"


def _keystr(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save(directory: str, step: int, tree: Any, extra: Optional[dict] = None):
    os.makedirs(directory, exist_ok=True)
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        arr = np.asarray(leaf)
        name = _keystr(path)
        if arr.dtype == jnp.bfloat16:
            flat[name + _BF16_SUFFIX] = arr.view(np.uint16)
        else:
            flat[name] = arr
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    np.savez_compressed(path + ".tmp.npz", **flat)
    os.replace(path + ".tmp.npz", path)
    manifest = {"step": step, "extra": extra or {}}
    with open(os.path.join(directory, f"ckpt_{step:08d}.json"), "w") as fh:
        json.dump(manifest, fh)
    return path


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for f in os.listdir(directory)
             if (m := re.fullmatch(r"ckpt_(\d+)\.npz", f))]
    return max(steps) if steps else None


def restore(directory: str, step: int, like: Any) -> Any:
    """Restore into the structure of `like` (shape/dtype-checked)."""
    with np.load(os.path.join(directory, f"ckpt_{step:08d}.npz")) as z:
        data = {k: z[k] for k in z.files}

    leaves = jax.tree_util.tree_flatten_with_path(like)[0]
    out = []
    for path, leaf in leaves:
        name = _keystr(path)
        if name + _BF16_SUFFIX in data:
            arr = data[name + _BF16_SUFFIX].view(jnp.bfloat16)
        elif name in data:
            arr = data[name]
        else:
            raise KeyError(f"checkpoint missing leaf {name}")
        ref = np.asarray(leaf)
        if arr.shape != ref.shape:
            raise ValueError(f"{name}: shape {arr.shape} != expected {ref.shape}")
        out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), out)


def prune(directory: str, keep: int = 3):
    steps = sorted([int(m.group(1)) for f in os.listdir(directory)
                    if (m := re.fullmatch(r"ckpt_(\d+)\.npz", f))])
    for s in steps[:-keep]:
        for ext in (".npz", ".json"):
            try:
                os.remove(os.path.join(directory, f"ckpt_{s:08d}{ext}"))
            except OSError:
                pass
