from repro.checkpoint.store import latest_step, prune, restore, save
