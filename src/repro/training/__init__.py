from repro.training.optimizer import AdamWConfig, OptState, apply_updates, init_opt_state, schedule_lr
from repro.training.train_step import TrainBatch, eval_step, loss_fn, train_step
