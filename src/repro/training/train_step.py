"""Loss + train step (rematerialized), shared by the launcher and examples."""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.transformer import forward
from repro.training.optimizer import AdamWConfig, OptState, apply_updates


class TrainBatch(NamedTuple):
    tokens: jnp.ndarray                # [B, S] int32
    targets: jnp.ndarray               # [B, S] int32 (next-token labels)
    valid: Optional[jnp.ndarray] = None      # [B, S] bool
    embeds: Optional[jnp.ndarray] = None     # [B, S, d] vlm/audio stub inputs
    positions: Optional[jnp.ndarray] = None


def loss_fn(params, cfg: ModelConfig, batch: TrainBatch, remat: bool = True):
    # remat is applied to each layer-scan BODY inside forward (per-layer
    # checkpointing): XLA's while-loop autodiff otherwise stashes every
    # per-layer intermediate regardless of an outer jax.checkpoint
    # (EXPERIMENTS.md §Perf iteration A2).
    out = forward(params, cfg,
                  batch.tokens if batch.embeds is None else None,
                  batch.embeds, batch.positions, batch.valid, False,
                  remat=remat)
    logits = out.logits
    logp = jax.nn.log_softmax(logits, axis=-1)
    tgt = jnp.take_along_axis(logp, batch.targets[..., None].astype(jnp.int32),
                              axis=-1)[..., 0]
    if batch.valid is not None:
        w = batch.valid.astype(jnp.float32)
        nll = -(tgt * w).sum() / jnp.clip(w.sum(), 1.0)
    else:
        nll = -tgt.mean()
    loss = nll + out.aux_loss
    return loss, {"nll": nll, "aux": out.aux_loss}


def train_step(params, opt_state: OptState, batch: TrainBatch,
               cfg: ModelConfig, opt_cfg: AdamWConfig, remat: bool = True,
               microbatches: int = 1):
    """One optimizer step; with microbatches > 1, gradients are accumulated
    over batch slices (lax.scan) so peak activation memory scales with the
    microbatch, not the global batch (§Perf A7)."""
    if microbatches <= 1:
        (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, cfg, batch, remat)
    else:
        B = batch.targets.shape[0]
        assert B % microbatches == 0, (B, microbatches)
        mb = B // microbatches

        def slice_mb(i):
            sl = lambda a: (jax.lax.dynamic_slice_in_dim(a, i * mb, mb, 0)
                            if a is not None else None)
            return TrainBatch(sl(batch.tokens), sl(batch.targets),
                              sl(batch.valid), sl(batch.embeds),
                              sl(batch.positions))

        def acc(carry, i):
            loss_sum, parts_sum, gsum = carry
            (loss, parts), g = jax.value_and_grad(loss_fn, has_aux=True)(
                params, cfg, slice_mb(i), remat)
            gsum = jax.tree.map(lambda a, b: a + b.astype(a.dtype), gsum, g)
            parts_sum = jax.tree.map(lambda a, b: a + b, parts_sum, parts)
            return (loss_sum + loss, parts_sum, gsum), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        p0 = {"nll": jnp.zeros(()), "aux": jnp.zeros(())}
        (loss, parts, grads), _ = jax.lax.scan(
            acc, (jnp.zeros(()), p0, g0), jnp.arange(microbatches))
        inv = 1.0 / microbatches
        loss = loss * inv
        parts = jax.tree.map(lambda a: a * inv, parts)
        grads = jax.tree.map(lambda a: a * inv, grads)
    params, opt_state, om = apply_updates(opt_cfg, params, grads, opt_state)
    metrics = {"loss": loss, **parts, **om}
    return params, opt_state, metrics


def eval_step(params, batch: TrainBatch, cfg: ModelConfig):
    loss, parts = loss_fn(params, cfg, batch, remat=False)
    return {"loss": loss, **parts}
