"""AdamW + LR schedules, from scratch (no optax in this environment).

Optimizer state keeps fp32 moments regardless of param dtype (mixed-precision
training: bf16 params/grads, fp32 m/v), matching large-scale practice and the
memory model used in the roofline analysis.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"      # cosine | linear | constant
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    step: jnp.ndarray
    m: dict
    v: dict


def init_opt_state(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), m=zeros,
                    v=jax.tree.map(jnp.copy, zeros))


def schedule_lr(cfg: AdamWConfig, step) -> jnp.ndarray:
    stepf = step.astype(jnp.float32)
    warm = jnp.minimum(stepf / max(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        decay = 1.0
    else:
        frac = jnp.clip((stepf - cfg.warmup_steps)
                        / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        if cfg.schedule == "cosine":
            decay = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 \
                * (1 + jnp.cos(jnp.pi * frac))
        else:
            decay = 1.0 - (1 - cfg.min_lr_frac) * frac
    return cfg.lr * warm * decay


def global_norm(tree) -> jnp.ndarray:
    sq = jax.tree.map(lambda g: jnp.sum(g.astype(jnp.float32) ** 2), tree)
    return jnp.sqrt(jax.tree.reduce(lambda a, b: a + b, sq, 0.0))


def apply_updates(cfg: AdamWConfig, params, grads, state: OptState):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip > 0 else 1.0
    step = state.step + 1
    lr = schedule_lr(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh, vh = m / b1c, v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        # decoupled weight decay on matrix params only (ndim >= 2)
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_p, OptState(step, new_m, new_v), metrics
