from repro.models.config import ModelConfig
from repro.models.transformer import (
    ForwardOut,
    forward,
    init_params,
    layer_windows,
    n_attn_layers,
)

__all__ = [
    "ModelConfig", "ForwardOut", "forward", "init_params",
    "layer_windows", "n_attn_layers",
]
