"""Unified model configuration covering every assigned architecture family.

One frozen dataclass describes dense / MoE / SSM / hybrid / VLM / audio decoder
stacks.  Every field that changes the *computation graph* is static config; every
quantity that merely changes values (e.g. sliding-window width per layer) can be
threaded through `lax.scan` as data.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                   # 0 -> d_model // n_heads
    # embed/unembed allocate this width (>= vocab_size); pad columns are
    # masked to -inf in the logits.  Lets a non-divisible vocabulary (e.g.
    # mamba2's 50280) shard on the 16-way model axis (§Perf C1).
    padded_vocab: int = 0

    # ---- attention variants -------------------------------------------------
    rope_theta: float = 10_000.0
    use_qk_norm: bool = False           # qwen3: RMSNorm on q and k heads
    attn_softcap: Optional[float] = None    # gemma2: tanh softcap on attn logits (50.)
    final_softcap: Optional[float] = None   # gemma2: tanh softcap on lm logits (30.)
    sliding_window: Optional[int] = None    # SWA width (mistral/mixtral: 4096)
    # layer window pattern: None -> all global; 'local_global' -> alternate
    # (even layers local with `sliding_window`, odd layers global), gemma2-style.
    window_pattern: Optional[str] = None
    mrope_sections: Optional[Tuple[int, int, int]] = None  # qwen2-vl M-RoPE (t,h,w)

    # ---- norm / mlp ----------------------------------------------------------
    norm_type: str = "rmsnorm"          # rmsnorm | layernorm | nonparametric_ln
    norm_eps: float = 1e-6
    mlp_type: str = "swiglu"            # swiglu | gelu
    use_post_norms: bool = False        # gemma2: post-attn + post-ffw RMSNorms

    # ---- MoE -----------------------------------------------------------------
    n_experts: int = 0
    experts_per_tok: int = 0
    moe_d_ff: int = 0                   # per-expert hidden dim
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # ---- SSM (Mamba2 / SSD) ---------------------------------------------------
    ssm_state: int = 0                  # N (state size per head)
    ssm_expand: int = 2                 # d_inner = expand * d_model
    ssm_head_dim: int = 64              # P
    ssm_conv_width: int = 4
    ssm_chunk: int = 128                # SSD chunk length
    # hybrid (zamba2): one shared attention block applied every `attn_period`
    # mamba blocks (block-shared weights, zamba2-style).
    attn_period: int = 0                # 0 -> not hybrid

    # ---- modality frontend (stubbed per spec) --------------------------------
    frontend: Optional[str] = None      # None | 'vision_stub' | 'audio_stub'
    frontend_tokens: int = 0            # patch/frame embeddings prepended (spec only)

    # ---- numerics -------------------------------------------------------------
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"

    # -------------------------------------------------------------------------
    @property
    def v_padded(self) -> int:
        return max(self.padded_vocab, self.vocab_size)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.hd

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.hd

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_ssm_only(self) -> bool:
        return self.arch_type == "ssm"

    @property
    def is_hybrid(self) -> bool:
        return self.attn_period > 0

    @property
    def has_attention(self) -> bool:
        return not self.is_ssm_only

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def layer_window(self, layer_idx: int) -> Optional[int]:
        """Static per-layer sliding-window width (None = global)."""
        if self.window_pattern == "local_global":
            return self.sliding_window if layer_idx % 2 == 0 else None
        return self.sliding_window

    def n_params(self) -> int:
        """Approximate parameter count (embedding + blocks + head)."""
        d, hd = self.d_model, self.hd
        p = self.vocab_size * d * 2  # embed + unembed (untied)
        if self.is_ssm_only or self.is_hybrid:
            di, N, H = self.d_inner, self.ssm_state, self.ssm_heads
            per_m = d * (2 * di + 2 * N * 0 + H * 0) + di * d  # in/out proj approx
            per_m += d * (2 * N * 1)  # B,C proj (approx, grouped)
            n_m = self.n_layers
            p += n_m * per_m
            if self.is_hybrid:
                attn = d * (self.q_dim + 2 * self.kv_dim) + self.q_dim * d
                mlp = 3 * d * self.d_ff
                p += attn + mlp  # shared block counted once
            return p
        attn = d * (self.q_dim + 2 * self.kv_dim) + self.q_dim * d
        if self.is_moe:
            mlp = self.n_experts * 3 * d * self.moe_d_ff + d * self.n_experts
        else:
            k = 3 if self.mlp_type == "swiglu" else 2
            mlp = k * d * self.d_ff
        p += self.n_layers * (attn + mlp)
        return p

    def n_active_params(self) -> int:
        """Active params per token (MoE: only routed experts count)."""
        if not self.is_moe:
            return self.n_params()
        d = self.d_model
        attn = d * (self.q_dim + 2 * self.kv_dim) + self.q_dim * d
        mlp = self.experts_per_tok * 3 * d * self.moe_d_ff + d * self.n_experts
        return self.vocab_size * d * 2 + self.n_layers * (attn + mlp)

    def validate(self) -> None:
        assert self.hd * self.n_heads == self.q_dim
        assert self.n_heads % max(self.n_kv_heads, 1) == 0 or not self.has_attention
        if self.is_moe:
            assert 0 < self.experts_per_tok <= self.n_experts
        if self.is_ssm_only or self.is_hybrid:
            assert self.ssm_state > 0
            assert self.d_inner % self.ssm_head_dim == 0
        if self.is_hybrid:
            # the hybrid stack materializes (n_layers // attn_period) super-
            # blocks; an indivisible count would silently drop layers AND
            # mis-size the recurrent-state arenas (capability/recurrent_tier
            # count n_layers)
            assert self.n_layers % self.attn_period == 0, \
                (self.n_layers, self.attn_period)
        if self.mrope_sections is not None:
            assert sum(self.mrope_sections) == self.hd // 2
