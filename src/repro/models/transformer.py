"""Unified decoder stack for every assigned architecture family.

Families:
  * dense / moe / vlm / audio — attention + (mlp|moe) blocks, scanned.
  * ssm  (mamba2)             — attention-free Mamba2 mixer blocks, scanned.
  * hybrid (zamba2)           — super-blocks of `attn_period` Mamba2 blocks
                                followed by one *weight-shared* attention block.

The forward pass doubles as the paper's measurement pass: for every
attention block it records the cosine similarity between the residual
stream entering the block and the stream after the attention residual-add
(SqueezeAttention Eq. 5) — the layer-importance signal that drives the
KV budget reallocation.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import mlp as mlp_lib
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.attention import GLOBAL_WINDOW
from repro.models.config import ModelConfig
from repro.models.norms import apply_norm, init_norm
from repro.models.shard_hints import hint


# --------------------------------------------------------------------------- #
# parameter construction
# --------------------------------------------------------------------------- #

def _init_block(key, cfg: ModelConfig):
    """One dense/moe block's params (unstacked)."""
    ka, km = jax.random.split(key)
    p = {
        "attn_norm": init_norm(cfg, cfg.d_model),
        "attn": init_attn_dict(ka, cfg),
        "mlp_norm": init_norm(cfg, cfg.d_model),
        "post_attn_norm": init_norm(cfg, cfg.d_model),
        "post_mlp_norm": init_norm(cfg, cfg.d_model),
    }
    if cfg.is_moe:
        p["moe"] = moe_lib.init_moe(km, cfg)._asdict()
    else:
        p["mlp"] = mlp_lib.init_mlp(km, cfg)._asdict()
    return p


def init_attn_dict(key, cfg):
    return attn_lib.init_attn(key, cfg)._asdict()


def _init_ssm_block(key, cfg):
    return {
        "norm": init_norm(cfg, cfg.d_model),
        "ssm": ssm_lib.init_ssm(key, cfg)._asdict(),
    }


def init_params(key, cfg: ModelConfig):
    cfg.validate()
    keys = jax.random.split(key, 8)
    pd = jnp.dtype(cfg.param_dtype)
    params = {
        "embed": (jax.random.normal(keys[0], (cfg.v_padded, cfg.d_model),
                                    jnp.float32) * 0.02).astype(pd),
        "final_norm": init_norm(cfg, cfg.d_model),
        "unembed": (jax.random.normal(keys[1], (cfg.d_model, cfg.v_padded),
                                      jnp.float32) * 0.02).astype(pd),
    }
    if cfg.is_ssm_only:
        lkeys = jax.random.split(keys[2], cfg.n_layers)
        params["layers"] = jax.vmap(lambda k: _init_ssm_block(k, cfg))(lkeys)
    elif cfg.is_hybrid:
        n_super = cfg.n_layers // cfg.attn_period
        lkeys = jax.random.split(keys[2], n_super * cfg.attn_period)
        blocks = jax.vmap(lambda k: _init_ssm_block(k, cfg))(lkeys)
        params["layers"] = jax.tree.map(
            lambda a: a.reshape((n_super, cfg.attn_period) + a.shape[1:]), blocks)
        params["shared_attn"] = {
            "attn_norm": init_norm(cfg, cfg.d_model),
            "attn": init_attn_dict(keys[3], cfg),
            "mlp_norm": init_norm(cfg, cfg.d_model),
            "mlp": mlp_lib.init_mlp(keys[4], cfg)._asdict(),
        }
    else:
        lkeys = jax.random.split(keys[2], cfg.n_layers)
        params["layers"] = jax.vmap(lambda k: _init_block(k, cfg))(lkeys)
    return params


def layer_windows(cfg: ModelConfig) -> jnp.ndarray:
    """[n_attn_layers] int32 per-attention-layer window widths (data, not shape)."""
    n = n_attn_layers(cfg)
    return jnp.asarray(
        [cfg.layer_window(i) or GLOBAL_WINDOW for i in range(n)], jnp.int32)


def n_attn_layers(cfg: ModelConfig) -> int:
    """Number of attention (== KV-cached) layers."""
    if cfg.is_ssm_only:
        return 0
    if cfg.is_hybrid:
        return cfg.n_layers // cfg.attn_period
    return cfg.n_layers


# --------------------------------------------------------------------------- #
# forward (train / prefill)
# --------------------------------------------------------------------------- #

class ForwardOut(NamedTuple):
    logits: jnp.ndarray                  # [B, S, V]
    cos_sims: jnp.ndarray                # [n_attn_layers, B]  (Eq. 5, token-avg)
    kv: Optional[tuple]                  # (k, v) each [n_attn, B, S, Hkv, hd]
    attn_scores: Optional[jnp.ndarray]   # [n_attn, B, Hkv, S] H2O column sums
    ssm_state: Optional[tuple]           # (state, conv_state) stacked per layer
    aux_loss: jnp.ndarray                # scalar (MoE load balance)


def _cos_sim(a, b, valid):
    """Token-averaged cosine similarity between residual streams. [B]"""
    af, bf = a.astype(jnp.float32), b.astype(jnp.float32)
    num = (af * bf).sum(-1)
    den = jnp.sqrt((af * af).sum(-1) * (bf * bf).sum(-1)) + 1e-8
    cs = num / den                                           # [B, S]
    if valid is None:
        return cs.mean(-1)
    w = valid.astype(jnp.float32)
    return (cs * w).sum(-1) / jnp.clip(w.sum(-1), 1.0)


def embed_tokens(params, cfg, tokens):
    """Token ids -> decoder input embeddings: table lookup + gemma-style
    sqrt(d) scaling, cast to the model dtype.  THE definition of what a
    token prompt feeds the stack — the decode step and the multimodal
    intake's text segments (`serving/intake.py`) call this too, so an
    embeds-carrying text request is bit-identical to the token path.
    (The sqrt(d) scaling keeps residual magnitudes sane for random-init
    studies; harmless otherwise.)"""
    x = params["embed"][tokens]
    return (x * jnp.sqrt(float(cfg.d_model)).astype(x.dtype)).astype(
        jnp.dtype(cfg.dtype))


def _embed(params, cfg, tokens, embeds):
    if embeds is not None:
        return embeds.astype(jnp.dtype(cfg.dtype))
    return hint(embed_tokens(params, cfg, tokens), {0: "batch"})


def forward(
    params,
    cfg: ModelConfig,
    tokens: Optional[jnp.ndarray] = None,      # [B, S] int32
    embeds: Optional[jnp.ndarray] = None,      # [B, S, d] (vlm/audio stub frontends)
    positions: Optional[jnp.ndarray] = None,   # [B, S] or [B, S, 3]
    valid: Optional[jnp.ndarray] = None,       # [B, S] bool
    collect_kv: bool = False,                  # prefill: return full KV + H2O scores
    remat: bool = False,                       # checkpoint each scan BODY
    segments: Optional[jnp.ndarray] = None,    # [B, S] int32 packed segment ids
    state_take: Optional[jnp.ndarray] = None,  # [B, K] recurrent-state snapshots
    state_take_aligned: bool = False,          # static: takes sit on chunk ends
    ctx=None,                                  # (k [L,B,C,Hkv,hd], v, pos [B,C])
    state_in=None,                             # (ssm [L,B,H,P,N], conv [L,B,W-1,C])
) -> ForwardOut:
    """remat=True reruns each layer's interior in the backward pass so the
    layer scan saves only its carry — without it, XLA's while-loop autodiff
    stashes every per-layer intermediate (e.g. [L, E, C, f] MoE hiddens),
    which dominated the training-step memory roofline (§Perf A2).

    Packed prefill (DESIGN.md §5): ``segments`` makes every attention mask
    block-diagonal and resets the SSM recurrence at segment boundaries, so
    one row can carry several concatenated requests (positions reset per
    segment).  ``state_take`` [B,K] makes recurrent layers return state
    snapshots after those positions ([L, B, K, ...]) instead of row-final
    states — one per packed segment.

    ``ctx`` is per-layer cached-prefix KV (prefix reuse and chunked
    prefill, DESIGN.md §5): the leading axis matches the attention-layer
    scan, so each layer's gathered context rides the scan as an extra
    input.  On its own it serves attention-only families — a cached
    prefix cannot restore a recurrent layer's state, which is why the
    serving layer gates prefix caching to attention-only models.

    ``state_in`` lifts that restriction for CHUNKED prefill: per-layer
    initial recurrent carries ``(ssm [L_rec, B, H, P, N], conv
    [L_rec, B, W-1, C])`` — the states the previous chunk's forward
    returned — seed each recurrent layer's scan, so a prompt split at
    SSD-chunk-aligned boundaries integrates bit-identically to one
    monolithic pass (`ssm.ssd_chunked`'s `initial_state` path).  Hybrid
    families may then combine ``ctx`` (the previous chunks' KV) with
    ``state_in`` (their recurrent carries); ``ctx`` without ``state_in``
    still asserts on recurrent families."""
    x = _embed(params, cfg, tokens, embeds)
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    if cfg.is_ssm_only:
        assert ctx is None, "prefix ctx requires attention layers"
        x, cos, ssm_state = _ssm_stack(params, cfg, x, valid, remat,
                                       segments, state_take,
                                       state_take_aligned, state_in)
        kv = scores = None
        aux = jnp.zeros((), jnp.float32)
    elif cfg.is_hybrid:
        assert ctx is None or state_in is not None, \
            "prefix ctx cannot restore recurrent state"
        x, cos, kv, scores, ssm_state, aux = _hybrid_stack(
            params, cfg, x, positions, valid, collect_kv, remat,
            segments, state_take, state_take_aligned, ctx, state_in)
    else:
        assert state_in is None, "state_in requires recurrent layers"
        x, cos, kv, scores, aux = _dense_stack(
            params, cfg, x, positions, valid, collect_kv, remat, segments,
            ctx=ctx)
        ssm_state = None

    x = apply_norm(params["final_norm"], x, cfg)
    logits = (x @ params["unembed"]).astype(jnp.float32)
    logits = hint(logits, {0: "batch", 2: "model"})
    if cfg.final_softcap:
        logits = jnp.tanh(logits / cfg.final_softcap) * cfg.final_softcap
    if cfg.v_padded != cfg.vocab_size:
        pad_mask = jnp.arange(cfg.v_padded) >= cfg.vocab_size
        logits = jnp.where(pad_mask, -1e30, logits)
    return ForwardOut(logits, cos, kv, scores, ssm_state, aux)


def _attn_block(bp, cfg, x, positions, valid, window, collect_kv,
                segments=None, ctx=None):
    """norm -> attention -> residual. Returns (x, cos, k, v, colsum)."""
    pre = x
    h = apply_norm(bp["attn_norm"], x, cfg)
    ap = attn_lib.AttnParams(**bp["attn"])
    out, k, v, colsum = attn_lib.full_attention(
        ap, h, positions, cfg, window, valid, return_colsums=collect_kv,
        segments=segments, ctx=ctx)
    if cfg.use_post_norms:
        out = apply_norm(bp["post_attn_norm"], out, cfg)
    x = x + out
    cos = _cos_sim(pre, x, valid)
    return x, cos, k, v, colsum


def _ffn_block(bp, cfg, x, valid):
    h = apply_norm(bp["mlp_norm"], x, cfg)
    if cfg.is_moe:
        out, aux = moe_lib.apply_moe(moe_lib.MoeParams(**bp["moe"]), h, cfg)
    else:
        out = mlp_lib.apply_mlp(mlp_lib.MlpParams(**bp["mlp"]), h, cfg)
        aux = jnp.zeros((), jnp.float32)
    if cfg.use_post_norms:
        out = apply_norm(bp["post_mlp_norm"], out, cfg)
    return x + out, aux


def _remat(body, remat):
    if not remat:
        return body
    return jax.checkpoint(body, prevent_cse=False,
                          policy=jax.checkpoint_policies.nothing_saveable)


def _dense_stack(params, cfg, x, positions, valid, collect_kv, remat=False,
                 segments=None, ctx=None):
    windows = layer_windows(cfg)
    # cached-prefix KV rides the layer scan as extra inputs; its positions
    # are layer-invariant (one [B, C] vector closed over)
    ctx_xs = (ctx[0], ctx[1]) if ctx is not None else ()
    pos_ctx = ctx[2] if ctx is not None else None

    def body(carry, inp):
        # re-pin the residual stream: the scan boundary loses the batch
        # sharding, leaving per-layer saved activations replicated over
        # `data` (§Perf A4); the d-dim model shard makes the per-layer remat
        # stash fit HBM at the cost of a per-layer all-gather — only worth
        # paying when a bwd stash exists, i.e. under remat (§Perf A6/E1)
        x = hint(carry, {0: "batch", 2: "model"} if remat else {0: "batch"})
        bp, window, *ctx_in = inp
        ctx_l = (ctx_in[0], ctx_in[1], pos_ctx) if ctx_in else None
        x, cos, k, v, colsum = _attn_block(bp, cfg, x, positions, valid, window,
                                           collect_kv, segments, ctx=ctx_l)
        x, aux = _ffn_block(bp, cfg, x, valid)
        outs = (cos, aux)
        if collect_kv:
            outs = outs + (k, v, colsum)
        return x, outs

    x, outs = jax.lax.scan(_remat(body, remat), x,
                           (params["layers"], windows) + ctx_xs)
    cos, aux = outs[0], outs[1]
    if collect_kv:
        kv, scores = (outs[2], outs[3]), outs[4]
    else:
        kv, scores = None, None
    return x, cos, kv, scores, aux.sum()


def _ssm_stack(params, cfg, x, valid, remat=False, segments=None,
               state_take=None, state_take_aligned=False, state_in=None):
    # chunked-prefill resume: per-layer initial carries ride the layer scan
    # as extra inputs, seeding each mixer exactly where the last chunk left it
    xs = (params["layers"],) + (tuple(state_in) if state_in is not None
                                else ())

    def body(carry, inp):
        bp, s0, c0 = inp if state_in is not None else (inp, None, None)
        x = hint(carry, {0: "batch", 2: "model"} if remat else {0: "batch"})
        pre = x
        h = apply_norm(bp["norm"], x, cfg)
        out, (state, conv) = ssm_lib.ssm_forward(
            ssm_lib.SsmParams(**bp["ssm"]), h, cfg,
            state=s0, conv_state=c0,
            segments=segments, state_take=state_take,
            state_take_aligned=state_take_aligned)
        x = x + out
        cos = _cos_sim(pre, x, valid)
        return x, (cos, state, conv)

    x, (cos, states, convs) = jax.lax.scan(
        _remat(body, remat), x, xs if state_in is not None else xs[0])
    return x, cos, (states, convs)


def _hybrid_stack(params, cfg, x, positions, valid, collect_kv, remat=False,
                  segments=None, state_take=None, state_take_aligned=False,
                  ctx=None, state_in=None):
    """Zamba2-style: scan over super-blocks of `attn_period` mamba blocks +
    one shared-weight attention/mlp block (its KV cache IS per-invocation).

    Chunked prefill threads BOTH optionals through the super-block scan:
    ``state_in`` carries reshape to [n_super, period, ...] and seed the
    inner mamba scan, ``ctx``'s leading axis is the attention-invocation
    count (== n_super), one context slice per shared-attention call."""
    sp = params["shared_attn"]
    n_super = cfg.n_layers // cfg.attn_period
    s_xs = ()
    if state_in is not None:
        s_xs = tuple(a.reshape((n_super, cfg.attn_period) + a.shape[1:])
                     for a in state_in)
    ctx_xs = (ctx[0], ctx[1]) if ctx is not None else ()
    pos_ctx = ctx[2] if ctx is not None else None

    def body(carry, inp):
        x = carry
        bps, rest = inp[0], inp[1:]
        if state_in is not None:
            in_xs, rest = (bps,) + rest[:2], rest[2:]
        else:
            in_xs = bps
        ctx_l = (rest[0], rest[1], pos_ctx) if rest else None

        def inner(c, binp):
            bp, s0, c0 = binp if state_in is not None else (binp, None, None)
            h = apply_norm(bp["norm"], c, cfg)
            out, (state, conv) = ssm_lib.ssm_forward(
                ssm_lib.SsmParams(**bp["ssm"]), h, cfg,
                state=s0, conv_state=c0,
                segments=segments, state_take=state_take,
                state_take_aligned=state_take_aligned)
            return c + out, (state, conv)

        x, (states, convs) = jax.lax.scan(inner, x, in_xs)
        x, cos, k, v, colsum = _attn_block(sp, cfg, x, positions, valid,
                                           GLOBAL_WINDOW, collect_kv,
                                           segments, ctx=ctx_l)
        h2 = apply_norm(sp["mlp_norm"], x, cfg)
        x = x + mlp_lib.apply_mlp(mlp_lib.MlpParams(**sp["mlp"]), h2, cfg)
        outs = (cos, states, convs)
        if collect_kv:
            outs = outs + (k, v, colsum)
        return x, outs

    x, outs = jax.lax.scan(_remat(body, remat), x,
                           (params["layers"],) + s_xs + ctx_xs)
    cos, states, convs = outs[0], outs[1], outs[2]
    n_super = states.shape[0]
    # flatten [n_super, period, ...] -> [n_layers, ...]
    states = jax.tree.map(
        lambda a: a.reshape((-1,) + a.shape[2:]), (states, convs))
    if collect_kv:
        kv, scores = (outs[3], outs[4]), outs[5]
    else:
        kv, scores = None, None
    return x, cos, kv, scores, states, jnp.zeros((), jnp.float32)
