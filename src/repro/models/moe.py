"""Mixture-of-Experts FFN: top-k router + capacity-based dispatch/combine.

GShard/Switch-style einsum dispatch: exact top-k routing with a per-expert
capacity so the computation is static-shaped and shards cleanly on a TPU mesh
(experts on the `model` axis).  FLOPs scale with `experts_per_tok *
capacity_factor`, i.e. with *active* — not total — parameters, which keeps the
roofline's MODEL_FLOPS/HLO_FLOPs ratio honest.

Covers mixtral-8x22b (8e top-2), qwen3-moe (128e top-8) and
moonshot-v1 (64e top-6).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.shard_hints import hint


class MoeParams(NamedTuple):
    w_router: jnp.ndarray  # [d, E]
    w_gate: jnp.ndarray    # [E, d, f]
    w_up: jnp.ndarray      # [E, d, f]
    w_down: jnp.ndarray    # [E, f, d]


def init_moe(key, cfg) -> MoeParams:
    pd = jnp.dtype(cfg.param_dtype)
    d, f, E = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    kr, k1, k2, k3 = jax.random.split(key, 4)
    s, so = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    return MoeParams(
        w_router=(jax.random.normal(kr, (d, E), jnp.float32) * s).astype(jnp.float32),
        w_gate=(jax.random.normal(k1, (E, d, f), jnp.float32) * s).astype(pd),
        w_up=(jax.random.normal(k2, (E, d, f), jnp.float32) * s).astype(pd),
        w_down=(jax.random.normal(k3, (E, f, d), jnp.float32) * so).astype(pd),
    )


def capacity(n_tokens: int, cfg) -> int:
    c = int(math.ceil(cfg.experts_per_tok * n_tokens * cfg.capacity_factor / cfg.n_experts))
    return max(c, 1)


def _route(p: MoeParams, xt, cfg):
    """Router: returns (gate_vals [T,K], gate_idx [T,K], aux scalar)."""
    E, K = cfg.n_experts, cfg.experts_per_tok
    logits = xt.astype(jnp.float32) @ p.w_router                  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)                 # [T, K]
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)
    # load-balance aux (Switch): E * sum_e f_e * P_e — top-1 fractions via
    # bincount (no [T,E] one-hot materialized)
    top1 = jax.nn.one_hot(gate_idx[:, 0], E, dtype=jnp.float32)
    aux = cfg.router_aux_weight * E * jnp.sum(
        top1.mean(axis=0) * probs.mean(axis=0))
    return gate_vals, gate_idx, aux


def _expert_ffn(p: MoeParams, xin, cfg):
    """[E, C, d] -> [E, C, d] SwiGLU per expert (the real MoE FLOPs)."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xin, p.w_gate)) \
        * jnp.einsum("ecd,edf->ecf", xin, p.w_up)
    return jnp.einsum("ecf,efd->ecd", h, p.w_down)


@jax.custom_vjp
def _permute_rows(src, fwd_idx, fwd_valid, inv_idx, inv_valid):
    """out[i] = src[fwd_idx[i]] if fwd_valid[i] else 0.

    fwd/inv describe a *partial permutation* (each kept row appears exactly
    once on both sides), so the VJP is the inverse gather — never a scatter.
    XLA's scatter expander otherwise lowers the d-column scatter (and the
    gather's transpose) to a sort over [rows, d] u32 key tensors, which
    dominated the MoE training-step bytes (§Perf A5).
    """
    return jnp.where(fwd_valid[:, None], src[fwd_idx], 0)


def _permute_rows_fwd(src, fwd_idx, fwd_valid, inv_idx, inv_valid):
    out = _permute_rows(src, fwd_idx, fwd_valid, inv_idx, inv_valid)
    return out, (fwd_idx, fwd_valid, inv_idx, inv_valid, src.shape[0])


def _permute_rows_bwd(res, g):
    fwd_idx, fwd_valid, inv_idx, inv_valid, n_src = res
    dsrc = jnp.where(inv_valid[:, None],
                     g[jnp.minimum(inv_idx, g.shape[0] - 1)], 0)
    return dsrc.astype(g.dtype), None, None, None, None


_permute_rows.defvjp(_permute_rows_fwd, _permute_rows_bwd)


def apply_moe(p: MoeParams, x: jnp.ndarray, cfg):
    """x: [B, S, d] -> (out [B, S, d], aux_loss scalar).

    Sort + gather-only dispatch (§Perf iterations A1/A5): assignments are
    ordered by a stable argsort on expert id; ranks within each expert come
    from group offsets; tokens move to/from the [E, C] expert layout through
    `_permute_rows` (pure gathers in both directions via custom_vjp).  Zero
    matmul FLOPs and O(T*K) bookkeeping — the GShard-style one-hot einsum
    dispatch (kept as `apply_moe_einsum` for A/B tests) costs O(T*E*C*d) dot
    FLOPs and dominated the whole training step for 128-expert models
    (useful-FLOP ratio 0.3% -> 60%, EXPERIMENTS.md §Perf).

    Tokens over a full expert's capacity are dropped (contribute zero), the
    standard static-shape trade-off; drop priority is token-major (vs the
    einsum path's k-major) — equivalent when nothing drops.
    """
    B, S, d = x.shape
    T = B * S
    E, K = cfg.n_experts, cfg.experts_per_tok
    C = capacity(T, cfg)
    xt = x.reshape(T, d)
    gate_vals, gate_idx, aux = _route(p, xt, cfg)

    flat_e = gate_idx.reshape(-1)                                 # [T*K]
    order = jnp.argsort(flat_e, stable=True)                      # expert-major
    counts = jnp.bincount(flat_e, length=E)                       # [E]
    starts = jnp.cumsum(counts) - counts                          # [E]
    inv = jnp.zeros((T * K,), jnp.int32).at[order].set(
        jnp.arange(T * K, dtype=jnp.int32))      # position in sorted order
    rank = inv - starts[flat_e].astype(jnp.int32)                 # rank in group
    keep = rank < C
    slot_of_tk = jnp.where(keep, flat_e * C + rank, E * C - 1)    # [T*K]

    # slot -> (t,k) source index (gather table for the dispatch direction)
    e_of_slot = jnp.arange(E * C, dtype=jnp.int32) // C
    r_of_slot = jnp.arange(E * C, dtype=jnp.int32) % C
    pos_sorted = starts[e_of_slot].astype(jnp.int32) + r_of_slot
    slot_valid = r_of_slot < counts[e_of_slot]
    tk_of_slot = order[jnp.minimum(pos_sorted, T * K - 1)].astype(jnp.int32)

    src = jnp.repeat(xt[:, None, :], K, axis=1).reshape(T * K, d)
    src = hint(src, {0: "batch"})
    xin = _permute_rows(src, tk_of_slot, slot_valid, slot_of_tk, keep)
    # pin to expert-parallel layout: without this XLA keeps the dispatched
    # tokens replicated (~E*C*d bytes PER DEVICE, §Perf A3)
    xin = hint(xin.reshape(E, C, d), {0: "model", 1: "data"})

    eout = _expert_ffn(p, xin, cfg)                               # [E, C, d]
    eout = hint(eout, {0: "model", 1: "data"})
    gathered = _permute_rows(eout.reshape(E * C, d), slot_of_tk, keep,
                             tk_of_slot, slot_valid)
    gathered = hint(gathered, {0: "batch"})
    w = (gate_vals.reshape(T * K) * keep).astype(jnp.float32)
    out = (gathered.astype(jnp.float32) * w[:, None]) \
        .reshape(T, K, d).sum(axis=1)
    return out.reshape(B, S, d).astype(x.dtype), aux


def apply_moe_einsum(p: MoeParams, x: jnp.ndarray, cfg):
    """Legacy GShard-style one-hot dispatch (v0 baseline; A/B reference)."""
    B, S, d = x.shape
    T = B * S
    E, K = cfg.n_experts, cfg.experts_per_tok
    C = capacity(T, cfg)
    xt = x.reshape(T, d)
    gate_vals, gate_idx, aux = _route(p, xt, cfg)

    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)       # [T, K, E]
    flat = onehot.transpose(1, 0, 2).reshape(K * T, E)            # [K*T, E]
    pos_in_expert = (jnp.cumsum(flat, axis=0) - flat)
    pos_in_expert = pos_in_expert.reshape(K, T, E).transpose(1, 0, 2)
    pos_tok = jnp.einsum("tke,tke->tk", pos_in_expert, onehot)    # [T, K]
    keep = pos_tok < C

    cap_onehot = jax.nn.one_hot(pos_tok.astype(jnp.int32), C, dtype=jnp.float32)
    disp = jnp.einsum("tke,tkc->tec", onehot * keep[..., None], cap_onehot)
    comb = jnp.einsum("tec,tk,tke->tec", disp, gate_vals, onehot)

    xin = jnp.einsum("tec,td->ecd", disp, xt.astype(jnp.float32)).astype(x.dtype)
    eout = _expert_ffn(p, xin, cfg)
    out = jnp.einsum("tec,ecd->td", comb, eout.astype(jnp.float32))
    return out.reshape(B, S, d).astype(x.dtype), aux
