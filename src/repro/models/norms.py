"""Normalisation layers: RMSNorm, LayerNorm, non-parametric LN (OLMo)."""
from __future__ import annotations

import jax.numpy as jnp


def init_norm(cfg, dim: int):
    """Return the parameter pytree for one norm of width `dim` (or {} if n/a)."""
    pd = jnp.dtype(cfg.param_dtype)
    if cfg.norm_type == "rmsnorm":
        return {"scale": jnp.ones((dim,), pd)}
    if cfg.norm_type == "layernorm":
        return {"scale": jnp.ones((dim,), pd), "bias": jnp.zeros((dim,), pd)}
    if cfg.norm_type == "nonparametric_ln":    # OLMo: no affine params
        return {}
    raise ValueError(cfg.norm_type)


def apply_norm(params, x, cfg):
    """Normalise over the last axis in fp32, cast back to x.dtype."""
    eps = cfg.norm_eps
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "rmsnorm":
        y = xf * _rsqrt_mean_sq(xf, eps)
        y = y * params["scale"].astype(jnp.float32)
    elif cfg.norm_type == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jnp.reciprocal(jnp.sqrt(var + eps))
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    elif cfg.norm_type == "nonparametric_ln":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jnp.reciprocal(jnp.sqrt(var + eps))
    else:
        raise ValueError(cfg.norm_type)
    return y.astype(x.dtype)


def _rsqrt_mean_sq(xf, eps):
    return jnp.reciprocal(jnp.sqrt((xf * xf).mean(-1, keepdims=True) + eps))


def rms_head_norm(scale, x, eps=1e-6):
    """qk-norm: RMSNorm applied to the last (head_dim) axis of q/k."""
    xf = x.astype(jnp.float32)
    y = xf * _rsqrt_mean_sq(xf, eps) * scale.astype(jnp.float32)
    return y.astype(x.dtype)
