"""Modality frontends — STUBS by assignment.

The [vlm] and [audio] architectures specify the transformer *backbone* only;
the vision encoder (ViT/SigLIP + projector) and audio codec (mel + conv /
EnCodec) are out of scope.  These helpers produce the precomputed patch/frame
embeddings of the right shape (and, for Qwen2-VL, the 3-D M-RoPE position
ids) that the real frontends would emit, so the decoder stack and the serving
engine exercise the exact interfaces a full system would.

Two encoder surfaces exist:

  * the original batch-key helpers (`vision_stub_embeds` /
    `audio_stub_embeds`): one PRNG key for a whole ``[B, n, d]`` batch —
    fine for smoke tests that fabricate one batch and keep it;
  * the *keyed* variants (`vision_stub_embeds_keyed` /
    `audio_stub_embeds_keyed`): one key PER ROW, vmapped, so row ``i``
    depends only on ``keys[i]``.  That batch-invariance is what lets the
    request-intake subsystem (`serving/intake.py`) encode a whole burst
    bucket in one dispatch while each request's embeddings stay identical
    to a solo encode — the property the vlm/audio token-identity tests
    pin continuous serving against.

``STUB_FRONTENDS`` is the registry the capability report and the intake
validate `ModelConfig.frontend` against.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

#: frontend name (ModelConfig.frontend) -> the segment kind it encodes
STUB_FRONTENDS = {"vision_stub": "image", "audio_stub": "audio"}


def _mrope_grid(n_patches: int, grid_hw=None):
    """Qwen2-VL M-RoPE ids over a patch grid: temporal id constant,
    height/width ids laid out over ``grid_hw``.  Returns [n_patches, 3]."""
    if grid_hw is None:
        side = max(int(n_patches ** 0.5), 1)
        grid_hw = (side, max(n_patches // side, 1))
    h, w = grid_hw
    hw = h * w
    ids_h = jnp.repeat(jnp.arange(h), w)[:n_patches]
    ids_w = jnp.tile(jnp.arange(w), h)[:n_patches]
    pad = n_patches - min(hw, n_patches)
    if pad > 0:
        ids_h = jnp.concatenate([ids_h, jnp.zeros((pad,), ids_h.dtype)])
        ids_w = jnp.concatenate([ids_w, jnp.zeros((pad,), ids_w.dtype)])
    t = jnp.zeros((n_patches,), jnp.int32)
    return jnp.stack([t, ids_h.astype(jnp.int32), ids_w.astype(jnp.int32)],
                     axis=-1)


def vision_stub_embeds(key, batch: int, n_patches: int, cfg, grid_hw=None):
    """[B, n_patches, d] patch embeddings + [B, n_patches, 3] M-RoPE ids.

    Position ids follow Qwen2-VL's scheme: temporal id constant per image,
    height/width ids laid out over the patch grid.
    """
    d = cfg.d_model
    embeds = jax.random.normal(key, (batch, n_patches, d), jnp.float32) * 0.02
    pos3 = jnp.broadcast_to(_mrope_grid(n_patches, grid_hw)[None],
                            (batch, n_patches, 3))
    return embeds.astype(jnp.dtype(cfg.dtype)), pos3


def vision_stub_embeds_keyed(keys, n_patches: int, cfg, grid_hw=None):
    """Per-row-keyed `vision_stub_embeds`: ``keys [B]`` -> [B, n_patches, d]
    float32 embeddings (+ broadcast M-RoPE ids) where row ``i`` is a pure
    function of ``keys[i]`` — batching never changes a request's values."""
    d = cfg.d_model

    def one(k):
        return jax.random.normal(k, (n_patches, d), jnp.float32) * 0.02

    embeds = jax.vmap(one)(keys)
    pos3 = jnp.broadcast_to(_mrope_grid(n_patches, grid_hw)[None],
                            (keys.shape[0], n_patches, 3))
    return embeds, pos3


def audio_stub_embeds(key, batch: int, n_frames: int, cfg):
    """[B, n_frames, d] EnCodec-style frame embeddings (musicgen decoder input)."""
    d = cfg.d_model
    e = jax.random.normal(key, (batch, n_frames, d), jnp.float32) * 0.02
    return e.astype(jnp.dtype(cfg.dtype))


def audio_stub_embeds_keyed(keys, n_frames: int, cfg):
    """Per-row-keyed `audio_stub_embeds`: row ``i`` depends only on
    ``keys[i]`` (see `vision_stub_embeds_keyed`)."""
    d = cfg.d_model

    def one(k):
        return jax.random.normal(k, (n_frames, d), jnp.float32) * 0.02

    return jax.vmap(one)(keys)


def mixed_positions(batch: int, n_frontend: int, n_text: int):
    """Concatenated [frontend tokens | text tokens] 1-D positions.

    This is the position scheme the intake's embeds-carrying requests use
    end to end: one sequential index over the mixed sequence (M-RoPE
    models see it as the degenerate t=h=w triple via `_project_qkv`'s
    repeat), which is exactly what the decode step's scalar ``t`` extends
    — so cache positions, eviction windows and RoPE agree between the
    frontend span and the generated tail.
    """
    pos = jnp.arange(n_frontend + n_text, dtype=jnp.int32)
    return jnp.broadcast_to(pos[None], (batch, n_frontend + n_text))
