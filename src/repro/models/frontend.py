"""Modality frontends — STUBS by assignment.

The [vlm] and [audio] architectures specify the transformer *backbone* only;
the vision encoder (ViT/SigLIP + projector) and audio codec (mel + conv /
EnCodec) are out of scope.  These helpers produce the precomputed patch/frame
embeddings of the right shape (and, for Qwen2-VL, the 3-D M-RoPE position
ids) that the real frontends would emit, so the decoder stack and the serving
engine exercise the exact interfaces a full system would.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def vision_stub_embeds(key, batch: int, n_patches: int, cfg, grid_hw=None):
    """[B, n_patches, d] patch embeddings + [B, n_patches, 3] M-RoPE ids.

    Position ids follow Qwen2-VL's scheme: temporal id constant per image,
    height/width ids laid out over the patch grid.
    """
    d = cfg.d_model
    embeds = jax.random.normal(key, (batch, n_patches, d), jnp.float32) * 0.02
    if grid_hw is None:
        side = max(int(n_patches ** 0.5), 1)
        grid_hw = (side, max(n_patches // side, 1))
    h, w = grid_hw
    hw = h * w
    ids_h = jnp.repeat(jnp.arange(h), w)[:n_patches]
    ids_w = jnp.tile(jnp.arange(w), h)[:n_patches]
    pad = n_patches - min(hw, n_patches)
    if pad > 0:
        ids_h = jnp.concatenate([ids_h, jnp.zeros((pad,), ids_h.dtype)])
        ids_w = jnp.concatenate([ids_w, jnp.zeros((pad,), ids_w.dtype)])
    t = jnp.zeros((n_patches,), jnp.int32)
    pos3 = jnp.stack([t, ids_h.astype(jnp.int32), ids_w.astype(jnp.int32)], axis=-1)
    pos3 = jnp.broadcast_to(pos3[None], (batch, n_patches, 3))
    return embeds.astype(jnp.dtype(cfg.dtype)), pos3


def audio_stub_embeds(key, batch: int, n_frames: int, cfg):
    """[B, n_frames, d] EnCodec-style frame embeddings (musicgen decoder input)."""
    d = cfg.d_model
    e = jax.random.normal(key, (batch, n_frames, d), jnp.float32) * 0.02
    return e.astype(jnp.dtype(cfg.dtype))


def mixed_positions(batch: int, n_frontend: int, n_text: int):
    """Concatenated [frontend tokens | text tokens] 1-D positions."""
    pos = jnp.arange(n_frontend + n_text, dtype=jnp.int32)
    return jnp.broadcast_to(pos[None], (batch, n_frontend + n_text))
