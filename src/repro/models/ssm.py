"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) mixer.

Chunked SSD algorithm for train/prefill (sub-quadratic: O(S/L * (L^2 + L*N*P))
per head) and an O(1)-state recurrent step for decode.  Single B/C group
shared across heads (Mamba2 default ngroups=1).

State layout for decode: [B, H, P, N] per layer — this *replaces* the KV cache
for SSM blocks, which is why SqueezeAttention's budget reallocation does not
apply to them (DESIGN.md §4).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp


class SsmParams(NamedTuple):
    w_in: jnp.ndarray     # [d, 2*di + 2*N + H]  (z, x, B, C, dt)
    conv_w: jnp.ndarray   # [W, di + 2*N] depthwise causal conv over (x,B,C)
    conv_b: jnp.ndarray   # [di + 2*N]
    a_log: jnp.ndarray    # [H]
    dt_bias: jnp.ndarray  # [H]
    d_skip: jnp.ndarray   # [H]
    w_out: jnp.ndarray    # [di, d]


def init_ssm(key, cfg) -> SsmParams:
    pd = jnp.dtype(cfg.param_dtype)
    d, di, N = cfg.d_model, cfg.d_inner, cfg.ssm_state
    H, W = cfg.ssm_heads, cfg.ssm_conv_width
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    so = 1.0 / math.sqrt(di)
    # dt bias so softplus(dt_bias) spans [1e-3, 1e-1] (mamba2 init)
    dt = jnp.exp(jax.random.uniform(k3, (H,), jnp.float32)
                 * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))  # inverse softplus
    return SsmParams(
        w_in=(jax.random.normal(k1, (d, 2 * di + 2 * N + H), jnp.float32) * s).astype(pd),
        conv_w=(jax.random.normal(k2, (W, di + 2 * N), jnp.float32) * 0.1).astype(pd),
        conv_b=jnp.zeros((di + 2 * N,), pd),
        a_log=jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),  # A = -exp(a_log)
        dt_bias=dt_bias.astype(jnp.float32),
        d_skip=jnp.ones((H,), jnp.float32),
        w_out=(jax.random.normal(k4, (di, d), jnp.float32) * so).astype(pd),
    )


def conv_channels(cfg) -> int:
    """Channels of the depthwise causal conv input (x, B, C stacked)."""
    return cfg.d_inner + 2 * cfg.ssm_state


def empty_decode_state(cfg, n_layers: int, batch: int):
    """Zero per-row recurrent-state arenas for `n_layers` stacked SSM blocks.

    Returns ``(ssm_state [L, B, H, P, N] float32, conv_state [L, B, W-1, C]
    model-dtype)`` — the layout `ssm_decode_step` carries and continuous
    batching scatters per-row (`core.cache.insert_state_rows`).  The SSD
    state accumulates in fp32 (`ssd_chunked` emits fp32 finals); the conv
    tail is raw activations, so it stays in the model dtype.
    """
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    W, C = cfg.ssm_conv_width, conv_channels(cfg)
    return (jnp.zeros((n_layers, batch, H, P, N), jnp.float32),
            jnp.zeros((n_layers, batch, W - 1, C), jnp.dtype(cfg.dtype)))


def _split_proj(p: SsmParams, x, cfg):
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    zxbcdt = x @ p.w_in
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:2 * di + 2 * N]
    dt = zxbcdt[..., 2 * di + 2 * N:].astype(jnp.float32)  # [.., H]
    return z, xbc, dt


def _causal_conv(xbc, conv_w, conv_b, conv_state=None):
    """Depthwise causal conv, width W.  xbc: [B,S,C]; conv_state: [B,W-1,C]."""
    W = conv_w.shape[0]
    if conv_state is None:
        pad = jnp.zeros(xbc.shape[:1] + (W - 1,) + xbc.shape[2:], xbc.dtype)
    else:
        pad = conv_state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)                 # [B, S+W-1, C]
    out = sum(xp[:, i:i + xbc.shape[1], :] * conv_w[i] for i in range(W))
    out = jax.nn.silu(out + conv_b)
    new_state = xp[:, -(W - 1):, :]
    return out, new_state


def ssd_chunked(xh, bh, ch, dt, a_log, d_skip, chunk: int, initial_state=None):
    """Chunked SSD scan.

    xh: [B,S,H,P], bh/ch: [B,S,N], dt: [B,S,H] (post-softplus, fp32),
    a_log: [H].  Returns y [B,S,H,P] and final state [B,H,P,N].
    """
    B, S, H, P = xh.shape
    N = bh.shape[-1]
    L = chunk
    S_orig = S
    A = -jnp.exp(a_log.astype(jnp.float32))                        # [H]
    dta = dt * A                                                   # [B,S,H] log-decay
    xf = xh.astype(jnp.float32) * dt[..., None]                    # dt-weighted input
    bf = bh.astype(jnp.float32)
    cf = ch.astype(jnp.float32)
    pad = (-S) % L
    if pad:
        # state-invariant padding: dta=0 (decay 1), xdt=0 (no update)
        xf = jnp.pad(xf, ((0, 0), (0, pad), (0, 0), (0, 0)))
        bf = jnp.pad(bf, ((0, 0), (0, pad), (0, 0)))
        cf = jnp.pad(cf, ((0, 0), (0, pad), (0, 0)))
        dta = jnp.pad(dta, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // L

    xc = xf.reshape(B, nc, L, H, P)
    bc = bf.reshape(B, nc, L, N)
    cc = cf.reshape(B, nc, L, N)
    ac = dta.reshape(B, nc, L, H)
    cum = jnp.cumsum(ac, axis=2)                                   # [B,nc,L,H]

    # ---- intra-chunk (quadratic within the chunk) ----------------------------
    # decay[t,s] = exp(cum[t] - cum[s]) for s <= t
    rel = cum[:, :, :, None, :] - cum[:, :, None, :, :]            # [B,nc,L,L,H]
    causal = jnp.tril(jnp.ones((L, L), bool))
    decay = jnp.where(causal[None, None, :, :, None], jnp.exp(rel), 0.0)
    scores = jnp.einsum("bqln,bqmn->bqlm", cc, bc)                 # [B,nc,L,L]
    y_intra = jnp.einsum("bqlm,bqlmh,bqmhp->bqlhp", scores, decay, xc)

    # ---- chunk summary states -------------------------------------------------
    # state_q = sum_s exp(cum[last] - cum[s]) * b[s] (x) xdt[s]
    tail = jnp.exp(cum[:, :, -1:, :] - cum)                        # [B,nc,L,H]
    chunk_state = jnp.einsum("bqln,bqlh,bqlhp->bqhpn", bc, tail, xc)

    # ---- inter-chunk recurrence ------------------------------------------------
    chunk_decay = jnp.exp(cum[:, :, -1, :])                        # [B,nc,H]
    if initial_state is None:
        initial_state = jnp.zeros((B, H, P, N), jnp.float32)

    def step(carry, inp):
        st = carry                                                  # [B,H,P,N]
        cs, cd = inp                                                # [B,H,P,N], [B,H]
        new = st * cd[:, :, None, None] + cs
        return new, st                                              # emit state *entering* the chunk

    final, entering = jax.lax.scan(
        step,
        initial_state,
        (chunk_state.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    entering = entering.transpose(1, 0, 2, 3, 4)                    # [B,nc,H,P,N]

    y_inter = jnp.einsum("bqln,bqlh,bqhpn->bqlhp", cc, jnp.exp(cum), entering)
    y = (y_intra + y_inter).reshape(B, S, H, P)[:, :S_orig]
    y = y + xh.astype(jnp.float32) * d_skip[None, None, :, None]
    return y, final


def ssm_forward(p: SsmParams, x, cfg, state=None, conv_state=None):
    """Full-sequence Mamba2 mixer.  x: [B,S,d] -> (y, (ssm_state, conv_state))."""
    B, S, _ = x.shape
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z, xbc, dt = _split_proj(p, x, cfg)
    xbc, new_conv = _causal_conv(xbc, p.conv_w, p.conv_b, conv_state)
    xs = xbc[..., :di].reshape(B, S, H, P)
    bh = xbc[..., di:di + N]
    ch = xbc[..., di + N:]
    dt = jax.nn.softplus(dt + p.dt_bias)
    y, final = ssd_chunked(xs, bh, ch, dt, p.a_log, p.d_skip, cfg.ssm_chunk, state)
    y = (y.reshape(B, S, di) * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return y @ p.w_out, (final, new_conv)


def ssm_decode_step(p: SsmParams, x, cfg, state, conv_state):
    """One-token recurrent step.  x: [B,1,d]; state: [B,H,P,N]; conv: [B,W-1,C]."""
    B = x.shape[0]
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z, xbc, dt = _split_proj(p, x, cfg)
    xbc, new_conv = _causal_conv(xbc, p.conv_w, p.conv_b, conv_state)
    xs = xbc[:, 0, :di].reshape(B, H, P).astype(jnp.float32)
    bh = xbc[:, 0, di:di + N].astype(jnp.float32)                  # [B,N]
    ch = xbc[:, 0, di + N:].astype(jnp.float32)
    dt1 = jax.nn.softplus(dt[:, 0] + p.dt_bias)                    # [B,H]
    A = -jnp.exp(p.a_log.astype(jnp.float32))
    decay = jnp.exp(dt1 * A)                                       # [B,H]
    upd = jnp.einsum("bhp,bn->bhpn", xs * dt1[..., None], bh)
    new_state = state * decay[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", new_state, ch)
    y = y + xs * p.d_skip[None, :, None]
    y = (y.reshape(B, 1, di) * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return y @ p.w_out, (new_state, new_conv)
