"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) mixer.

Chunked SSD algorithm for train/prefill (sub-quadratic: O(S/L * (L^2 + L*N*P))
per head) and an O(1)-state recurrent step for decode.  Single B/C group
shared across heads (Mamba2 default ngroups=1).

State layout for decode: [B, H, P, N] per layer — this *replaces* the KV cache
for SSM blocks, which is why SqueezeAttention's budget reallocation does not
apply to them (DESIGN.md §4).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp


class SsmParams(NamedTuple):
    w_in: jnp.ndarray     # [d, 2*di + 2*N + H]  (z, x, B, C, dt)
    conv_w: jnp.ndarray   # [W, di + 2*N] depthwise causal conv over (x,B,C)
    conv_b: jnp.ndarray   # [di + 2*N]
    a_log: jnp.ndarray    # [H]
    dt_bias: jnp.ndarray  # [H]
    d_skip: jnp.ndarray   # [H]
    w_out: jnp.ndarray    # [di, d]


def init_ssm(key, cfg) -> SsmParams:
    pd = jnp.dtype(cfg.param_dtype)
    d, di, N = cfg.d_model, cfg.d_inner, cfg.ssm_state
    H, W = cfg.ssm_heads, cfg.ssm_conv_width
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    so = 1.0 / math.sqrt(di)
    # dt bias so softplus(dt_bias) spans [1e-3, 1e-1] (mamba2 init)
    dt = jnp.exp(jax.random.uniform(k3, (H,), jnp.float32)
                 * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))  # inverse softplus
    return SsmParams(
        w_in=(jax.random.normal(k1, (d, 2 * di + 2 * N + H), jnp.float32) * s).astype(pd),
        conv_w=(jax.random.normal(k2, (W, di + 2 * N), jnp.float32) * 0.1).astype(pd),
        conv_b=jnp.zeros((di + 2 * N,), pd),
        a_log=jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),  # A = -exp(a_log)
        dt_bias=dt_bias.astype(jnp.float32),
        d_skip=jnp.ones((H,), jnp.float32),
        w_out=(jax.random.normal(k4, (di, d), jnp.float32) * so).astype(pd),
    )


def conv_channels(cfg) -> int:
    """Channels of the depthwise causal conv input (x, B, C stacked)."""
    return cfg.d_inner + 2 * cfg.ssm_state


def empty_decode_state(cfg, n_layers: int, batch: int):
    """Zero per-row recurrent-state arenas for `n_layers` stacked SSM blocks.

    Returns ``(ssm_state [L, B, H, P, N] float32, conv_state [L, B, W-1, C]
    model-dtype)`` — the layout `ssm_decode_step` carries and continuous
    batching scatters per-row (`core.cache.insert_state_rows`).  The SSD
    state accumulates in fp32 (`ssd_chunked` emits fp32 finals); the conv
    tail is raw activations, so it stays in the model dtype.  The same
    pair doubles as the carry-in/carry-out of chunked prefill
    (`forward(..., state_in=...)`): chunk boundaries land on the SSD
    chunk grid (DESIGN.md §5), so resuming from a carried state is
    bit-identical to scanning the prompt in one piece.
    """
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    W, C = cfg.ssm_conv_width, conv_channels(cfg)
    return (jnp.zeros((n_layers, batch, H, P, N), jnp.float32),
            jnp.zeros((n_layers, batch, W - 1, C), jnp.dtype(cfg.dtype)))


def _split_proj(p: SsmParams, x, cfg):
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    zxbcdt = x @ p.w_in
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:2 * di + 2 * N]
    dt = zxbcdt[..., 2 * di + 2 * N:].astype(jnp.float32)  # [.., H]
    return z, xbc, dt


def _causal_conv(xbc, conv_w, conv_b, conv_state=None, segments=None):
    """Depthwise causal conv, width W.  xbc: [B,S,C]; conv_state: [B,W-1,C].

    With ``segments`` (packed prefill, [B,S] int32) a tap only contributes
    when its source token shares the output token's segment id, so the
    receptive field never crosses a request boundary — each segment sees
    the same zero left-padding a fresh sequence would.
    """
    W = conv_w.shape[0]
    S = xbc.shape[1]
    if conv_state is None:
        pad = jnp.zeros(xbc.shape[:1] + (W - 1,) + xbc.shape[2:], xbc.dtype)
    else:
        pad = conv_state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)                 # [B, S+W-1, C]
    if segments is None:
        out = sum(xp[:, i:i + S, :] * conv_w[i] for i in range(W))
    else:
        segp = jnp.concatenate(
            [jnp.full(segments.shape[:1] + (W - 1,), -1, segments.dtype),
             segments], axis=1)                              # [B, S+W-1]
        out = sum(
            jnp.where((segp[:, i:i + S] == segments)[..., None],
                      xp[:, i:i + S, :], 0) * conv_w[i]
            for i in range(W))
    out = jax.nn.silu(out + conv_b)
    new_state = xp[:, -(W - 1):, :]
    return out, new_state


def ssd_chunked(xh, bh, ch, dt, a_log, d_skip, chunk: int, initial_state=None,
                segments=None, take_pos=None, take_aligned: bool = False):
    """Chunked SSD scan.

    xh: [B,S,H,P], bh/ch: [B,S,N], dt: [B,S,H] (post-softplus, fp32),
    a_log: [H].  Returns y [B,S,H,P] and final state [B,H,P,N].

    Packed prefill (DESIGN.md §5) adds two optionals:

    * ``segments`` [B,S] int32, non-decreasing per row — the recurrence
      resets at every segment boundary.  Resets are implemented by
      *masking* (intra-chunk decay, chunk-summary tails, the inter-chunk
      recurrence and the entering-state readout each drop cross-segment
      terms) rather than by injecting -inf log-decays, which would wreck
      the cumsum's precision for every later segment.  When segment starts
      are chunk-aligned the per-segment arithmetic is bit-identical to
      running each segment alone.
    * ``take_pos`` [B,K] int32 (-1 = unused slot) — also return the state
      *after* each listed position: [B,K,H,P,N].  This is how packed
      admission reads one recurrent state per packed request out of a
      single scan.  Return becomes ``(y, final, states_at)``.
      ``take_aligned`` (static) promises every real position sits at a
      chunk boundary (``pos % chunk == chunk-1``): the states are then a
      cheap gather of the scan's own post-chunk values — bit-identical to
      a solo run — and the generic per-position reconstruction is skipped
      entirely.  Packed admission always qualifies (slot boundaries are
      chunk-aligned by construction).
    """
    B, S, H, P = xh.shape
    N = bh.shape[-1]
    L = chunk
    S_orig = S
    A = -jnp.exp(a_log.astype(jnp.float32))                        # [H]
    dta = dt * A                                                   # [B,S,H] log-decay
    xf = xh.astype(jnp.float32) * dt[..., None]                    # dt-weighted input
    bf = bh.astype(jnp.float32)
    cf = ch.astype(jnp.float32)
    pad = (-S) % L
    if pad:
        # state-invariant padding: dta=0 (decay 1), xdt=0 (no update)
        xf = jnp.pad(xf, ((0, 0), (0, pad), (0, 0), (0, 0)))
        bf = jnp.pad(bf, ((0, 0), (0, pad), (0, 0)))
        cf = jnp.pad(cf, ((0, 0), (0, pad), (0, 0)))
        dta = jnp.pad(dta, ((0, 0), (0, pad), (0, 0)))
        if segments is not None:   # edge-pad: padding extends the last segment
            segments = jnp.pad(segments, ((0, 0), (0, pad)), mode="edge")
        S = S + pad
    nc = S // L

    xc = xf.reshape(B, nc, L, H, P)
    bc = bf.reshape(B, nc, L, N)
    cc = cf.reshape(B, nc, L, N)
    ac = dta.reshape(B, nc, L, H)
    cum = jnp.cumsum(ac, axis=2)                                   # [B,nc,L,H]
    sc = segments.reshape(B, nc, L) if segments is not None else None

    # ---- intra-chunk (quadratic within the chunk) ----------------------------
    # decay[t,s] = exp(cum[t] - cum[s]) for s <= t (and seg[t] == seg[s])
    rel = cum[:, :, :, None, :] - cum[:, :, None, :, :]            # [B,nc,L,L,H]
    causal = jnp.tril(jnp.ones((L, L), bool))[None, None]
    if sc is not None:
        causal = causal & (sc[:, :, :, None] == sc[:, :, None, :])
    decay = jnp.where(causal[..., None], jnp.exp(rel), 0.0)
    scores = jnp.einsum("bqln,bqmn->bqlm", cc, bc)                 # [B,nc,L,L]
    y_intra = jnp.einsum("bqlm,bqlmh,bqmhp->bqlhp", scores, decay, xc)

    # ---- chunk summary states -------------------------------------------------
    # state_q = sum_s exp(cum[last] - cum[s]) * b[s] (x) xdt[s], over tokens
    # in the chunk's LAST segment only (earlier segments died at their reset)
    tail = jnp.exp(cum[:, :, -1:, :] - cum)                        # [B,nc,L,H]
    if sc is not None:
        tail = tail * (sc == sc[:, :, -1:])[..., None]
    chunk_state = jnp.einsum("bqln,bqlh,bqlhp->bqhpn", bc, tail, xc)

    # ---- inter-chunk recurrence ------------------------------------------------
    chunk_decay = jnp.exp(cum[:, :, -1, :])                        # [B,nc,H]
    if sc is not None:
        # the state entering chunk q belongs to chunk q-1's last segment;
        # it survives to chunk q's exit iff no reset happened in q (segment
        # ids are non-decreasing, so equality of the two chunk-final ids
        # means exactly that)
        seg_last = sc[:, :, -1]                                    # [B,nc]
        seg_prev_last = jnp.concatenate(
            [jnp.full((B, 1), -1, seg_last.dtype), seg_last[:, :-1]], axis=1)
        chunk_decay = chunk_decay * (
            seg_last == seg_prev_last)[..., None].astype(jnp.float32)
    if initial_state is None:
        initial_state = jnp.zeros((B, H, P, N), jnp.float32)

    def step(carry, inp):
        st = carry                                                  # [B,H,P,N]
        cs, cd = inp                                                # [B,H,P,N], [B,H]
        new = st * cd[:, :, None, None] + cs
        return new, st                                              # emit state *entering* the chunk

    final, entering = jax.lax.scan(
        step,
        initial_state,
        (chunk_state.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    entering = entering.transpose(1, 0, 2, 3, 4)                    # [B,nc,H,P,N]

    inter_w = jnp.exp(cum)                                          # [B,nc,L,H]
    if sc is not None:
        # the entering state is visible to a token only before the chunk's
        # first reset, i.e. while the token still belongs to the segment
        # the state came from
        inter_w = inter_w * (sc == seg_prev_last[:, :, None])[..., None]
    y_inter = jnp.einsum("bqln,bqlh,bqhpn->bqlhp", cc, inter_w, entering)
    y = (y_intra + y_inter).reshape(B, S, H, P)[:, :S_orig]
    y = y + xh.astype(jnp.float32) * d_skip[None, None, :, None]
    if take_pos is None:
        return y, final
    # chunk-aligned take positions read the scan's own post-chunk state —
    # bit-identical to a solo run of the segment (the generic formula
    # re-associates the last chunk's sum, which is only ~ulp-equal);
    # packed admission aligns slots to the chunk grid exactly for this
    after = jnp.concatenate([entering[:, 1:], final[:, None]], axis=1)
    tp = jnp.maximum(take_pos, 0)
    live = (take_pos >= 0)[..., None, None, None]
    snap_aligned = after[jnp.arange(B)[:, None], tp // L]          # [B,K,...]
    if take_aligned:
        return y, final, jnp.where(live, snap_aligned, 0.0)
    states_at = _ssd_states_at(cum, bc, xc, entering, sc,
                               None if sc is None else seg_prev_last,
                               take_pos, L)
    aligned = (take_pos >= 0) & (tp % L == L - 1)
    states_at = jnp.where(aligned[..., None, None, None],
                          jnp.where(live, snap_aligned, 0.0),
                          states_at)
    return y, final, states_at


def _ssd_states_at(cum, bc, xc, entering, sc, seg_prev_last, take_pos, L):
    """Recurrent state *after* arbitrary positions, from chunked pieces.

    state(e) = entering(chunk of e) · exp(cum[e]) + Σ_{m ≤ e, same chunk}
    exp(cum[e] − cum[m]) b_m ⊗ xdt_m — the same decomposition the chunk
    summary uses, evaluated at position e instead of the chunk tail.  With
    `sc` the sums drop cross-segment terms, so state(e) is exactly the
    state of e's own segment.  take_pos [B,K] (-1 → zeros) → [B,K,H,P,N].
    """
    def one(cum_b, bc_b, xc_b, ent_b, sc_b, spl_b, e):
        live = e >= 0
        e = jnp.maximum(e, 0)
        q, l = e // L, e % L
        idx = lambda a: jax.lax.dynamic_index_in_dim(a, q, 0, keepdims=False)
        cum_q, bc_q, xc_q, ent_q = idx(cum_b), idx(bc_b), idx(xc_b), idx(ent_b)
        cl = jax.lax.dynamic_index_in_dim(cum_q, l, 0, keepdims=False)  # [H]
        m = jnp.arange(L) <= l
        keep_ent = jnp.float32(1.0)
        if sc_b is not None:
            sc_q = idx(sc_b)
            sl = jax.lax.dynamic_index_in_dim(sc_q, l, 0, keepdims=False)
            m = m & (sc_q == sl)
            keep_ent = (sl == idx(spl_b)).astype(jnp.float32)
        w = jnp.exp(cl[None, :] - cum_q) * m[:, None]                # [L,H]
        intra = jnp.einsum("ln,lh,lhp->hpn", bc_q, w, xc_q)
        st = ent_q * (keep_ent * jnp.exp(cl))[:, None, None] + intra
        return jnp.where(live, st, 0.0)

    over_k = jax.vmap(one, in_axes=(None, None, None, None, None, None, 0))
    over_b = jax.vmap(over_k, in_axes=(0, 0, 0, 0,
                                       None if sc is None else 0,
                                       None if sc is None else 0, 0))
    return over_b(cum, bc, xc, entering, sc, seg_prev_last, take_pos)


def ssm_forward(p: SsmParams, x, cfg, state=None, conv_state=None,
                segments=None, state_take=None,
                state_take_aligned: bool = False):
    """Full-sequence Mamba2 mixer.  x: [B,S,d] -> (y, (ssm_state, conv_state)).

    Packed prefill: ``segments`` [B,S] resets the recurrence (and the causal
    conv's receptive field) at request boundaries; ``state_take`` [B,K]
    switches the returned carry from the row-final state to per-position
    snapshots — ``(ssm [B,K,H,P,N], conv [B,K,W-1,C])``, the state each
    packed request would have ended with on its own.
    """
    B, S, _ = x.shape
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z, xbc_in, dt = _split_proj(p, x, cfg)
    xbc, new_conv = _causal_conv(xbc_in, p.conv_w, p.conv_b, conv_state,
                                 segments)
    xs = xbc[..., :di].reshape(B, S, H, P)
    bh = xbc[..., di:di + N]
    ch = xbc[..., di + N:]
    dt = jax.nn.softplus(dt + p.dt_bias)
    if state_take is None:
        y, final = ssd_chunked(xs, bh, ch, dt, p.a_log, p.d_skip,
                               cfg.ssm_chunk, state, segments=segments)
        carry = (final, new_conv)
    else:
        y, _, snaps = ssd_chunked(xs, bh, ch, dt, p.a_log, p.d_skip,
                                  cfg.ssm_chunk, state, segments=segments,
                                  take_pos=state_take,
                                  take_aligned=state_take_aligned)
        # conv state = the PRE-conv projection stream, not the conv output
        carry = (snaps, _conv_states_at(xbc_in, segments, state_take,
                                        p.conv_w.shape[0]))
    y = (y.reshape(B, S, di) * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return y @ p.w_out, carry


def _conv_states_at(xbc, segments, take_pos, W):
    """Conv tail snapshots: the last W-1 *same-segment* inputs ending at each
    take position (zeros where the segment is shorter), i.e. exactly the
    ``conv_state`` a solo run of that segment would have left behind.
    xbc [B,S,C], take_pos [B,K] -> [B,K,W-1,C]."""
    B, S, C = xbc.shape
    e = jnp.maximum(take_pos, 0)                                 # [B,K]
    idx = e[:, :, None] - (W - 2) + jnp.arange(W - 1)[None, None]  # [B,K,W-1]
    ok = (idx >= 0) & (take_pos[:, :, None] >= 0)
    if segments is not None:
        seg_e = jnp.take_along_axis(segments, e, axis=1)         # [B,K]
        seg_i = jnp.take_along_axis(
            segments[:, None, :].repeat(e.shape[1], 1),
            jnp.clip(idx, 0, S - 1), axis=2)                     # [B,K,W-1]
        ok = ok & (seg_i == seg_e[:, :, None])
    gath = jnp.take_along_axis(
        xbc[:, None].repeat(e.shape[1], 1),                      # [B,K,S,C]
        jnp.clip(idx, 0, S - 1)[..., None], axis=2)              # [B,K,W-1,C]
    return jnp.where(ok[..., None], gath, 0)


def ssm_decode_step(p: SsmParams, x, cfg, state, conv_state):
    """One-token recurrent step.  x: [B,1,d]; state: [B,H,P,N]; conv: [B,W-1,C]."""
    B = x.shape[0]
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z, xbc, dt = _split_proj(p, x, cfg)
    xbc, new_conv = _causal_conv(xbc, p.conv_w, p.conv_b, conv_state)
    xs = xbc[:, 0, :di].reshape(B, H, P).astype(jnp.float32)
    bh = xbc[:, 0, di:di + N].astype(jnp.float32)                  # [B,N]
    ch = xbc[:, 0, di + N:].astype(jnp.float32)
    dt1 = jax.nn.softplus(dt[:, 0] + p.dt_bias)                    # [B,H]
    A = -jnp.exp(p.a_log.astype(jnp.float32))
    decay = jnp.exp(dt1 * A)                                       # [B,H]
    upd = jnp.einsum("bhp,bn->bhpn", xs * dt1[..., None], bh)
    new_state = state * decay[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", new_state, ch)
    y = y + xs * p.d_skip[None, :, None]
    y = (y.reshape(B, 1, di) * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return y @ p.w_out, (new_state, new_conv)
