"""Mesh-aware sharding hints usable from model code.

Model layers sometimes produce tensors whose sharding XLA's propagation
loses (reshapes that split a sharded dim, scatters into fresh buffers).
`hint` re-pins them to the ambient mesh — and is a no-op when no mesh is
active (CPU tests/engine) or when a dim doesn't divide, so model code stays
mesh-agnostic.

Roles: "model" (tensor-parallel axis), "batch" (('pod','data') or ('data',)),
"data" (the fsdp axis alone).
"""
from __future__ import annotations

import math

import jax
from jax.sharding import PartitionSpec as P


def _ambient_mesh():
    try:
        import jax._src.mesh as mesh_lib
        env = mesh_lib.thread_resources.env.physical_mesh
        if env is not None and env.axis_names:
            return env
    except Exception:      # noqa: BLE001
        pass
    return None


def hint(x, roles: dict):
    """roles: {dim_index: 'model'|'batch'|'data'}.  Best-effort constraint."""
    mesh = _ambient_mesh()
    if mesh is None or x is None:
        return x
    names = mesh.axis_names
    spec = [None] * x.ndim
    for dim, role in roles.items():
        size = x.shape[dim]
        if role == "model" and "model" in names:
            if size % mesh.shape["model"] == 0:
                spec[dim] = "model"
        elif role == "data" and "data" in names:
            if size % mesh.shape["data"] == 0:
                spec[dim] = "data"
        elif role == "batch":
            axes = tuple(a for a in ("pod", "data") if a in names)
            total = math.prod(mesh.shape[a] for a in axes) if axes else 0
            if axes and total and size % total == 0:
                spec[dim] = axes if len(axes) > 1 else axes[0]
    if not any(spec):
        return x
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:      # noqa: BLE001
        return x
