"""GQA attention: full (train/prefill) and budgeted-cache decode paths.

Variants covered (all static config):
  * grouped-query attention with arbitrary q/kv head ratio
  * RoPE / M-RoPE (positions are explicit so cache eviction never perturbs them)
  * qk RMS-norm (qwen3), attention-logit tanh softcap (gemma2)
  * per-layer sliding windows (mistral/mixtral SWA, gemma2 local/global) — the
    window width is *data* (a scanned scalar), so one scan body serves
    alternating-layout models.

The decode path attends over a *slot cache*: a fixed [B, S_slots, Hkv, D]
arena whose slots carry their original token positions (`slot_pos`, -1 =
empty).  It returns per-slot attention mass so H2O can accumulate scores
without a second pass.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import rope as rope_lib
from repro.models.norms import rms_head_norm
from repro.models.shard_hints import hint

GLOBAL_WINDOW = 1 << 30  # "no window" sentinel (fits int32)


class AttnParams(NamedTuple):
    wq: jnp.ndarray   # [d, H*hd]
    wk: jnp.ndarray   # [d, Hkv*hd]
    wv: jnp.ndarray   # [d, Hkv*hd]
    wo: jnp.ndarray   # [H*hd, d]
    q_norm: jnp.ndarray  # [hd] (ones when unused)
    k_norm: jnp.ndarray  # [hd]


def init_attn(key, cfg) -> AttnParams:
    pd = jnp.dtype(cfg.param_dtype)
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    so = 1.0 / math.sqrt(qd)
    return AttnParams(
        wq=(jax.random.normal(k1, (d, qd), jnp.float32) * s).astype(pd),
        wk=(jax.random.normal(k2, (d, kvd), jnp.float32) * s).astype(pd),
        wv=(jax.random.normal(k3, (d, kvd), jnp.float32) * s).astype(pd),
        wo=(jax.random.normal(k4, (qd, d), jnp.float32) * so).astype(pd),
        q_norm=jnp.ones((cfg.hd,), pd),
        k_norm=jnp.ones((cfg.hd,), pd),
    )


def _project_qkv(p: AttnParams, x, positions, cfg):
    """x: [B,S,d] -> q [B,S,H,hd], k/v [B,S,Hkv,hd] (RoPE applied)."""
    B, S, _ = x.shape
    hd = cfg.hd
    # reshapes that split the (heads*hd) projection dim lose the model-axis
    # sharding (XLA "involuntary full rematerialization" -> replicated
    # attention compute); re-pin heads to the model axis (§Perf A4/B1)
    q = hint((x @ p.wq).reshape(B, S, cfg.n_heads, hd),
             {0: "batch", 2: "model"})
    k = hint((x @ p.wk).reshape(B, S, cfg.n_kv_heads, hd),
             {0: "batch", 2: "model"})
    v = hint((x @ p.wv).reshape(B, S, cfg.n_kv_heads, hd),
             {0: "batch", 2: "model"})
    if cfg.use_qk_norm:
        q = rms_head_norm(p.q_norm, q, cfg.norm_eps)
        k = rms_head_norm(p.k_norm, k, cfg.norm_eps)
    if cfg.mrope_sections is not None:
        pos3 = positions if positions.ndim == 3 else jnp.repeat(positions[..., None], 3, -1)
        q = rope_lib.apply_mrope(q, pos3, cfg.rope_theta, cfg.mrope_sections)
        k = rope_lib.apply_mrope(k, pos3, cfg.rope_theta, cfg.mrope_sections)
    else:
        pos1 = positions if positions.ndim == 2 else positions[..., 0]
        q = rope_lib.apply_rope(q, pos1, cfg.rope_theta)
        k = rope_lib.apply_rope(k, pos1, cfg.rope_theta)
    return q, k, v


def _softcap(x, cap):
    return jnp.tanh(x / cap) * cap if cap else x


FLASH_THRESHOLD = 1024   # above this seq len, use the blockwise flash path
FLASH_BLOCK = 1024


def full_attention(
    p: AttnParams,
    x: jnp.ndarray,                 # [B, S, d]
    positions: jnp.ndarray,         # [B, S] or [B, S, 3]
    cfg,
    window: jnp.ndarray | int = GLOBAL_WINDOW,  # scalar, data not shape
    valid: Optional[jnp.ndarray] = None,        # [B, S] bool (padding mask)
    return_colsums: bool = False,   # H2O: per-key total attention mass
    segments: Optional[jnp.ndarray] = None,     # [B, S] int32 packed seg ids
    ctx=None,                       # (k_ctx [B,C,Hkv,hd], v_ctx, pos_ctx [B,C])
):
    """Causal (+sliding window) attention.

    Returns (out [B,S,d], k, v, colsums [B,Hkv,S] | None).
    Long sequences take a blockwise online-softmax (flash) path so peak
    activation memory is O(S * block) instead of O(S^2).

    ``segments`` turns the causal mask block-diagonal for packed prefill
    (DESIGN.md §5): a token attends only within its own segment id, so
    several requests concatenated into one row (positions reset per
    segment) never see each other.  H2O column sums from queries with no
    visible key (the tail padding of a packed row) are dropped rather than
    softmax-uniform garbage.

    ``ctx`` is the carried-prefix hook (DESIGN.md §5): already-RoPE'd keys
    and values of earlier prompt tokens, attended as EXTRA keys ahead of
    this call's own tokens (whose ``positions`` then start past the
    prefix).  Two callers share it — prefix-cache admission gathers a
    cached prompt's pages, and chunked prefill passes the staging buffer
    of chunks landed so far (`serving/prefill.py:chunk_prefill`), which is
    why a mid-stream chunk sees exactly the keys the monolithic prefill
    would have at the same position.  Context entries with ``pos_ctx = -1``
    are masked out exactly like empty cache slots.  With ``ctx`` the
    returned colsums cover the concatenated key axis [B, Hkv, C+S].
    """
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, x, positions, cfg)
    G = cfg.n_heads // cfg.n_kv_heads
    qf = q.reshape(B, S, cfg.n_kv_heads, G, cfg.hd).astype(jnp.float32)
    pos1 = positions if positions.ndim == 2 else positions[..., 0]

    if ctx is not None:
        # ctx prefill batches are suffix-sized (<= max_prompt_len): the
        # quadratic naive path is the right cost model, and it concatenates
        # the gathered prefix keys without a blockwise mask rework
        out, colsums = _naive_attention(qf, k, v, pos1, cfg, window, valid,
                                        return_colsums, segments, ctx=ctx)
    elif S > FLASH_THRESHOLD and S % FLASH_BLOCK == 0:
        out, colsums = _flash_attention(qf, k, v, pos1, cfg, window, valid,
                                        return_colsums, segments=segments)
    else:
        out, colsums = _naive_attention(qf, k, v, pos1, cfg, window, valid,
                                        return_colsums, segments)
    out = out.reshape(B, S, cfg.q_dim).astype(x.dtype)
    return out @ p.wo, k, v, colsums


def _mask(pos_q, pos_k, window, valid_k, seg_q=None, seg_k=None):
    """pos_q [B,Sq], pos_k [B,Sk] -> bool [B,1,Sq,1,Sk]."""
    qp = pos_q[:, None, :, None, None]
    kp = pos_k[:, None, None, None, :]
    m = (kp <= qp) & (kp > qp - window)
    if valid_k is not None:
        m &= valid_k[:, None, None, None, :]
    if seg_q is not None:
        m &= seg_q[:, None, :, None, None] == seg_k[:, None, None, None, :]
    return m


def _naive_attention(qf, k, v, pos1, cfg, window, valid, return_colsums,
                     segments=None, ctx=None):
    pos_k, valid_k, seg_k = pos1, valid, segments
    if ctx is not None:
        k_ctx, v_ctx, pos_ctx = ctx
        assert segments is None, "prefix ctx and packed prefill are exclusive"
        k = jnp.concatenate([k_ctx.astype(k.dtype), k], axis=1)
        v = jnp.concatenate([v_ctx.astype(v.dtype), v], axis=1)
        pos_k = jnp.concatenate([pos_ctx, pos1], axis=1)
        B, S = pos1.shape
        valid_q = jnp.ones((B, S), bool) if valid is None else valid
        valid_k = jnp.concatenate([pos_ctx >= 0, valid_q], axis=1)
    scores = jnp.einsum("bsngd,btnd->bnsgt", qf, k.astype(jnp.float32))
    scores = scores * (1.0 / math.sqrt(cfg.hd))
    scores = _softcap(scores, cfg.attn_softcap)
    mask = _mask(pos1, pos_k, window, valid_k, segments, seg_k)
    scores = jnp.where(mask, scores, -1e30)   # [B,1,Sq,1,Sk] broadcasts
    probs = jax.nn.softmax(scores, axis=-1)
    colsums = None
    if return_colsums:
        # all-masked queries (packed tail padding) softmax to uniform junk;
        # zeroing through the mask keeps every real contribution bit-exact
        # (exp(-1e30 - m) underflows to 0.0) and drops only the junk rows
        colsums = jnp.where(mask, probs, 0.0).sum(axis=(2, 3))   # [B,n,Sk]
    out = jnp.einsum("bnsgt,btnd->bsngd", probs, v.astype(jnp.float32))
    return out, colsums


def _flash_attention(qf, k, v, pos1, cfg, window, valid, return_colsums,
                     segments=None, block: int = FLASH_BLOCK):
    """Online-softmax over key blocks (lax.scan).  Peak extra memory is
    O(B * heads * S * block) fp32 — the pure-JAX analogue of the Pallas
    swa_prefill kernel (kernels/swa_prefill.py is the TPU version)."""
    B, S, n, G, hd = qf.shape
    nb = S // block
    scale = 1.0 / math.sqrt(hd)
    kb = k.astype(jnp.float32).reshape(B, nb, block, n, hd).transpose(1, 0, 2, 3, 4)
    vb = v.astype(jnp.float32).reshape(B, nb, block, n, hd).transpose(1, 0, 2, 3, 4)
    pb = pos1.reshape(B, nb, block).transpose(1, 0, 2)
    valb = (valid.reshape(B, nb, block).transpose(1, 0, 2)
            if valid is not None else jnp.ones((nb, B, block), bool))
    # the segment-id block stream exists only for packed prefill — the
    # common (unpacked) path carries no dead scan input
    segb = (segments.reshape(B, nb, block).transpose(1, 0, 2),) \
        if segments is not None else ()

    def scores_fn(k_blk, p_blk, v_blk_valid, rest):
        s = jnp.einsum("bsngd,btnd->bnsgt", qf, k_blk) * scale
        s = _softcap(s, cfg.attn_softcap)
        m = _mask(pos1, p_blk, window, v_blk_valid,
                  segments, rest[0] if rest else None)
        return jnp.where(m, s, -1e30), m

    def step(carry, blk):
        m, l, acc = carry
        k_blk, v_blk, p_blk, val_blk, *rest = blk
        s, _ = scores_fn(k_blk, p_blk, val_blk, rest)          # [B,n,S,G,block]
        m_new = jnp.maximum(m, s.max(-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * corr + p.sum(-1)
        acc = acc * corr[..., None] + jnp.einsum("bnsgt,btnd->bnsgd", p, v_blk)
        return (m_new, l, acc), None

    m0 = jnp.full((B, n, S, G), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, n, S, G), jnp.float32)
    a0 = jnp.zeros((B, n, S, G, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                  (kb, vb, pb, valb) + segb)
    lsafe = jnp.where(l > 0, l, 1.0)
    out = (acc / lsafe[..., None]).transpose(0, 2, 1, 3, 4)   # [B,S,n,G,hd]

    colsums = None
    if return_colsums:
        inv = (1.0 / lsafe)[..., None]                         # [B,n,S,G,1]

        def col_step(_, blk):
            k_blk, p_blk, val_blk, *rest = blk
            s, msk = scores_fn(k_blk, p_blk, val_blk, rest)
            # mask-weighted like the naive branch: all-masked queries (m =
            # -1e30 -> exp(0) = 1 junk) contribute nothing
            p = jnp.where(msk, jnp.exp(s - m[..., None]) * inv, 0.0)
            return None, p.sum(axis=(2, 3))                    # [B,n,block]

        _, cols = jax.lax.scan(col_step, None, (kb, pb, valb) + segb)
        colsums = cols.transpose(1, 2, 0, 3).reshape(B, n, S)
    return out, colsums


class DecodeAttnOut(NamedTuple):
    out: jnp.ndarray          # [B, 1, d]
    slot_probs: jnp.ndarray   # [B, Hkv, S_slots+1] attention mass (mean over q-group)
    k_new: jnp.ndarray        # [B, 1, Hkv, hd] (RoPE'd)
    v_new: jnp.ndarray


def decode_attention(
    p: AttnParams,
    x: jnp.ndarray,            # [B, 1, d] current token's hidden state
    t: jnp.ndarray,            # [B] logical position of the current token
    cache_k: jnp.ndarray,      # [B, S_slots, Hkv, hd] (already RoPE'd at write)
    cache_v: jnp.ndarray,
    slot_pos: jnp.ndarray,     # [B, S_slots] original positions, -1 = empty
    cfg,
    window: jnp.ndarray | int = GLOBAL_WINDOW,
    use_flash: bool = False,   # Pallas split-S flash-decode kernel path
) -> DecodeAttnOut:
    """One-token attention over the compressed cache + the current token.

    The new token's KV is attended in-place (appended logically as slot S);
    the caller decides which physical slot it overwrites afterwards.

    With ``use_flash`` the arena read runs through the Pallas flash-decode
    kernel (`kernels/flash_decode`): split-S partials + combine epilogue,
    with the new token's self-attention term folded in as one extra partial
    (``extra_kv``).  Masking (validity/causality/window) and the H2O slot
    statistic match this dense path; interpret mode is used off-TPU.
    """
    B, S = slot_pos.shape
    pos = t[:, None] if t.ndim == 1 else t          # [B,1] (or [B,1,3] mrope)
    q, k_new, v_new = _project_qkv(p, x, pos, cfg)
    G = cfg.n_heads // cfg.n_kv_heads
    qf = q.reshape(B, cfg.n_kv_heads, G, cfg.hd).astype(jnp.float32)
    t1 = (t if t.ndim == 1 else t[..., 0]).reshape(B)

    if use_flash:
        from repro.kernels.flash_decode.ops import flash_decode
        out_f, cols = flash_decode(
            qf, cache_k, cache_v, slot_pos, t1, window,
            softcap=cfg.attn_softcap, extra_kv=(k_new, v_new),
            return_colsums=True,
            interpret=jax.default_backend() != "tpu")
        out = out_f.reshape(B, 1, cfg.q_dim).astype(x.dtype) @ p.wo
        # kernel colsums sum over the q-group; the H2O statistic here is the
        # group mean, matching the dense branch below
        return DecodeAttnOut(out, cols / G, k_new, v_new)

    # The arena is read exactly once for K and once for V, in its own bf16
    # dtype (an `astype(f32)` here materializes an f32 copy of the WHOLE
    # arena per layer — 3x the decode HBM traffic, §Perf D3); accumulation
    # happens in f32 via preferred_element_type, matching the MXU.  Only the
    # SCORES (S+1 scalars/head) are concatenated with the new token's — a
    # cache-sized concatenate would copy the arena again (§Perf D2).
    scale = 1.0 / math.sqrt(cfg.hd)
    qc = qf.astype(cache_k.dtype)
    s_cache = jnp.einsum("bngd,btnd->bngt", qc, cache_k,
                         preferred_element_type=jnp.float32) * scale
    s_new = jnp.einsum("bngd,btnd->bngt", qf,
                       k_new.astype(jnp.float32)) * scale       # [B,n,G,1]
    scores = _softcap(jnp.concatenate([s_cache, s_new], -1), cfg.attn_softcap)
    mask_cache = (slot_pos >= 0) & (slot_pos <= t1[:, None]) \
        & (slot_pos > t1[:, None] - window)
    mask = jnp.concatenate(
        [mask_cache, jnp.ones((B, 1), bool)], axis=1)           # [B,S+1]
    scores = jnp.where(mask[:, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)                     # [B,n,G,S+1]
    out = jnp.einsum("bngt,btnd->bngd",
                     probs[..., :S].astype(cache_v.dtype), cache_v,
                     preferred_element_type=jnp.float32) \
        + probs[..., S:] * v_new[:, 0, :, None, :].astype(jnp.float32)
    out = out.reshape(B, 1, cfg.q_dim).astype(x.dtype) @ p.wo
    return DecodeAttnOut(out, probs.mean(axis=2), k_new, v_new)


def paged_decode_attention(
    p: AttnParams,
    x: jnp.ndarray,            # [B, 1, d]
    t: jnp.ndarray,            # [B]
    pool_k: jnp.ndarray,       # [N_pages, psize, Hkv, hd] global page pool
    pool_v: jnp.ndarray,
    page_tbl: jnp.ndarray,     # [B, npp] int32 page ids (0 = null page)
    slot_pos: jnp.ndarray,     # [B, S_slots] original positions, -1 = empty
    cfg,
    window: jnp.ndarray | int = GLOBAL_WINDOW,
    use_flash: bool = False,
) -> DecodeAttnOut:
    """`decode_attention` over a paged arena (core/paging.py).

    One gather materializes the row set's arena view from the pool —
    ``pool[page_tbl]`` is a traced-index gather, so page assignments are
    data and decode never retraces when rows land on different pages — and
    the result feeds BOTH the dense einsum and the Pallas flash-decode
    kernel unchanged.  The last page of a row may extend past the tier's
    slot count (budgets need not be page multiples); the tail is sliced
    off before attention, mirroring `paging.gather_layer_pages`.
    """
    B, S = slot_pos.shape
    npp = page_tbl.shape[-1]
    psize = pool_k.shape[1]

    def g(a):
        return a[page_tbl].reshape(B, npp * psize, *a.shape[2:])[:, :S]

    return decode_attention(p, x, t, g(pool_k), g(pool_v), slot_pos, cfg,
                            window, use_flash=use_flash)
