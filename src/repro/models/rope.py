"""Rotary position embeddings: standard RoPE and Qwen2-VL M-RoPE.

All functions take explicit integer `positions` so the decode path (one new
token at logical position `t` against a compressed cache whose slots remember
their own original positions) stays exact — eviction never perturbs RoPE.
"""
from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """[head_dim/2] inverse frequencies (fp32)."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [..., S, H, D]; positions: broadcastable to [..., S] (int32)."""
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)                       # [half]
    ang = positions[..., None].astype(jnp.float32) * freqs       # [..., S, half]
    cos = jnp.cos(ang)[..., None, :]                             # [..., S, 1, half]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jnp.ndarray,
    positions_3d: jnp.ndarray,
    theta: float,
    sections: tuple,
) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE.

    x: [..., S, H, D]; positions_3d: [..., S, 3] (temporal, height, width ids).
    `sections` splits the head_dim/2 frequency bands among the three id streams;
    for pure-text tokens the three ids are identical, reducing to standard RoPE.
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half
    freqs = rope_freqs(x.shape[-1], theta)                       # [half]
    # Per-band position id: band j uses positions_3d[..., axis(j)].
    axis_of_band = jnp.concatenate([
        jnp.full((sections[0],), 0), jnp.full((sections[1],), 1),
        jnp.full((sections[2],), 2),
    ]).astype(jnp.int32)                                          # [half]
    pos = jnp.take_along_axis(
        positions_3d.astype(jnp.float32),
        jnp.broadcast_to(axis_of_band, positions_3d.shape[:-1] + (half,)).astype(jnp.int32),
        axis=-1,
    )                                                             # [..., S, half]
    ang = pos * freqs
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1)
    return out.astype(x.dtype)
