"""Feed-forward blocks: SwiGLU (llama-family) and GELU (musicgen-style)."""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp


class MlpParams(NamedTuple):
    w_gate: jnp.ndarray  # [d, f]  (unused/zeros for gelu)
    w_up: jnp.ndarray    # [d, f]
    w_down: jnp.ndarray  # [f, d]


def init_mlp(key, cfg) -> MlpParams:
    pd = jnp.dtype(cfg.param_dtype)
    d, f = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    s, so = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    gate = (jax.random.normal(k1, (d, f), jnp.float32) * s).astype(pd)
    if cfg.mlp_type == "gelu":
        gate = jnp.zeros((d, f), pd)  # keeps pytree uniform across archs
    return MlpParams(
        w_gate=gate,
        w_up=(jax.random.normal(k2, (d, f), jnp.float32) * s).astype(pd),
        w_down=(jax.random.normal(k3, (f, d), jnp.float32) * so).astype(pd),
    )


def apply_mlp(p: MlpParams, x: jnp.ndarray, cfg) -> jnp.ndarray:
    if cfg.mlp_type == "swiglu":
        return (jax.nn.silu(x @ p.w_gate) * (x @ p.w_up)) @ p.w_down
    if cfg.mlp_type == "gelu":
        return jax.nn.gelu(x @ p.w_up) @ p.w_down
    raise ValueError(cfg.mlp_type)
