"""jit'd wrapper: pad -> kernel partials -> combine epilogue (+H2O pass)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_decode import kernel as K


def _pad_arena(k, v, pos, block_s):
    S = k.shape[1]
    pad = (-S) % block_s
    if pad == 0:
        return k, v, pos, S
    zk = jnp.zeros(k.shape[:1] + (pad,) + k.shape[2:], k.dtype)
    pp = jnp.full(pos.shape[:1] + (pad,), -1, pos.dtype)
    return (jnp.concatenate([k, zk], 1), jnp.concatenate([v, zk], 1),
            jnp.concatenate([pos, pp], 1), S)


def flash_decode(q, k, v, pos, t, window, *, block_s: int = 512,
                 softcap=None, return_colsums: bool = False,
                 interpret: bool = True):
    """Budgeted decode attention via the Pallas split-S kernel.

    q [B,Hkv,G,hd], k/v [B,S,Hkv,hd], pos [B,S], t [B], window scalar.
    Returns (out [B,Hkv,G,hd] f32, colsums [B,Hkv,S] f32 | None).
    """
    S_orig = k.shape[1]
    block_s = min(block_s, max(64, 1 << (S_orig - 1).bit_length()))
    k, v, pos, _ = _pad_arena(k, v, pos, block_s)

    m_p, l_p, acc_p = K.flash_decode_partials(
        q, k, v, pos, t, window, block_s=block_s, softcap=softcap,
        interpret=interpret)
    # ---- combine split-S partials (tiny epilogue) ----------------------------
    m = jnp.max(m_p, axis=2)                              # [B,Hkv,G]
    w = jnp.exp(m_p - m[:, :, None])                      # [B,Hkv,nS,G]
    l = jnp.sum(l_p * w, axis=2)                          # [B,Hkv,G]
    acc = jnp.sum(acc_p * w[..., None], axis=2)           # [B,Hkv,G,hd]
    linv = 1.0 / jnp.clip(l, 1e-30)
    out = acc * linv[..., None]

    colsums = None
    if return_colsums:
        colsums = K.flash_decode_colsums(
            q, k, pos, t, window, m, linv, block_s=block_s, softcap=softcap,
            interpret=interpret)[:, :, :S_orig]
    return out, colsums
