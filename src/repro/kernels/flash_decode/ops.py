"""jit'd wrapper: pad -> kernel partials -> combine epilogue (+H2O pass).

The combine epilogue also folds in the current decode token's self-attention
term (``extra_kv``): the new token is one more split-S partial with a single
slot, so the serving hot path (`models/attention.decode_attention` with
``use_flash=True``) gets a jointly-normalized softmax over cache + new token
without a cache-sized concatenate.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.kernels.flash_decode import kernel as K


def _pad_arena(k, v, pos, block_s):
    S = k.shape[1]
    pad = (-S) % block_s
    if pad == 0:
        return k, v, pos, S
    zk = jnp.zeros(k.shape[:1] + (pad,) + k.shape[2:], k.dtype)
    pp = jnp.full(pos.shape[:1] + (pad,), -1, pos.dtype)
    return (jnp.concatenate([k, zk], 1), jnp.concatenate([v, zk], 1),
            jnp.concatenate([pos, pp], 1), S)


def flash_decode(q, k, v, pos, t, window, *, block_s: int = 512,
                 softcap=None, extra_kv=None, return_colsums: bool = False,
                 interpret: bool = True):
    """Budgeted decode attention via the Pallas split-S kernel.

    q [B,Hkv,G,hd], k/v [B,S,Hkv,hd], pos [B,S], t [B], window scalar.
    ``extra_kv`` (k_new, v_new) [B,1,Hkv,hd] appends the current token as a
    jointly-softmaxed extra slot (the serving decode step's self-attention
    term).  Returns (out [B,Hkv,G,hd] f32, colsums f32 | None); colsums are
    [B,Hkv,S] — or [B,Hkv,S+1] with ``extra_kv``, the last column being the
    new token's mass (summed over the q-group, matching the ref oracle).
    """
    S_orig = k.shape[1]
    block_s = min(block_s, max(64, 1 << (S_orig - 1).bit_length()))
    k, v, pos, _ = _pad_arena(k, v, pos, block_s)

    m_p, l_p, acc_p = K.flash_decode_partials(
        q, k, v, pos, t, window, block_s=block_s, softcap=softcap,
        interpret=interpret)
    s_new = None
    if extra_kv is not None:
        # the new token is one more partial: a single always-valid slot with
        # m = its score, l = 1, acc = v_new (broadcast over the q-group)
        k_new, v_new = extra_kv
        scale = 1.0 / math.sqrt(q.shape[-1])
        s_new = jnp.einsum("bngd,bnd->bng", q.astype(jnp.float32),
                           k_new[:, 0].astype(jnp.float32)) * scale
        if softcap:
            s_new = jnp.tanh(s_new / softcap) * softcap
        m_p = jnp.concatenate([m_p, s_new[:, :, None, :]], axis=2)
        l_p = jnp.concatenate([l_p, jnp.ones_like(s_new)[:, :, None, :]],
                              axis=2)
        v_b = jnp.broadcast_to(
            v_new[:, 0].astype(jnp.float32)[:, :, None, None, :],
            acc_p.shape[:2] + (1,) + acc_p.shape[3:])
        acc_p = jnp.concatenate([acc_p, v_b], axis=2)
    # ---- combine split-S partials (tiny epilogue) ----------------------------
    m = jnp.max(m_p, axis=2)                              # [B,Hkv,G]
    w = jnp.exp(m_p - m[:, :, None])                      # [B,Hkv,nS,G]
    l = jnp.sum(l_p * w, axis=2)                          # [B,Hkv,G]
    acc = jnp.sum(acc_p * w[..., None], axis=2)           # [B,Hkv,G,hd]
    linv = 1.0 / jnp.clip(l, 1e-30)
    out = acc * linv[..., None]

    colsums = None
    if return_colsums:
        colsums = K.flash_decode_colsums(
            q, k, pos, t, window, m, linv, block_s=block_s, softcap=softcap,
            interpret=interpret)[:, :, :S_orig]
        if s_new is not None:
            col_new = jnp.sum(jnp.exp(s_new - m) * linv, axis=-1)  # [B,Hkv]
            colsums = jnp.concatenate([colsums, col_new[..., None]], axis=-1)
    return out, colsums
