"""Pure-jnp oracle for the budgeted flash-decode kernel.

One query token per (batch, kv-head, q-group) attending over a slot arena
with position-based validity/window masking — the inner loop of
SqueezeAttention's decode step.  Returns the attention output AND the
per-slot probability mass (H2O statistic) so the fused kernel has an exact
reference for both.
"""
from __future__ import annotations

import jax.numpy as jnp


def decode_attention_ref(
    q: jnp.ndarray,        # [B, Hkv, G, hd]
    k: jnp.ndarray,        # [B, S, Hkv, hd]
    v: jnp.ndarray,        # [B, S, Hkv, hd]
    pos: jnp.ndarray,      # [B, S] slot positions (-1 = empty)
    t: jnp.ndarray,        # [B] current token position
    window,                # int or scalar array
    softcap: float | None = None,
):
    """Returns (out [B,Hkv,G,hd] f32, slot_probs [B,Hkv,S] f32)."""
    B, S, Hkv, hd = k.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    s = jnp.einsum("bngd,bsnd->bngs", qf, kf) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    tb = t[:, None].astype(jnp.int32)
    mask = (pos >= 0) & (pos <= tb) & (pos > tb - window)          # [B, S]
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    probs = jnp.exp(s - s.max(-1, keepdims=True))
    probs = jnp.where(mask[:, None, None, :], probs, 0.0)
    denom = jnp.clip(probs.sum(-1, keepdims=True), 1e-30)
    probs = probs / denom
    out = jnp.einsum("bngs,bsnd->bngd", probs, v.astype(jnp.float32))
    return out, probs.sum(axis=2)            # slot mass summed over q-group
