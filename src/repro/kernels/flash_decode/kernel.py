"""Pallas TPU kernel: split-S budgeted decode attention (flash-decode).

TPU adaptation of the paper's decode hot-spot (DESIGN.md §3): each decode
step streams the whole KV arena from HBM; SqueezeAttention shrinks that
arena per layer, and this kernel makes the remaining reads bandwidth-
optimal:

  * grid (B, Hkv, S/block) — slot blocks are independent partials
    (split-K / flash-decode style), so the sequential-grid constraint on
    TPU costs nothing and long arenas parallelize across the grid.
  * K/V blocks are tiled into VMEM as [block_s, hd] with hd padded to the
    128-lane register shape; q [G, hd] stays resident.
  * position-based masking (validity + causality + sliding window) happens
    on the block in VMEM — evicted/empty slots never reach the MXU.
  * partials (m, l, acc) are combined by a tiny jnp epilogue in ops.py,
    which also folds in the current token's self-attention term.

The H2O statistic (per-slot probability mass) is produced by a second
1-read pass (`colsum_kernel`) given the combined (m, l) — K is re-read but
V is not, matching the fused-statistic design in core/cache.py.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, pos_ref, t_ref, w_ref,
                   m_ref, l_ref, acc_ref, *, scale: float, softcap: float):
    q = q_ref[0, 0].astype(jnp.float32)                 # [G, hd]
    k = k_ref[0, :, 0, :].astype(jnp.float32)           # [bs, hd]
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    pos = pos_ref[0]                                     # [bs]
    t = t_ref[0]
    w = w_ref[0]
    mask = (pos >= 0) & (pos <= t) & (pos > t - w)       # [bs]
    s = jnp.where(mask[None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)                              # [G]
    p = jnp.exp(s - m[:, None])
    p = jnp.where(mask[None, :], p, 0.0)
    l = jnp.sum(p, axis=-1)                              # [G]
    acc = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    m_ref[0, 0, 0] = m
    l_ref[0, 0, 0] = l
    acc_ref[0, 0, 0] = acc


def flash_decode_partials(q, k, v, pos, t, window, *, block_s: int = 512,
                          softcap: float | None = None,
                          interpret: bool = True):
    """q [B,Hkv,G,hd]; k/v [B,S,Hkv,hd]; pos [B,S]; t [B]; window scalar array.

    Returns split-S partials m,l [B,Hkv,nS,G] and acc [B,Hkv,nS,G,hd] (f32).
    S must be a multiple of block_s (ops.py pads with empty slots).
    """
    B, Hkv, G, hd = q.shape
    S = k.shape[1]
    assert S % block_s == 0, (S, block_s)
    nS = S // block_s
    scale = 1.0 / math.sqrt(hd)
    w_arr = jnp.broadcast_to(jnp.asarray(window, jnp.int32), (1,))

    kern = functools.partial(_decode_kernel, scale=scale,
                             softcap=float(softcap or 0.0))
    grid = (B, Hkv, nS)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, n, sb: (b, n, 0, 0)),
            pl.BlockSpec((1, block_s, 1, hd), lambda b, n, sb: (b, sb, n, 0)),
            pl.BlockSpec((1, block_s, 1, hd), lambda b, n, sb: (b, sb, n, 0)),
            pl.BlockSpec((1, block_s), lambda b, n, sb: (b, sb)),
            pl.BlockSpec((1,), lambda b, n, sb: (b,)),
            pl.BlockSpec((1,), lambda b, n, sb: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, G), lambda b, n, sb: (b, n, sb, 0)),
            pl.BlockSpec((1, 1, 1, G), lambda b, n, sb: (b, n, sb, 0)),
            pl.BlockSpec((1, 1, 1, G, hd), lambda b, n, sb: (b, n, sb, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, nS, G), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, nS, G), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, nS, G, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, pos, t, w_arr)


def _colsum_kernel(q_ref, k_ref, pos_ref, t_ref, w_ref, m_ref, l_ref,
                   out_ref, *, scale: float, softcap: float):
    q = q_ref[0, 0].astype(jnp.float32)                  # [G, hd]
    k = k_ref[0, :, 0, :].astype(jnp.float32)            # [bs, hd]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    pos = pos_ref[0]
    t = t_ref[0]
    w = w_ref[0]
    mask = (pos >= 0) & (pos <= t) & (pos > t - w)
    m = m_ref[0, 0]                                      # [G] combined max
    linv = l_ref[0, 0]                                   # [G] 1/l combined
    p = jnp.exp(s - m[:, None]) * linv[:, None]
    p = jnp.where(mask[None, :], p, 0.0)
    out_ref[0, 0] = jnp.sum(p, axis=0)                   # [bs] over q-group


def flash_decode_colsums(q, k, pos, t, window, m_comb, l_comb, *,
                         block_s: int = 512, softcap: float | None = None,
                         interpret: bool = True):
    """Second pass: per-slot probability mass given combined (m, 1/l).

    m_comb/l_comb: [B, Hkv, G] (l_comb already inverted).
    Returns [B, Hkv, S] f32.
    """
    B, Hkv, G, hd = q.shape
    S = k.shape[1]
    nS = S // block_s
    scale = 1.0 / math.sqrt(hd)
    w_arr = jnp.broadcast_to(jnp.asarray(window, jnp.int32), (1,))
    kern = functools.partial(_colsum_kernel, scale=scale,
                             softcap=float(softcap or 0.0))
    return pl.pallas_call(
        kern,
        grid=(B, Hkv, nS),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, n, sb: (b, n, 0, 0)),
            pl.BlockSpec((1, block_s, 1, hd), lambda b, n, sb: (b, sb, n, 0)),
            pl.BlockSpec((1, block_s), lambda b, n, sb: (b, sb)),
            pl.BlockSpec((1,), lambda b, n, sb: (b,)),
            pl.BlockSpec((1,), lambda b, n, sb: (0,)),
            pl.BlockSpec((1, 1, G), lambda b, n, sb: (b, n, 0)),
            pl.BlockSpec((1, 1, G), lambda b, n, sb: (b, n, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_s), lambda b, n, sb: (b, n, sb)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, S), jnp.float32),
        interpret=interpret,
    )(q, k, pos, t, w_arr, m_comb, l_comb)
