"""jit'd wrapper for the SSD kernel: pre-scale, pad, call, epilogue."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.ssd_scan import kernel as K


def ssd(xh, bh, ch, dt, a_log, d_skip, *, chunk: int = 128,
        interpret: bool = True):
    """Drop-in for models.ssm.ssd_chunked (initial_state=None).

    xh [B,S,H,P], bh/ch [B,S,N], dt [B,S,H] post-softplus, a_log [H].
    Returns (y [B,S,H,P] f32 incl. D-skip, final_state [B,H,P,N] f32).
    """
    B, S, H, P = xh.shape
    pad = (-S) % chunk
    A = -jnp.exp(a_log.astype(jnp.float32))
    dta = dt.astype(jnp.float32) * A                     # [B,S,H]
    xdt = xh.astype(jnp.float32) * dt[..., None]
    if pad:
        xdt = jnp.pad(xdt, ((0, 0), (0, pad), (0, 0), (0, 0)))
        bh = jnp.pad(bh, ((0, 0), (0, pad), (0, 0)))
        ch = jnp.pad(ch, ((0, 0), (0, pad), (0, 0)))
        dta = jnp.pad(dta, ((0, 0), (0, pad), (0, 0)))   # dta=0 -> decay 1, x=0
    y, fin = K.ssd_scan(xdt, bh.astype(jnp.float32), ch.astype(jnp.float32),
                        dta, chunk=chunk, interpret=interpret)
    y = y[:, :S] + xh.astype(jnp.float32) * d_skip[None, None, :, None]
    return y, fin
