"""Oracles for the SSD scan kernel.

`ssd_ref` re-exports the chunked jnp implementation the model stack uses;
`ssd_recurrent_ref` is the O(S) literal recurrence — the ground truth both
the chunked jnp path and the Pallas kernel must match.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.ssm import ssd_chunked as ssd_ref  # noqa: F401


def ssd_recurrent_ref(xh, bh, ch, dt, a_log, d_skip, initial_state=None):
    """Token-by-token recurrence.  xh [B,S,H,P], bh/ch [B,S,N], dt [B,S,H]."""
    B, S, H, P = xh.shape
    N = bh.shape[-1]
    A = -jnp.exp(a_log.astype(jnp.float32))
    if initial_state is None:
        initial_state = jnp.zeros((B, H, P, N), jnp.float32)

    def step(state, inp):
        x_t, b_t, c_t, dt_t = inp                       # [B,H,P],[B,N],[B,N],[B,H]
        decay = jnp.exp(dt_t * A)                       # [B,H]
        upd = jnp.einsum("bhp,bn->bhpn", x_t * dt_t[..., None], b_t)
        state = state * decay[:, :, None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", state, c_t)
        return state, y

    xs = (xh.astype(jnp.float32).transpose(1, 0, 2, 3),
          bh.astype(jnp.float32).transpose(1, 0, 2),
          ch.astype(jnp.float32).transpose(1, 0, 2),
          dt.astype(jnp.float32).transpose(1, 0, 2))
    final, ys = jax.lax.scan(step, initial_state, xs)
    y = ys.transpose(1, 0, 2, 3)                         # [B,S,H,P]
    y = y + xh.astype(jnp.float32) * d_skip[None, None, :, None]
    return y, final
