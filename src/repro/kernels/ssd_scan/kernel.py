"""Pallas TPU kernel: chunked SSD (Mamba2 state-space duality) scan.

TPU adaptation (DESIGN.md §3): the SSD chunk algorithm maps naturally onto
the MXU — the intra-chunk term is an L x L masked matmul and the inter-chunk
term an L x N x P contraction — while the O(1) recurrent state [P, N] lives
in a VMEM scratch that persists across the sequential chunk dimension of the
grid.  Layout:

  grid (B, H, n_chunks): chunks iterate innermost (TPU grids are sequential),
  so the scratch state carries the recurrence without HBM round-trips;
  (B, H) are embarrassingly parallel.

  blocks per step: xdt [L, P], b/c [L, N], dta [L] — with L=128 (chunk),
  P=64..128, N=64..128 everything is 128-aligned for the MXU and a chunk's
  working set is ~200 KB, far under the ~16 MB VMEM budget.

Inputs are pre-scaled by ops.py (xdt = x * dt, dta = dt * A) so the kernel
body is pure SSD algebra; the D-skip and gating are cheap VPU epilogues that
XLA fuses outside.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(xdt_ref, b_ref, c_ref, dta_ref, y_ref, fin_ref, state_ref,
                *, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    xdt = xdt_ref[0, :, 0, :].astype(jnp.float32)       # [L, P]
    b = b_ref[0].astype(jnp.float32)                    # [L, N]
    c = c_ref[0].astype(jnp.float32)                    # [L, N]
    dta = dta_ref[0, :, 0].astype(jnp.float32)          # [L]
    L = dta.shape[0]

    cum = jnp.cumsum(dta)                               # [L]
    # ---- intra-chunk: (C B^T ∘ decay) @ Xdt ---------------------------------
    rel = cum[:, None] - cum[None, :]                   # [L, L]  (t, s)
    causal = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    decay = jnp.where(causal, jnp.exp(rel), 0.0)
    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    y = jax.lax.dot_general(scores * decay, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # ---- inter-chunk: C e^{cum} @ S0^T --------------------------------------
    s0 = state_ref[...]                                  # [P, N]
    y += jax.lax.dot_general(c * jnp.exp(cum)[:, None], s0,
                             (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)

    # ---- state update: S = e^{cum[-1]} S0 + Xdt^T (B ∘ tail) ----------------
    tail = jnp.exp(cum[-1] - cum)                        # [L]
    snew = jnp.exp(cum[-1]) * s0 + jax.lax.dot_general(
        xdt, b * tail[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    state_ref[...] = snew

    y_ref[0, :, 0, :] = y

    @pl.when(ci == n_chunks - 1)
    def _final():
        fin_ref[0, 0] = snew


def ssd_scan(xdt, bh, ch, dta, *, chunk: int = 128, interpret: bool = True):
    """xdt [B,S,H,P] (x pre-multiplied by dt), bh/ch [B,S,N], dta [B,S,H]
    (dt*A log-decay).  Returns (y [B,S,H,P] f32, final_state [B,H,P,N] f32).
    """
    B, S, H, P = xdt.shape
    N = bh.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    return pl.pallas_call(
        lambda *refs: _ssd_kernel(*refs, n_chunks=nc),
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, P), jnp.float32),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xdt, bh, ch, dta)
