"""Pure-jnp oracle: causal sliding-window prefill attention (GQA)."""
from __future__ import annotations

import jax.numpy as jnp


def swa_attention_ref(q, k, v, window: int, softcap: float | None = None,
                      segments=None):
    """q [B,Hq,S,hd], k/v [B,Hkv,S,hd]; canonical positions 0..S-1.
    Returns out [B,Hq,S,hd] f32.  ``segments`` [B,S] restricts attention
    to same-segment tokens (packed-prefill block-diagonal mask)."""
    B, Hq, S, hd = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    kf = jnp.repeat(k.astype(jnp.float32), G, axis=1)
    vf = jnp.repeat(v.astype(jnp.float32), G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kf)
    s = s / jnp.sqrt(jnp.float32(hd))
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    qi = jnp.arange(S)[:, None]
    ki = jnp.arange(S)[None, :]
    mask = (ki <= qi) & (ki > qi - window)
    if segments is not None:
        mask = mask[None] & (segments[:, :, None] == segments[:, None, :])
        mask = mask[:, None]                               # [B,1,S,S]
    s = jnp.where(mask, s, -1e30)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = jnp.where(mask, p, 0.0)
    p = p / jnp.clip(p.sum(-1, keepdims=True), 1e-30)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf)
