"""Pallas TPU kernel: causal sliding-window flash-attention prefill (GQA).

The sequence-wise policies the paper builds on (Sliding Window /
StreamingLLM) make prefill attention band-limited; this kernel exploits that
structurally:

  * grid (B, Hq, S/bq, S/bk) with the key dimension innermost — online
    softmax state (m, l, acc) lives in VMEM scratch across the key sweep.
  * q/k blocks are 128x128 MXU-aligned; GQA is folded into the k/v index
    map (query head h reads kv head h // G) so no repeated KV materializes
    in HBM.
  * fully-masked (non-causal or out-of-window) blocks skip the MXU work via
    pl.when — with window w, each query row touches O(w) keys, which is the
    sub-quadratic property that makes long_500k dense decode viable.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _swa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                bq: int, bk: int, nk: int, window: int, scale: float,
                softcap: float, segq_ref=None, segk_ref=None):
    ki = pl.program_id(3)
    qi = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * bq
    k_start = ki * bk
    # block is live iff some (qpos >= kpos) and some (kpos > qpos - window)
    live = (k_start <= q_start + bq - 1) & \
        (k_start + bk - 1 > q_start - window)
    if segq_ref is not None:
        # packed prefill: whole block skips when the q rows' segment range
        # cannot intersect the k rows' (ids are non-decreasing along S)
        live &= (segk_ref[0, 0] <= segq_ref[0, bq - 1]) & \
            (segk_ref[0, bk - 1] >= segq_ref[0, 0])

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)              # [bq, hd]
        k = k_ref[0, 0].astype(jnp.float32)              # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = (kpos <= qpos) & (kpos > qpos - window)
        if segq_ref is not None:
            # block-diagonal extension: tokens attend within their segment
            mask &= segq_ref[0, :][:, None] == segk_ref[0, :][None, :]
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_scr[...]
        lsafe = jnp.where(l > 0, l, 1.0)
        o_ref[0, 0] = (acc_scr[...] / lsafe[:, None]).astype(o_ref.dtype)


def swa_prefill(q, k, v, *, window: int, bq: int = 128, bk: int = 128,
                softcap: float | None = None, interpret: bool = True,
                segments=None):
    """q [B,Hq,S,hd], k/v [B,Hkv,S,hd] -> out [B,Hq,S,hd] (q dtype).

    `window` is static (per-layer attention geometry).  S must be a
    multiple of the block sizes (ops.py pads).  ``segments`` [B,S] int32
    (non-decreasing per row) makes the mask block-diagonal for packed
    prefill; blocks whose q/k segment ranges cannot intersect skip the MXU
    work entirely, so a pack of R short requests costs O(R * len²) instead
    of O((R * len)²).
    """
    B, Hq, S, hd = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    assert S % bq == 0 and S % bk == 0
    nq, nk = S // bq, S // bk
    base = dict(bq=bq, bk=bk, nk=nk, window=int(window),
                scale=1.0 / math.sqrt(hd), softcap=float(softcap or 0.0))
    in_specs = [
        pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // G, j, 0)),
        pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // G, j, 0)),
    ]
    args = (q, k, v)
    if segments is None:
        kern = functools.partial(_swa_kernel, **base)
    else:
        def kern(q_ref, k_ref, v_ref, segq_ref, segk_ref, o_ref,
                 m_scr, l_scr, acc_scr):
            _swa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                        segq_ref=segq_ref, segk_ref=segk_ref, **base)
        in_specs += [
            pl.BlockSpec((1, bq), lambda b, h, i, j: (b, i)),
            pl.BlockSpec((1, bk), lambda b, h, i, j: (b, j)),
        ]
        args = (q, k, v, segments.astype(jnp.int32), segments.astype(jnp.int32))
    return pl.pallas_call(
        kern,
        grid=(B, Hq, nq, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(*args)
