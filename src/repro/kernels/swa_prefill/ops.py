"""jit'd wrapper: pad to block multiples, call kernel, slice back."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.swa_prefill import kernel as K


def swa_attention(q, k, v, *, window: int, bq: int = 128, bk: int = 128,
                  softcap=None, interpret: bool = True, segments=None):
    """q [B,Hq,S,hd], k/v [B,Hkv,S,hd] -> [B,Hq,S,hd].  Pads S as needed;
    padded queries attend only to themselves... and are sliced away.
    ``segments`` [B,S] adds packed-prefill block-diagonal masking (padding
    extends the last segment, then is sliced away)."""
    B, Hq, S, hd = q.shape
    blk = max(bq, bk)
    if S < blk:                      # tiny sequences: shrink blocks
        bq = bk = max(8, 1 << (S - 1).bit_length() >> 1)
    pad = (-S) % max(bq, bk)
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        if segments is not None:
            segments = jnp.pad(segments, ((0, 0), (0, pad)), mode="edge")
    out = K.swa_prefill(q, k, v, window=window, bq=bq, bk=bk,
                        softcap=softcap, interpret=interpret,
                        segments=segments)
    return out[:, :, :S]
