"""Paged KV arenas: fixed-size pages in one global pool (DESIGN.md §3).

The contiguous budget-tier arenas (`core/cache.py`) couple `max_concurrency`
to the worst-case budget: every row owns `budget` physical slots per layer
whether it uses them or not.  This module splits the storage dimension off:

  * **the pool** (`KVPool`) — ONE device array pair ``[N_pages, page_size,
    Hkv, hd]`` holding every KV page of every row AND the prefix cache's
    resident pages (`serving/prefix.py`).  Page 0 is the reserved **null
    page**: never allocated, it absorbs the unconditional eviction writes of
    retired (frozen) rows, whose slots are masked by ``pos = -1`` and whose
    page-table rows are zeroed at clear — stale bits land somewhere harmless
    instead of in a page another row now owns.
  * **the tier** (`PagedTier`) — the per-layer/per-row *metadata* of a budget
    tier: an int32 page table ``tbl [L, B, pages_per_row]`` plus the same
    ``pos``/``score`` slot arrays the contiguous `SlotCache` carries.  Slot
    ``s`` of a row lives at ``(tbl[l, b, s // page_size], s % page_size)``.
    Table entries are **data** (traced int32), so gathers/scatters compile
    once and never retrace when rows move to different pages.
  * **the allocator** (`PagePool`) — the host-side free list + refcounts.
    Rows allocate privately-owned pages at admission (only as many as the
    request can actually touch — `pages_needed`, the page-release bound
    `compact()` documents) and free them wholesale at retirement; the prefix
    cache owns its resident pages with the same refcounts and releases them
    through LRU leaf eviction when the pool runs tight.

Scatter convention: a page id equal to ``N_pages`` (one past the pool) is
the **drop sentinel** — `.at[ids].set(..., mode="drop")` discards it, the
exact trick `core.cache.insert_rows` uses for pad rows — and the id stored
into a device page table is remapped to the null page 0.
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Callable, Deque, Dict, Iterable, NamedTuple, Optional, \
    Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class KVPool(NamedTuple):
    """The global paged KV storage (device).  Shapes [N_pages, psize, Hkv, hd]."""
    kp: jnp.ndarray
    vp: jnp.ndarray

    @property
    def n_pages(self) -> int:
        return self.kp.shape[0]

    @property
    def page_size(self) -> int:
        return self.kp.shape[1]


class PagedTier(NamedTuple):
    """Metadata of one budget tier under paging — the `SlotCache` with its
    k/v storage moved into the `KVPool` and replaced by a page table."""
    tbl: jnp.ndarray     # [L, B, npp] int32 page ids (0 = null page)
    pos: jnp.ndarray     # [L, B, S] int32 original positions, -1 = empty
    score: jnp.ndarray   # [L, B, S] float32 accumulated H2O mass

    @property
    def n_slots(self) -> int:
        return self.pos.shape[-1]

    @property
    def n_layers(self) -> int:
        return self.pos.shape[0]

    @property
    def pages_per_row(self) -> int:
        return self.tbl.shape[-1]


def pages_for(slots: int, page_size: int) -> int:
    """Pages a `slots`-slot arena row spans: ceil(slots / page_size)."""
    return -(-max(int(slots), 1) // int(page_size))


def pages_needed(t, budget: int, max_new: int, page_size: int) -> int:
    """Tight per-(layer, row) page bound for one admitted request.

    After compaction the live slots form a PREFIX of the arena row (see
    `core.cache.compact`), and decode fills empties in index order, so a
    request that enters with ``t`` prompt slots and may emit ``max_new``
    tokens (``max_new - 1`` decode KV writes — the first token samples off
    the prefill logits) can never touch a slot past
    ``min(budget, t + max_new - 1)``.  Pages beyond that bound stay the
    null page: sequence-wise squeezing releases them to the pool instead of
    leaving torn half-pages resident.

    Chunked admission (`ContinuousEngine.begin_chunked`) books this same
    quota UP FRONT — the pending row's pages are allocated before its
    first chunk runs and sit unscattered until the final chunk's fused
    admit — so a partially-prefilled row holds exactly the headroom a
    monolithic admission of the same request would, and pool accounting
    (`audit_pool`) balances at every intermediate poll.
    """
    used = min(int(budget), max(int(t), 0) + max(int(max_new), 1) - 1)
    return pages_for(max(used, 1), page_size)


def empty_pool(n_pages: int, page_size: int, kv_heads: int, head_dim: int,
               dtype=jnp.bfloat16) -> KVPool:
    shape = (n_pages, page_size, kv_heads, head_dim)
    return KVPool(kp=jnp.zeros(shape, dtype), vp=jnp.zeros(shape, dtype))


def empty_paged_tier(n_layers: int, batch: int, slots: int,
                     page_size: int) -> PagedTier:
    return PagedTier(
        tbl=jnp.zeros((n_layers, batch, pages_for(slots, page_size)),
                      jnp.int32),
        pos=jnp.full((n_layers, batch, slots), -1, jnp.int32),
        score=jnp.zeros((n_layers, batch, slots), jnp.float32),
    )


# --------------------------------------------------------------------------- #
# device gathers / scatters (all indices are traced data — zero retrace)
# --------------------------------------------------------------------------- #

def gather_layer_pages(pool: KVPool, tbl_row: jnp.ndarray, slots: int):
    """One layer's arena view for a row set: ``tbl_row [B, npp]`` ->
    (k, v) each [B, slots, Hkv, hd].  The last page of a row may extend past
    `slots` (budgets need not be page multiples); the tail is sliced off."""
    B, npp = tbl_row.shape
    psize = pool.page_size

    def g(a):
        return a[tbl_row].reshape(B, npp * psize, *a.shape[2:])[:, :slots]

    return g(pool.kp), g(pool.vp)


def _chunked(a: jnp.ndarray, psize: int) -> jnp.ndarray:
    """[L, B, S, ...] -> [L, B, ceil(S/psize), psize, ...] (zero-padded tail).

    The pad slots mirror `gather_layer_pages`'s tail slice: they occupy the
    last page's unused capacity and are never read back."""
    L, B, S = a.shape[:3]
    nch = pages_for(S, psize)
    pad = [(0, 0), (0, 0), (0, nch * psize - S)] + [(0, 0)] * (a.ndim - 3)
    return jnp.pad(a, pad).reshape(L, B, nch, psize, *a.shape[3:])


def scatter_rows_to_pages(pool: KVPool, k: jnp.ndarray, v: jnp.ndarray,
                          tbl: jnp.ndarray) -> KVPool:
    """Write admitted rows' [L, NB, S, Hkv, hd] KV into their pages.

    ``tbl [L, NB, npp]`` carries the drop sentinel (``pool.n_pages``) for
    pad rows of a partial admit batch AND for the released tail pages of the
    `pages_needed` bound — both vanish in the ``mode="drop"`` scatter."""
    psize = pool.page_size
    kc = _chunked(k, psize).astype(pool.kp.dtype)
    vc = _chunked(v, psize).astype(pool.vp.dtype)
    return KVPool(kp=pool.kp.at[tbl].set(kc, mode="drop"),
                  vp=pool.vp.at[tbl].set(vc, mode="drop"))


def insert_tier_rows(tier: PagedTier, rows_cache, rows, tbl: jnp.ndarray,
                     sentinel: int) -> PagedTier:
    """Paged counterpart of `core.cache.insert_rows` (metadata half).

    Scatters the admitted rows' pos/score slot arrays and their page-table
    rows at traced row indices; `sentinel` entries (pad rows / released tail
    pages) remap to the null page 0 in the stored table — the K/V payload
    itself goes to the pool via `scatter_rows_to_pages`, where the same
    sentinel drops the write."""
    return PagedTier(
        tbl=tier.tbl.at[:, rows].set(
            jnp.where(tbl >= sentinel, 0, tbl).astype(jnp.int32),
            mode="drop"),
        pos=tier.pos.at[:, rows].set(rows_cache.pos.astype(jnp.int32),
                                     mode="drop"),
        score=tier.score.at[:, rows].set(
            rows_cache.score.astype(tier.score.dtype), mode="drop"),
    )


def clear_tier_row(tier: PagedTier, row) -> PagedTier:
    """Paged `clear_row`: empty every slot AND point the row's page table at
    the null page, so a frozen row's unconditional eviction writes scribble
    into page 0 — never into pages the allocator has since handed to another
    row or to the prefix cache."""
    L, _, S = tier.pos.shape
    npp = tier.tbl.shape[-1]
    return PagedTier(
        tbl=jax.lax.dynamic_update_slice_in_dim(
            tier.tbl, jnp.zeros((L, 1, npp), tier.tbl.dtype), row, axis=1),
        pos=jax.lax.dynamic_update_slice_in_dim(
            tier.pos, jnp.full((L, 1, S), -1, tier.pos.dtype), row, axis=1),
        score=jax.lax.dynamic_update_slice_in_dim(
            tier.score, jnp.zeros((L, 1, S), tier.score.dtype), row, axis=1),
    )


def write_decode_records(pool: KVPool, k_new: jnp.ndarray, v_new: jnp.ndarray,
                         pages: jnp.ndarray, offs: jnp.ndarray) -> KVPool:
    """Apply one decode step's deferred KV writes in ONE batched scatter.

    The layer scan reads the pool as a closure constant and emits per-layer
    write records ``(k_new, v_new, page, offset)`` as scan outputs instead
    of scattering inside the `lax.cond` tier branches (which would fork the
    pool per branch); this lands all ``[L_attn, B]`` writes afterwards.
    Frozen rows' records target the null page 0 (their tables were zeroed at
    clear), where colliding writes are harmless scribbles."""
    return KVPool(
        kp=pool.kp.at[pages, offs].set(k_new.astype(pool.kp.dtype)),
        vp=pool.vp.at[pages, offs].set(v_new.astype(pool.vp.dtype)),
    )


# --------------------------------------------------------------------------- #
# host-side allocator
# --------------------------------------------------------------------------- #

class PagePool:
    """Free list + refcounts over the `KVPool`'s page axis (host side).

    Page 0 is reserved (the null page — permanently pinned).  `alloc`
    returns page ids with refcount 1; `incref`/`decref` implement sharing
    (the prefix cache pins a matched path for the duration of an admission
    burst so LRU eviction cannot free pages a request is about to gather
    from); a page returns to the free list when its refcount reaches 0.

    ``evict_hook`` (set by `serving.prefix.PrefixCache`) is called when an
    allocation cannot be satisfied; it should release refcount-0-pinnable
    pages (LRU leaves) and return True while progress is possible.

    **Watermarks** (`set_watermarks`) are advisory thresholds for an
    *overcommitted* pool (DESIGN.md §5): `below_low()` tells the engine to
    stop admitting, `above_high()` that free pages recovered enough to
    resume.  They never change `alloc` semantics.  `forced_failures` is the
    fault-injection hook: `try_alloc` (and the engine's admission headroom
    check) consume one scripted failure per call before touching the free
    list; the raising `alloc` ignores it so a mid-burst allocation can
    never be failed out from under an admission the engine already
    committed to.

    **Thread safety**: the serving loop (`serving/service.py`) mutates the
    free list and refcounts on its own thread while submitting threads read
    stats (`n_free`, `n_resident`, occupancy).  Every mutation and
    threshold read holds ``lock`` — a re-entrant lock SHARED with the
    prefix cache (`serving.prefix.PrefixCache` adopts it), so the
    alloc → evict_hook → decref cycle re-enters instead of deadlocking and
    there is no lock-order to get wrong between the two structures.
    """

    def __init__(self, n_pages: int):
        assert n_pages >= 2, "pool needs the null page plus at least one"
        self.n_pages = int(n_pages)
        self.refcount = np.zeros(self.n_pages, np.int32)
        self.refcount[0] = 1                      # null page: never allocated
        self._free: Deque[int] = deque(range(1, self.n_pages))
        self.evict_hook: Optional[Callable[[], bool]] = None
        self.low_pages = 0          # advisory: admission stalls below this
        self.high_pages = 0         # advisory: stall clears above this
        self.forced_failures = 0    # fault injection: try_alloc failures owed
        self.lock = threading.RLock()

    @property
    def sentinel(self) -> int:
        """The drop-sentinel page id (one past the pool)."""
        return self.n_pages

    @property
    def n_free(self) -> int:
        with self.lock:
            return len(self._free)

    @property
    def n_resident(self) -> int:
        """Allocated pages (excluding the null page)."""
        with self.lock:
            return self.n_pages - 1 - len(self._free)

    def set_watermarks(self, low_pages: int, high_pages: int) -> None:
        """Install advisory low/high free-page thresholds (page counts)."""
        low_pages, high_pages = int(low_pages), int(high_pages)
        if not 0 <= low_pages <= high_pages < self.n_pages:
            raise ValueError(
                f"watermarks must satisfy 0 <= low <= high < n_pages; got "
                f"low={low_pages} high={high_pages} n_pages={self.n_pages}")
        self.low_pages, self.high_pages = low_pages, high_pages

    def below_low(self, extra_free: int = 0) -> bool:
        """Free pages (+ `extra_free` reclaimables) at/below the low mark."""
        with self.lock:
            return len(self._free) + int(extra_free) <= self.low_pages

    def above_high(self, extra_free: int = 0) -> bool:
        """Free pages (+ `extra_free` reclaimables) past the high mark."""
        with self.lock:
            return len(self._free) + int(extra_free) > self.high_pages

    def alloc(self, n: int) -> np.ndarray:
        """Allocate `n` pages (refcount 1 each), evicting through
        ``evict_hook`` under pressure.  Raises RuntimeError when the pool is
        genuinely exhausted — under the admission-time headroom check
        (`ContinuousEngine.admissible_prefix`) this means a caller bypassed
        the degradation ladder, or the prefix cache's *pinned* pages
        exceeded their headroom."""
        with self.lock:
            while len(self._free) < n:
                if self.evict_hook is None or not self.evict_hook():
                    raise RuntimeError(
                        f"page pool exhausted: need {n}, free "
                        f"{len(self._free)} of {self.n_pages} (pinned "
                        f"prefix pages exceed headroom)")
            ids = np.asarray([self._free.popleft() for _ in range(n)],
                             np.int32)
            self.refcount[ids] = 1
            return ids

    def try_alloc(self, n: int) -> Optional[np.ndarray]:
        """`alloc` that returns None instead of raising (prefix-cache
        insertion is best-effort: a full pool skips caching, never fails
        admission).  Consumes one scripted `forced_failures` per call."""
        with self.lock:
            if self.forced_failures > 0:
                self.forced_failures -= 1
                return None
            while len(self._free) < n:
                if self.evict_hook is None or not self.evict_hook():
                    return None
            return self.alloc(n)

    def incref(self, ids) -> None:
        ids = np.asarray(ids, np.int64).reshape(-1)
        with self.lock:
            self._check_known(ids)
            self.refcount[ids] += 1

    def decref(self, ids) -> None:
        ids = np.asarray(ids, np.int64).reshape(-1)
        with self.lock:
            self._check_known(ids)
            if not (self.refcount[ids] > 0).all():
                bad = ids[self.refcount[ids] <= 0]
                raise RuntimeError(f"page double free: ids {bad.tolist()} "
                                   f"already have refcount 0")
            self.refcount[ids] -= 1
            for i in ids[self.refcount[ids] == 0]:
                self._free.append(int(i))

    def _check_known(self, ids: np.ndarray) -> None:
        if ids.size and not ((ids > 0) & (ids < self.n_pages)).all():
            bad = ids[(ids <= 0) | (ids >= self.n_pages)]
            raise RuntimeError(
                f"unknown page ids {bad.tolist()}: valid ids are "
                f"1..{self.n_pages - 1} (0 is the reserved null page)")

    free = decref    # rows free privately-owned (refcount-1) pages


def audit_pool_accounting(pool: PagePool,
                          owners: Dict[str, Iterable[np.ndarray]],
                          page_tables: Sequence[np.ndarray] = ()) -> None:
    """Assert the pool's books balance: free list + owned pages must tile
    ``{1, ..., n_pages - 1}`` exactly (DESIGN.md §5's pool-accounting audit).

    ``owners`` maps an owner label (for error messages) to an iterable of
    page-id arrays it holds; an id may appear under several owners only via
    refcount sharing, and every owned id's refcount must equal the number of
    owner entries referencing it.  ``page_tables`` are optional host copies
    of device tables whose non-null entries must all be owned (the "deep"
    check).  Raises AssertionError with a labelled message on any violation.
    Holds the pool's lock for the whole audit, so a concurrent stat poll
    never observes (nor interleaves with) a half-checked pool.
    """
    with pool.lock:
        _audit_pool_locked(pool, owners, page_tables)


def _audit_pool_locked(pool: PagePool,
                       owners: Dict[str, Iterable[np.ndarray]],
                       page_tables: Sequence[np.ndarray] = ()) -> None:
    free = np.asarray(list(pool._free), np.int64)
    if free.size != len(set(free.tolist())):
        raise AssertionError("pool audit: duplicate ids on the free list")
    if free.size and not ((free > 0) & (free < pool.n_pages)).all():
        raise AssertionError("pool audit: free list holds out-of-range ids")
    if (pool.refcount[free] != 0).any() if free.size else False:
        raise AssertionError("pool audit: free page with nonzero refcount")

    held: Dict[int, int] = {}
    owner_of: Dict[int, str] = {}
    for label, arrays in owners.items():
        for arr in arrays:
            for i in np.asarray(arr, np.int64).reshape(-1).tolist():
                if not 0 < i < pool.n_pages:
                    raise AssertionError(
                        f"pool audit: owner {label!r} holds invalid id {i}")
                held[i] = held.get(i, 0) + 1
                owner_of[i] = label
    free_set = set(free.tolist())
    for i, n_refs in held.items():
        if i in free_set:
            raise AssertionError(
                f"pool audit: page {i} owned by {owner_of[i]!r} but free")
        if int(pool.refcount[i]) != n_refs:
            raise AssertionError(
                f"pool audit: page {i} refcount {int(pool.refcount[i])} != "
                f"{n_refs} owner references (last owner {owner_of[i]!r})")
    if int(pool.refcount[0]) != 1:
        raise AssertionError("pool audit: null page refcount disturbed")
    leaked = set(range(1, pool.n_pages)) - free_set - set(held)
    if leaked:
        raise AssertionError(f"pool audit: leaked pages {sorted(leaked)} "
                             f"(neither free nor owned)")

    owned = set(held)
    for tbl in page_tables:
        entries = np.asarray(tbl, np.int64).reshape(-1)
        live = entries[(entries != 0) & (entries != pool.sentinel)]
        bad = [i for i in set(live.tolist()) if i not in owned]
        if bad:
            raise AssertionError(
                f"pool audit: device table references unowned pages {bad}")


class PoolFaultInjector:
    """Deterministic scripted pool pressure (DESIGN.md §5 fault injection).

    ``script`` maps a tick index (the scheduler calls `tick(pool)` once per
    poll that has a live pool, counting from 0) to a list of actions:

      * ``("steal", n)``      — allocate up to ``n`` free pages and hold them
      * ``("release", n)``    — return up to ``n`` stolen pages (-1: all)
      * ``("fail_alloc", k)`` — owe the pool ``k`` forced `try_alloc`/
                                headroom-check failures
      * ``("evict_storm", k)`` — fire ``evict_hook`` up to ``k`` times

    Stolen pages are real allocations (refcount 1, audited under the
    injector's name), so steals exercise exactly the accounting paths a
    burst of real admissions would.
    """

    def __init__(self, script: Dict[int, Sequence[Tuple[str, int]]]):
        self.script = {int(k): list(v) for k, v in script.items()}
        self.ticks = 0
        self.stolen: Deque[int] = deque()

    @property
    def stolen_pages(self) -> np.ndarray:
        return np.asarray(list(self.stolen), np.int32)

    def tick(self, pool: PagePool) -> None:
        actions = self.script.get(self.ticks, ())
        self.ticks += 1
        for op, arg in actions:
            if op == "steal":
                got = pool.try_alloc(min(int(arg), pool.n_free))
                if got is not None:
                    self.stolen.extend(got.tolist())
            elif op == "release":
                n = len(self.stolen) if arg < 0 else min(int(arg),
                                                         len(self.stolen))
                if n:
                    ids = [self.stolen.popleft() for _ in range(n)]
                    pool.decref(np.asarray(ids, np.int32))
            elif op == "fail_alloc":
                pool.forced_failures += int(arg)
            elif op == "evict_storm":
                for _ in range(int(arg)):
                    if pool.evict_hook is None or not pool.evict_hook():
                        break
            else:
                raise ValueError(f"unknown fault action {op!r}")

    def release_all(self, pool: PagePool) -> None:
        """Return every stolen page (end-of-trace cleanup)."""
        if self.stolen:
            pool.decref(self.stolen_pages)
            self.stolen.clear()
