"""Paged KV arenas: fixed-size pages in one global pool (DESIGN.md §3).

The contiguous budget-tier arenas (`core/cache.py`) couple `max_concurrency`
to the worst-case budget: every row owns `budget` physical slots per layer
whether it uses them or not.  This module splits the storage dimension off:

  * **the pool** (`KVPool`) — ONE device array pair ``[N_pages, page_size,
    Hkv, hd]`` holding every KV page of every row AND the prefix cache's
    resident pages (`serving/prefix.py`).  Page 0 is the reserved **null
    page**: never allocated, it absorbs the unconditional eviction writes of
    retired (frozen) rows, whose slots are masked by ``pos = -1`` and whose
    page-table rows are zeroed at clear — stale bits land somewhere harmless
    instead of in a page another row now owns.
  * **the tier** (`PagedTier`) — the per-layer/per-row *metadata* of a budget
    tier: an int32 page table ``tbl [L, B, pages_per_row]`` plus the same
    ``pos``/``score`` slot arrays the contiguous `SlotCache` carries.  Slot
    ``s`` of a row lives at ``(tbl[l, b, s // page_size], s % page_size)``.
    Table entries are **data** (traced int32), so gathers/scatters compile
    once and never retrace when rows move to different pages.
  * **the allocator** (`PagePool`) — the host-side free list + refcounts.
    Rows allocate privately-owned pages at admission (only as many as the
    request can actually touch — `pages_needed`, the page-release bound
    `compact()` documents) and free them wholesale at retirement; the prefix
    cache owns its resident pages with the same refcounts and releases them
    through LRU leaf eviction when the pool runs tight.

Scatter convention: a page id equal to ``N_pages`` (one past the pool) is
the **drop sentinel** — `.at[ids].set(..., mode="drop")` discards it, the
exact trick `core.cache.insert_rows` uses for pad rows — and the id stored
into a device page table is remapped to the null page 0.
"""
from __future__ import annotations

from typing import Callable, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np


class KVPool(NamedTuple):
    """The global paged KV storage (device).  Shapes [N_pages, psize, Hkv, hd]."""
    kp: jnp.ndarray
    vp: jnp.ndarray

    @property
    def n_pages(self) -> int:
        return self.kp.shape[0]

    @property
    def page_size(self) -> int:
        return self.kp.shape[1]


class PagedTier(NamedTuple):
    """Metadata of one budget tier under paging — the `SlotCache` with its
    k/v storage moved into the `KVPool` and replaced by a page table."""
    tbl: jnp.ndarray     # [L, B, npp] int32 page ids (0 = null page)
    pos: jnp.ndarray     # [L, B, S] int32 original positions, -1 = empty
    score: jnp.ndarray   # [L, B, S] float32 accumulated H2O mass

    @property
    def n_slots(self) -> int:
        return self.pos.shape[-1]

    @property
    def n_layers(self) -> int:
        return self.pos.shape[0]

    @property
    def pages_per_row(self) -> int:
        return self.tbl.shape[-1]


def pages_for(slots: int, page_size: int) -> int:
    """Pages a `slots`-slot arena row spans: ceil(slots / page_size)."""
    return -(-max(int(slots), 1) // int(page_size))


def pages_needed(t, budget: int, max_new: int, page_size: int) -> int:
    """Tight per-(layer, row) page bound for one admitted request.

    After compaction the live slots form a PREFIX of the arena row (see
    `core.cache.compact`), and decode fills empties in index order, so a
    request that enters with ``t`` prompt slots and may emit ``max_new``
    tokens (``max_new - 1`` decode KV writes — the first token samples off
    the prefill logits) can never touch a slot past
    ``min(budget, t + max_new - 1)``.  Pages beyond that bound stay the
    null page: sequence-wise squeezing releases them to the pool instead of
    leaving torn half-pages resident.
    """
    used = min(int(budget), max(int(t), 0) + max(int(max_new), 1) - 1)
    return pages_for(max(used, 1), page_size)


def empty_pool(n_pages: int, page_size: int, kv_heads: int, head_dim: int,
               dtype=jnp.bfloat16) -> KVPool:
    shape = (n_pages, page_size, kv_heads, head_dim)
    return KVPool(kp=jnp.zeros(shape, dtype), vp=jnp.zeros(shape, dtype))


def empty_paged_tier(n_layers: int, batch: int, slots: int,
                     page_size: int) -> PagedTier:
    return PagedTier(
        tbl=jnp.zeros((n_layers, batch, pages_for(slots, page_size)),
                      jnp.int32),
        pos=jnp.full((n_layers, batch, slots), -1, jnp.int32),
        score=jnp.zeros((n_layers, batch, slots), jnp.float32),
    )


# --------------------------------------------------------------------------- #
# device gathers / scatters (all indices are traced data — zero retrace)
# --------------------------------------------------------------------------- #

def gather_layer_pages(pool: KVPool, tbl_row: jnp.ndarray, slots: int):
    """One layer's arena view for a row set: ``tbl_row [B, npp]`` ->
    (k, v) each [B, slots, Hkv, hd].  The last page of a row may extend past
    `slots` (budgets need not be page multiples); the tail is sliced off."""
    B, npp = tbl_row.shape
    psize = pool.page_size

    def g(a):
        return a[tbl_row].reshape(B, npp * psize, *a.shape[2:])[:, :slots]

    return g(pool.kp), g(pool.vp)


def _chunked(a: jnp.ndarray, psize: int) -> jnp.ndarray:
    """[L, B, S, ...] -> [L, B, ceil(S/psize), psize, ...] (zero-padded tail).

    The pad slots mirror `gather_layer_pages`'s tail slice: they occupy the
    last page's unused capacity and are never read back."""
    L, B, S = a.shape[:3]
    nch = pages_for(S, psize)
    pad = [(0, 0), (0, 0), (0, nch * psize - S)] + [(0, 0)] * (a.ndim - 3)
    return jnp.pad(a, pad).reshape(L, B, nch, psize, *a.shape[3:])


def scatter_rows_to_pages(pool: KVPool, k: jnp.ndarray, v: jnp.ndarray,
                          tbl: jnp.ndarray) -> KVPool:
    """Write admitted rows' [L, NB, S, Hkv, hd] KV into their pages.

    ``tbl [L, NB, npp]`` carries the drop sentinel (``pool.n_pages``) for
    pad rows of a partial admit batch AND for the released tail pages of the
    `pages_needed` bound — both vanish in the ``mode="drop"`` scatter."""
    psize = pool.page_size
    kc = _chunked(k, psize).astype(pool.kp.dtype)
    vc = _chunked(v, psize).astype(pool.vp.dtype)
    return KVPool(kp=pool.kp.at[tbl].set(kc, mode="drop"),
                  vp=pool.vp.at[tbl].set(vc, mode="drop"))


def insert_tier_rows(tier: PagedTier, rows_cache, rows, tbl: jnp.ndarray,
                     sentinel: int) -> PagedTier:
    """Paged counterpart of `core.cache.insert_rows` (metadata half).

    Scatters the admitted rows' pos/score slot arrays and their page-table
    rows at traced row indices; `sentinel` entries (pad rows / released tail
    pages) remap to the null page 0 in the stored table — the K/V payload
    itself goes to the pool via `scatter_rows_to_pages`, where the same
    sentinel drops the write."""
    return PagedTier(
        tbl=tier.tbl.at[:, rows].set(
            jnp.where(tbl >= sentinel, 0, tbl).astype(jnp.int32),
            mode="drop"),
        pos=tier.pos.at[:, rows].set(rows_cache.pos.astype(jnp.int32),
                                     mode="drop"),
        score=tier.score.at[:, rows].set(
            rows_cache.score.astype(tier.score.dtype), mode="drop"),
    )


def clear_tier_row(tier: PagedTier, row) -> PagedTier:
    """Paged `clear_row`: empty every slot AND point the row's page table at
    the null page, so a frozen row's unconditional eviction writes scribble
    into page 0 — never into pages the allocator has since handed to another
    row or to the prefix cache."""
    L, _, S = tier.pos.shape
    npp = tier.tbl.shape[-1]
    return PagedTier(
        tbl=jax.lax.dynamic_update_slice_in_dim(
            tier.tbl, jnp.zeros((L, 1, npp), tier.tbl.dtype), row, axis=1),
        pos=jax.lax.dynamic_update_slice_in_dim(
            tier.pos, jnp.full((L, 1, S), -1, tier.pos.dtype), row, axis=1),
        score=jax.lax.dynamic_update_slice_in_dim(
            tier.score, jnp.zeros((L, 1, S), tier.score.dtype), row, axis=1),
    )


def write_decode_records(pool: KVPool, k_new: jnp.ndarray, v_new: jnp.ndarray,
                         pages: jnp.ndarray, offs: jnp.ndarray) -> KVPool:
    """Apply one decode step's deferred KV writes in ONE batched scatter.

    The layer scan reads the pool as a closure constant and emits per-layer
    write records ``(k_new, v_new, page, offset)`` as scan outputs instead
    of scattering inside the `lax.cond` tier branches (which would fork the
    pool per branch); this lands all ``[L_attn, B]`` writes afterwards.
    Frozen rows' records target the null page 0 (their tables were zeroed at
    clear), where colliding writes are harmless scribbles."""
    return KVPool(
        kp=pool.kp.at[pages, offs].set(k_new.astype(pool.kp.dtype)),
        vp=pool.vp.at[pages, offs].set(v_new.astype(pool.vp.dtype)),
    )


# --------------------------------------------------------------------------- #
# host-side allocator
# --------------------------------------------------------------------------- #

class PagePool:
    """Free list + refcounts over the `KVPool`'s page axis (host side).

    Page 0 is reserved (the null page — permanently pinned).  `alloc`
    returns page ids with refcount 1; `incref`/`decref` implement sharing
    (the prefix cache pins a matched path for the duration of an admission
    burst so LRU eviction cannot free pages a request is about to gather
    from); a page returns to the free list when its refcount reaches 0.

    ``evict_hook`` (set by `serving.prefix.PrefixCache`) is called when an
    allocation cannot be satisfied; it should release refcount-0-pinnable
    pages (LRU leaves) and return True while progress is possible.
    """

    def __init__(self, n_pages: int):
        assert n_pages >= 2, "pool needs the null page plus at least one"
        self.n_pages = int(n_pages)
        self.refcount = np.zeros(self.n_pages, np.int32)
        self.refcount[0] = 1                      # null page: never allocated
        self._free: List[int] = list(range(1, self.n_pages))
        self.evict_hook: Optional[Callable[[], bool]] = None

    @property
    def sentinel(self) -> int:
        """The drop-sentinel page id (one past the pool)."""
        return self.n_pages

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_resident(self) -> int:
        """Allocated pages (excluding the null page)."""
        return self.n_pages - 1 - len(self._free)

    def alloc(self, n: int) -> np.ndarray:
        """Allocate `n` pages (refcount 1 each), evicting through
        ``evict_hook`` under pressure.  Raises RuntimeError when the pool is
        genuinely exhausted — by construction the pool is sized for the
        worst-case row demand, so this means the prefix cache's *pinned*
        pages exceeded their headroom."""
        while len(self._free) < n:
            if self.evict_hook is None or not self.evict_hook():
                raise RuntimeError(
                    f"page pool exhausted: need {n}, free {len(self._free)} "
                    f"of {self.n_pages} (pinned prefix pages exceed headroom)")
        ids = np.asarray([self._free.pop(0) for _ in range(n)], np.int32)
        self.refcount[ids] = 1
        return ids

    def try_alloc(self, n: int) -> Optional[np.ndarray]:
        """`alloc` that returns None instead of raising (prefix-cache
        insertion is best-effort: a full pool skips caching, never fails
        admission)."""
        while len(self._free) < n:
            if self.evict_hook is None or not self.evict_hook():
                return None
        return self.alloc(n)

    def incref(self, ids) -> None:
        ids = np.asarray(ids, np.int64).reshape(-1)
        self.refcount[ids] += 1

    def decref(self, ids) -> None:
        ids = np.asarray(ids, np.int64).reshape(-1)
        assert (self.refcount[ids] > 0).all(), "double free"
        self.refcount[ids] -= 1
        for i in ids[self.refcount[ids] == 0]:
            assert i != 0
            self._free.append(int(i))

    free = decref    # rows free privately-owned (refcount-1) pages
