"""Sequence-wise KV eviction policies as slot keep-priorities.

The paper combines its layer-wise budgets with three sequence-wise
compressors: Sliding Window (Beltagy et al. 2020), StreamingLLM (Xiao et al.
2023) and Heavy-Hitter Oracle / H2O (Zhang et al. 2024).  On TPU all three
reduce to one mechanism over a fixed slot arena:

  * keep-priority(slot) — a float per cached slot; **the victim of an
    eviction is always argmin(priority)**, and prefill compaction keeps the
    top-`budget` slots by the same priority.

    sliding_window : priority = position           (evict oldest)
    streaming_llm  : priority = position, but the first `n_sink` tokens get
                     +INF (never evicted — "attention sinks")
    h2o            : priority = accumulated attention score (kv-head mean),
                     with the most recent `recent_frac * budget` tokens
                     protected (H2O's local statistics window)
    l2_norm        : priority = -||K_slot||_2 (arXiv:2406.11430 — low key
                     norm correlates with high attention mass), same recency
                     window as h2o.  Needs NO attention-score accumulation:
                     the score channel stores the static key norm, so the
                     H2O colsum plumbing is bypassed in decode and
                     chunked-prefill staging, and the policy is layout- and
                     prefix-cache-independent.

Empty slots carry priority -INF so they are always filled first.  This is the
static-shape equivalent of the paper's "if len(K) > b: evict" loop — the
arena IS the budget, so memory savings are physical.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

BIG = 1e18

SLIDING_WINDOW = "sliding_window"
STREAMING_LLM = "streaming_llm"
H2O = "h2o"
# beyond-paper: sinks + heavy-hitters + recency in one priority — the union
# of StreamingLLM's and H2O's protected sets (the paper combines its layer
# dimension with ONE sequence policy at a time; nothing prevents composing)
SINK_H2O = "sink_h2o"
# beyond-paper: key-L2-norm importance (arXiv:2406.11430) — no score
# accumulation, so it composes with every admission layout and the prefix
# cache (the score channel carries the slot's static ||K||_2 instead)
L2_NORM = "l2_norm"
POLICIES = (SLIDING_WINDOW, STREAMING_LLM, H2O, SINK_H2O, L2_NORM)

# policies whose score channel accumulates attention mass across steps; the
# rest leave the colsum plumbing disabled (l2_norm repurposes the channel)
SCORE_ACCUMULATING = (H2O, SINK_H2O)


def accumulates_scores(pol: "PolicyConfig") -> bool:
    """True iff the policy's score channel integrates attention mass."""
    return pol.name in SCORE_ACCUMULATING


def uses_key_norms(pol: "PolicyConfig") -> bool:
    """True iff the policy's score channel holds per-slot key L2 norms."""
    return pol.name == L2_NORM


def key_norms(k: jnp.ndarray) -> jnp.ndarray:
    """Per-slot key L2 norm over (kv_heads, head_dim): [..., S, H, d] -> [..., S].

    Computed in float32 regardless of cache dtype so priorities compare
    stably, and identically for every admission layout (the norm depends
    only on the cached K values — never on which queries attended them)."""
    kf = k.astype(jnp.float32)
    return jnp.sqrt((kf * kf).sum(axis=(-2, -1)))


@dataclasses.dataclass(frozen=True)
class PolicyConfig:
    name: str = SLIDING_WINDOW
    n_sink: int = 4              # streaming_llm: protected prefix tokens
    recent_frac: float = 0.5     # h2o: fraction of budget kept as recency window

    def __post_init__(self):
        assert self.name in POLICIES, self.name


def keep_priority(
    pol: PolicyConfig,
    pos: jnp.ndarray,       # [..., S] original token positions, -1 = empty
    score: jnp.ndarray,     # [..., S] accumulated attention mass (H2O)
    t,                      # current logical position (scalar or [...])
    budget: int,            # arena size (for the H2O recency window)
) -> jnp.ndarray:
    empty = pos < 0
    t = jnp.asarray(t)
    # t: scalar, or any shape broadcastable to pos.shape[:-1] (e.g. [B] under
    # a stacked [L, B, S] arena)
    tb = t if t.ndim == 0 else jnp.broadcast_to(t, pos.shape[:-1])[..., None]
    if pol.name == SLIDING_WINDOW:
        pri = pos.astype(jnp.float32)
    elif pol.name == STREAMING_LLM:
        pri = pos.astype(jnp.float32) + BIG * (pos < pol.n_sink)
    elif pol.name == H2O:
        recent_w = max(int(pol.recent_frac * budget), 1)
        protected = pos > (tb - recent_w)
        pri = score.astype(jnp.float32) + BIG * protected
    elif pol.name == SINK_H2O:
        recent_w = max(int(pol.recent_frac * budget), 1)
        protected = (pos > (tb - recent_w)) | (pos < pol.n_sink)
        pri = score.astype(jnp.float32) + BIG * protected
    elif pol.name == L2_NORM:
        # score holds ||K_slot||_2 — LOW norm = important (keep), so the
        # priority is the negated norm, recency window protected like h2o
        recent_w = max(int(pol.recent_frac * budget), 1)
        protected = pos > (tb - recent_w)
        pri = -score.astype(jnp.float32) + BIG * protected
    else:
        raise ValueError(pol.name)
    return jnp.where(empty, -BIG, pri)
