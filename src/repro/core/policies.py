"""Sequence-wise KV eviction policies as slot keep-priorities.

The paper combines its layer-wise budgets with three sequence-wise
compressors: Sliding Window (Beltagy et al. 2020), StreamingLLM (Xiao et al.
2023) and Heavy-Hitter Oracle / H2O (Zhang et al. 2024).  On TPU all three
reduce to one mechanism over a fixed slot arena:

  * keep-priority(slot) — a float per cached slot; **the victim of an
    eviction is always argmin(priority)**, and prefill compaction keeps the
    top-`budget` slots by the same priority.

    sliding_window : priority = position           (evict oldest)
    streaming_llm  : priority = position, but the first `n_sink` tokens get
                     +INF (never evicted — "attention sinks")
    h2o            : priority = accumulated attention score (kv-head mean),
                     with the most recent `recent_frac * budget` tokens
                     protected (H2O's local statistics window)

Empty slots carry priority -INF so they are always filled first.  This is the
static-shape equivalent of the paper's "if len(K) > b: evict" loop — the
arena IS the budget, so memory savings are physical.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

BIG = 1e18

SLIDING_WINDOW = "sliding_window"
STREAMING_LLM = "streaming_llm"
H2O = "h2o"
# beyond-paper: sinks + heavy-hitters + recency in one priority — the union
# of StreamingLLM's and H2O's protected sets (the paper combines its layer
# dimension with ONE sequence policy at a time; nothing prevents composing)
SINK_H2O = "sink_h2o"
POLICIES = (SLIDING_WINDOW, STREAMING_LLM, H2O, SINK_H2O)


@dataclasses.dataclass(frozen=True)
class PolicyConfig:
    name: str = SLIDING_WINDOW
    n_sink: int = 4              # streaming_llm: protected prefix tokens
    recent_frac: float = 0.5     # h2o: fraction of budget kept as recency window

    def __post_init__(self):
        assert self.name in POLICIES, self.name


def keep_priority(
    pol: PolicyConfig,
    pos: jnp.ndarray,       # [..., S] original token positions, -1 = empty
    score: jnp.ndarray,     # [..., S] accumulated attention mass (H2O)
    t,                      # current logical position (scalar or [...])
    budget: int,            # arena size (for the H2O recency window)
) -> jnp.ndarray:
    empty = pos < 0
    t = jnp.asarray(t)
    # t: scalar, or any shape broadcastable to pos.shape[:-1] (e.g. [B] under
    # a stacked [L, B, S] arena)
    tb = t if t.ndim == 0 else jnp.broadcast_to(t, pos.shape[:-1])[..., None]
    if pol.name == SLIDING_WINDOW:
        pri = pos.astype(jnp.float32)
    elif pol.name == STREAMING_LLM:
        pri = pos.astype(jnp.float32) + BIG * (pos < pol.n_sink)
    elif pol.name == H2O:
        recent_w = max(int(pol.recent_frac * budget), 1)
        protected = pos > (tb - recent_w)
        pri = score.astype(jnp.float32) + BIG * protected
    elif pol.name == SINK_H2O:
        recent_w = max(int(pol.recent_frac * budget), 1)
        protected = (pos > (tb - recent_w)) | (pos < pol.n_sink)
        pri = score.astype(jnp.float32) + BIG * protected
    else:
        raise ValueError(pol.name)
    return jnp.where(empty, -BIG, pri)
