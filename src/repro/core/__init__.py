# SqueezeAttention core: layer-importance measurement -> KMeans grouping ->
# Algorithm-1 budget reallocation -> policy-driven slot arenas.
from repro.core.allocation import (BudgetPlan, allocate, allocate_zigzag,
                                   plan_cache_bytes, plan_pool_pages,
                                   uniform_plan)
from repro.core.cache import (SlotCache, clear_row, compact, empty_cache,
                              insert_row, insert_rows, pad_cache, sort_slots,
                              write_token)
from repro.core.kmeans import kmeans_1d, kmeans_1d_jax
from repro.core.paging import (KVPool, PagedTier, PagePool, pages_for,
                               pages_needed)
from repro.core.policies import (H2O, L2_NORM, POLICIES, SINK_H2O,
                                 SLIDING_WINDOW, STREAMING_LLM, PolicyConfig,
                                 key_norms)

__all__ = [
    "BudgetPlan", "allocate", "allocate_zigzag", "uniform_plan",
    "plan_cache_bytes", "plan_pool_pages",
    "SlotCache", "compact", "empty_cache", "pad_cache", "write_token",
    "insert_row", "insert_rows", "clear_row", "sort_slots",
    "KVPool", "PagedTier", "PagePool", "pages_for", "pages_needed",
    "kmeans_1d", "kmeans_1d_jax",
    "PolicyConfig", "POLICIES", "SLIDING_WINDOW", "STREAMING_LLM", "H2O",
    "SINK_H2O", "L2_NORM", "key_norms",
]
