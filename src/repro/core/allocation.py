"""SqueezeAttention Algorithm 1: layer-wise KV budget reallocation.

Given per-layer cosine similarities (measured during prefill), cluster the
layers into 3 groups; the group with the *highest* similarity (G3 — attention
barely changes the residual stream there) gets its budget squeezed to
``b_init * p`` and the freed tokens are redistributed uniformly to G1∪G2:

    b_unimportant = b_init * p
    b_important   = (n_layer*b_init - |G3|*b_init*p) / (|G1| + |G2|)

Total budget is conserved exactly (paper §A.2).

TPU adaptation (DESIGN.md §3): XLA needs static cache shapes, so budgets are
quantized to multiples of ``bucket`` with the sub-bucket remainder reported
as ``slack``.  The grouped layout (every layer is in one of a small number of
budget *tiers*) lets the decode step run one uniform scan per tier instead of
n_layer heterogeneous bodies.

Beyond the paper's 2-group split, `allocate_zigzag` maps per-layer
sensitivity onto ``n_tiers`` budget levels (ZigZagKV, arXiv:2412.09036,
realized as rank-quantile tiers with exact bucket-unit conservation);
`uniform_plan` and `allocate` are the 1-tier / 2-tier special cases of the
same `BudgetPlan` record.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

from repro.core.kmeans import kmeans_1d


@dataclasses.dataclass(frozen=True)
class BudgetPlan:
    """Static description of a layer-wise KV budget allocation.

    A plan is a list of budget *tiers*: ``tier_budgets[t]`` slots for every
    layer ``l`` with ``tier_of[l] == t``.  Tier ids are ordered by budget —
    tier 0 is the largest (most sensitive layers), the last tier the most
    squeezed.  ``uniform_plan`` is the 1-tier case, the paper's Algorithm 1
    (`allocate`) the 2-tier case, `allocate_zigzag` the N-tier case.

    ``slack`` is the budget the bucket quantization could not place:
    ``total + slack == n_layers * b_init`` holds exactly (slack may be
    negative when the ``min_budget`` floor forces an overshoot).
    """
    n_layers: int
    b_init: int                 # uniform per-layer budget before reallocation
    p: float
    group: tuple                # per-layer diagnostic label (kmeans id / tier)
    tier_of: tuple              # per-layer tier id; tier 0 = biggest budget
    tier_budgets: tuple         # per-tier slot counts, non-increasing
    centers: tuple              # kmeans centers / tier means (diagnostics)
    slack: int = 0              # n_layers*b_init - total (quantization slack)

    # ---- N-tier accessors -------------------------------------------------
    @property
    def n_tiers(self) -> int:
        return len(self.tier_budgets)

    @property
    def tier_counts(self) -> tuple:
        return tuple(sum(1 for q in self.tier_of if q == t)
                     for t in range(self.n_tiers))

    def layer_tiers(self):
        """Per-tier ``(budget, layer_indices)`` preserving model layer order."""
        return tuple(
            (int(self.tier_budgets[t]),
             tuple(l for l, q in enumerate(self.tier_of) if q == t))
            for t in range(self.n_tiers))

    @property
    def budgets(self) -> np.ndarray:
        bt = np.asarray(self.tier_budgets, np.int64)
        return bt[np.asarray(self.tier_of, np.int64)]

    @property
    def total(self) -> int:
        return int(self.budgets.sum())

    # ---- legacy 2-tier views (analysis / launcher prints) -----------------
    @property
    def is_small(self) -> tuple:
        """Per-layer bool: True -> most-squeezed tier (False for 1 tier)."""
        if self.n_tiers <= 1:
            return tuple([False] * self.n_layers)
        last = self.n_tiers - 1
        return tuple(q == last for q in self.tier_of)

    @property
    def b_small(self) -> int:
        return int(self.tier_budgets[-1])

    @property
    def b_big(self) -> int:
        return int(self.tier_budgets[0])

    @property
    def n_small(self) -> int:
        return int(sum(self.is_small))

    @property
    def n_big(self) -> int:
        return self.n_layers - self.n_small

    def layer_order(self):
        """(big_indices, small_indices) preserving model layer order."""
        small = [i for i, s in enumerate(self.is_small) if s]
        big = [i for i, s in enumerate(self.is_small) if not s]
        return tuple(big), tuple(small)

    def describe(self) -> str:
        return " + ".join(f"{n}x{b}" for (b, ls), n
                          in zip(self.layer_tiers(), self.tier_counts))


def uniform_plan(n_layers: int, b_init: int) -> BudgetPlan:
    """Baseline: every layer keeps b_init (sequence-wise-only compression)."""
    return BudgetPlan(
        n_layers=n_layers, b_init=b_init, p=1.0,
        group=tuple([1] * n_layers), tier_of=tuple([0] * n_layers),
        tier_budgets=(b_init,), centers=(0.0,), slack=0,
    )


def allocate(
    cos_sims: Sequence[float],
    b_init: int,
    p: float = 0.35,
    k: int = 3,
    bucket: int = 16,
    min_budget: int = 16,
) -> BudgetPlan:
    """Algorithm 1, lines 2–13: cosine sims -> per-layer budgets (2 tiers)."""
    cs = np.asarray(cos_sims, np.float64).reshape(-1)
    n = cs.shape[0]
    assert n >= 1
    if p >= 1.0 or n < k:
        return uniform_plan(n, b_init)
    labels, centers = kmeans_1d(cs, k=k)
    is_small = labels == (k - 1)        # G3: highest cosine sim = least important
    n_small = int(is_small.sum())
    n_big = n - n_small
    if n_small == 0 or n_big == 0:      # degenerate clustering -> no reallocation
        return uniform_plan(n, b_init)

    b_small = b_init * p

    # ---- bucket quantization (static-shape requirement) ----------------------
    b_small_q = max(min_budget, int(b_small // bucket) * bucket)
    freed = n * b_init - n_small * b_small_q
    b_big_q = max(min_budget, (freed // n_big) // bucket * bucket)

    return BudgetPlan(
        n_layers=n, b_init=b_init, p=p,
        group=tuple(int(v) for v in labels),
        tier_of=tuple(int(v) for v in is_small),
        tier_budgets=(int(b_big_q), int(b_small_q)),
        centers=tuple(float(c) for c in centers),
        slack=n * b_init - (n_small * int(b_small_q) + n_big * int(b_big_q)),
    )


def allocate_zigzag(
    cos_sims: Sequence[float],
    b_init: int,
    n_tiers: int = 4,
    bucket: int = 16,
    min_budget: int = 16,
) -> BudgetPlan:
    """N-tier layer-wise budgets (ZigZagKV mode, arXiv:2412.09036).

    Per-layer sensitivity ``u = 1 - cos_sim`` (a layer whose attention barely
    moves the residual stream tolerates a small cache) is mapped onto
    ``n_tiers`` rank-quantile tiers, and the total budget
    ``n_layers * b_init`` is split across layers *proportionally to tier
    sensitivity* in whole ``bucket`` units:

      1. tiers = rank quantiles of u (tier 0 = most sensitive layers);
      2. each tier's per-layer budget = ``min_budget`` floor + its
         sensitivity share of the remaining bucket units, rounded down;
      3. leftover whole buckets go one-per-layer to the most sensitive
         layers (which may split a tier into two adjacent budget levels);
      4. equal-budget tiers merge.

    Conservation is exact in bucket units: ``plan.total + plan.slack ==
    n_layers * b_init`` with ``slack = (n_layers * b_init) % bucket`` — zero
    whenever ``bucket`` divides the total, e.g. whenever it divides
    ``b_init``.  (The one exception: if the ``min_budget`` floor alone
    exceeds the total, every layer gets the floor and slack goes negative,
    mirroring `allocate`'s floor overshoot.)
    """
    cs = np.asarray(cos_sims, np.float64).reshape(-1)
    n = cs.shape[0]
    assert n >= 1
    assert bucket >= 1 and min_budget >= 1
    if n_tiers <= 1 or n < n_tiers:
        return uniform_plan(n, b_init)
    u = np.clip(1.0 - cs, 0.0, None)          # per-layer sensitivity
    if float(u.max() - u.min()) < 1e-9 or float(u.sum()) <= 0.0:
        return uniform_plan(n, b_init)        # flat sensitivity: nothing to move

    m_min = -(-min_budget // bucket)          # floor, in bucket units
    M = (n * b_init) // bucket                # total bucket units to place
    slack0 = n * b_init - M * bucket          # sub-bucket remainder
    if M <= n * m_min:                        # floor dominates: uniform at floor
        b = m_min * bucket
        return BudgetPlan(
            n_layers=n, b_init=b_init, p=b / b_init,
            group=tuple([0] * n), tier_of=tuple([0] * n),
            tier_budgets=(b,), centers=(float(cs.mean()),),
            slack=n * b_init - n * b)

    order = np.argsort(-u, kind="stable")     # most sensitive first
    bounds = [i * n // n_tiers for i in range(n_tiers + 1)]
    tier_of = np.zeros(n, np.int64)
    for t in range(n_tiers):
        tier_of[order[bounds[t]:bounds[t + 1]]] = t

    # sensitivity-proportional split of the units above the floor
    W = np.array([u[tier_of == t].sum() for t in range(n_tiers)])
    cnt = np.array([int((tier_of == t).sum()) for t in range(n_tiers)])
    E = M - n * m_min
    share = m_min + E * (W / W.sum()) / cnt   # per-layer units, per tier
    m_tier = np.floor(share).astype(np.int64)
    m_tier = np.sort(m_tier)[::-1]            # monotone non-increasing by tier

    # leftover whole buckets: one per layer, most sensitive layers first
    m_layer = m_tier[tier_of]
    D = int(M - m_layer.sum())
    assert D >= 0
    m_layer[order[:D]] += 1

    # rebuild tiers from the distinct realized budgets (merges equal tiers,
    # splits the tier the leftover pass straddled)
    levels = np.unique(m_layer)[::-1]
    tier_of_f = np.searchsorted(-levels, -m_layer)
    budgets = tuple(int(v * bucket) for v in levels)
    centers = tuple(float(cs[tier_of_f == t].mean())
                    for t in range(len(levels)))
    plan = BudgetPlan(
        n_layers=n, b_init=b_init, p=budgets[-1] / b_init,
        group=tuple(int(v) for v in tier_of_f),
        tier_of=tuple(int(v) for v in tier_of_f),
        tier_budgets=budgets, centers=centers, slack=int(slack0))
    assert plan.total + plan.slack == n * b_init
    return plan


def allocate_jax(cos_sims, b_init: int, p: float = 0.35, k: int = 3,
                 bucket: int = 1, min_budget: int = 1):
    """jit-able Algorithm 1 (beyond-paper): returns per-layer budgets as a
    traced array so allocation can fuse into the prefill graph — useful when
    budgets feed *data* (masking/priorities) rather than static shapes.

    Returns (budgets [n] int32, is_small [n] bool).  The static-shape
    engine still uses the host `allocate` (shapes must be concrete); this
    path powers on-device telemetry and the property tests that pin the two
    implementations together.  Bucket quantization and the ``min_budget``
    floor mirror the host arithmetic exactly, so ``budgets`` equals
    ``allocate(...).budgets`` for any (b_init, p, bucket, min_budget).
    """
    import jax.numpy as jnp

    from repro.core.kmeans import kmeans_1d_jax

    cs = jnp.asarray(cos_sims, jnp.float32).reshape(-1)
    n = cs.shape[0]
    labels, _ = kmeans_1d_jax(cs, k=k)
    is_small = labels == (k - 1)
    n_small = is_small.sum().astype(jnp.int32)
    n_big = jnp.int32(n) - n_small
    degenerate = (n_small == 0) | (n_big == 0) | (p >= 1.0) | (n < k)

    # host parity: b_small = b_init * p quantized down to a bucket multiple,
    # floored at min_budget; freed tokens to the big tier, same quantization
    b_small_q = jnp.maximum(
        jnp.int32(min_budget),
        jnp.floor(jnp.float32(b_init * p) / bucket).astype(jnp.int32) * bucket)
    freed = jnp.int32(n * b_init) - n_small * b_small_q
    b_big_q = jnp.maximum(
        jnp.int32(min_budget),
        (freed // jnp.maximum(n_big, 1)) // bucket * bucket)
    budgets = jnp.where(degenerate, jnp.int32(b_init),
                        jnp.where(is_small, b_small_q, b_big_q))
    return budgets, is_small & ~degenerate


def plan_cache_bytes(plan: BudgetPlan, batch: int, kv_heads: int, head_dim: int,
                     bytes_per_el: int = 2) -> int:
    """Physical KV arena size implied by a plan (both K and V)."""
    return 2 * plan.total * batch * kv_heads * head_dim * bytes_per_el


# --------------------------------------------------------------------------- #
# paged arenas: tier budgets as page quotas (core/paging.py)
# --------------------------------------------------------------------------- #

def page_quota(budget: int, page_size: int) -> int:
    """Pages one (layer, row) of a `budget`-slot tier can occupy at most:
    ceil(budget / page_size).  Under paging this IS the tier budget — the
    arena's slot count stays `budget`, but the quota is only *reached* by
    rows that actually fill the arena; `paging.pages_needed` gives the
    tight per-request bound below it."""
    assert page_size > 0
    return -(-int(budget) // int(page_size))


def plan_page_quota(plan: BudgetPlan, page_size: int) -> int:
    """Worst-case pages ONE row needs across all layers of a plan — the
    paged reading of the allocator's output: each tier's layers hold
    ``page_quota(tier_budget)`` pages."""
    return sum(len(layers) * page_quota(b, page_size)
               for b, layers in plan.layer_tiers())


def plan_pool_pages(plan: BudgetPlan, batch: int, page_size: int,
                    prefix_pages: int = 0, overcommit: float = 1.0) -> int:
    """Global pool size for a paged engine: the reserved null page, the
    row-demand region, and the prefix cache's residency headroom.

    ``overcommit = 1.0`` sizes the row region for the worst case (every row
    at quota) so admission-time allocation always succeeds.  ``overcommit <
    1.0`` is the capacity win paging buys (DESIGN.md §5): squeezed layers'
    `pages_needed` release means typical rows use well under quota, so a
    smaller pool hosts the same — or more — resident rows, with the engine's
    watermark backpressure / preemption ladder absorbing the worst case.
    The row region never shrinks below one full row quota, so a lone
    request can always eventually admit (liveness floor)."""
    overcommit = float(overcommit)
    if overcommit <= 0.0:
        raise ValueError(f"overcommit must be positive, got {overcommit}")
    quota = plan_page_quota(plan, page_size)
    rows_region = max(quota, math.ceil(batch * quota * overcommit))
    return 1 + rows_region + int(prefix_pages)


# --------------------------------------------------------------------------- #
# recurrent layers: the fixed-cost tier
# --------------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class RecurrentTier:
    """The degenerate budget tier of SSM / hybrid models.

    A recurrent layer's state is O(1) in sequence length — its "budget" is a
    constant that Algorithm 1 can neither squeeze nor boost.  The allocator
    therefore treats recurrent layers as a *fixed-cost* tier: they are
    excluded from the KMeans clustering and the budget split entirely (a
    hybrid model splits ``n_attn * b_init`` across its attention layers
    only), and this record carries the per-row cost the tier pins so memory
    accounting (`total_state_bytes`) stays honest about it.
    """
    n_layers: int
    state_elems: int           # per-layer per-row SSD state elements (H*P*N)
    conv_elems: int            # per-layer per-row conv-tail elements ((W-1)*C)

    @property
    def is_empty(self) -> bool:
        return self.n_layers == 0

    def bytes_per_row(self, state_bytes: int = 4, act_bytes: int = 2) -> int:
        """Fixed state bytes one batch row pins across all recurrent layers
        (SSD state accumulates fp32; the conv tail is model-dtype acts)."""
        return self.n_layers * (self.state_elems * state_bytes
                                + self.conv_elems * act_bytes)


def recurrent_tier(cfg) -> RecurrentTier:
    """Fixed-cost tier of a `ModelConfig` (empty for attention-only models)."""
    # deferred import: core stays importable without the models package at
    # module-load time, and the conv layout has exactly one owner (ssm.py)
    from repro.models.ssm import conv_channels

    if not (cfg.is_ssm_only or cfg.is_hybrid):
        return RecurrentTier(0, 0, 0)
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    conv = (cfg.ssm_conv_width - 1) * conv_channels(cfg)
    return RecurrentTier(cfg.n_layers, H * P * N, conv)


def total_state_bytes(plan: BudgetPlan, rtier: RecurrentTier, batch: int,
                      kv_heads: int, head_dim: int,
                      kv_bytes_per_el: int = 2) -> int:
    """Budgeted KV arenas + the fixed recurrent tier: the full per-batch
    decode-state footprint (the 2D budget picture for hybrid families)."""
    kv = 0 if plan is None else plan_cache_bytes(
        plan, batch, kv_heads, head_dim, kv_bytes_per_el)
    return kv + batch * rtier.bytes_per_row(act_bytes=kv_bytes_per_el)
