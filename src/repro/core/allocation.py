"""SqueezeAttention Algorithm 1: layer-wise KV budget reallocation.

Given per-layer cosine similarities (measured during prefill), cluster the
layers into 3 groups; the group with the *highest* similarity (G3 — attention
barely changes the residual stream there) gets its budget squeezed to
``b_init * p`` and the freed tokens are redistributed uniformly to G1∪G2:

    b_unimportant = b_init * p
    b_important   = (n_layer*b_init - |G3|*b_init*p) / (|G1| + |G2|)

Total budget is conserved exactly (paper §A.2).

TPU adaptation (DESIGN.md §3): XLA needs static cache shapes, so the two
resulting budgets are quantized to multiples of ``bucket`` — conserving the
total by construction (we round the small budget down and give the remainder
to the big group, then round the big budget down; the slack is reported so the
engine can account for it).  The grouped layout (every layer is in one of two
budget tiers) also lets the decode step run two uniform scans instead of
n_layer heterogeneous bodies.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

from repro.core.kmeans import kmeans_1d


@dataclasses.dataclass(frozen=True)
class BudgetPlan:
    """Static description of a layer-wise KV budget allocation."""
    n_layers: int
    b_init: int                 # uniform per-layer budget before reallocation
    p: float
    group: tuple                # per-layer group id (0/1/2), 2 = least important
    is_small: tuple             # per-layer bool: True -> squeezed budget
    b_small: int                # slots for squeezed layers
    b_big: int                  # slots for boosted layers
    centers: tuple              # kmeans centers (diagnostics)

    @property
    def n_small(self) -> int:
        return int(sum(self.is_small))

    @property
    def n_big(self) -> int:
        return self.n_layers - self.n_small

    @property
    def budgets(self) -> np.ndarray:
        return np.where(np.asarray(self.is_small), self.b_small, self.b_big)

    @property
    def total(self) -> int:
        return int(self.budgets.sum())

    def layer_order(self):
        """(big_indices, small_indices) preserving model layer order."""
        small = [i for i, s in enumerate(self.is_small) if s]
        big = [i for i, s in enumerate(self.is_small) if not s]
        return tuple(big), tuple(small)


def uniform_plan(n_layers: int, b_init: int) -> BudgetPlan:
    """Baseline: every layer keeps b_init (sequence-wise-only compression)."""
    return BudgetPlan(
        n_layers=n_layers, b_init=b_init, p=1.0,
        group=tuple([1] * n_layers), is_small=tuple([False] * n_layers),
        b_small=b_init, b_big=b_init, centers=(0.0,),
    )


def allocate(
    cos_sims: Sequence[float],
    b_init: int,
    p: float = 0.35,
    k: int = 3,
    bucket: int = 16,
    min_budget: int = 16,
) -> BudgetPlan:
    """Algorithm 1, lines 2–13: cosine sims -> per-layer budgets."""
    cs = np.asarray(cos_sims, np.float64).reshape(-1)
    n = cs.shape[0]
    assert n >= 1
    if p >= 1.0 or n < k:
        return uniform_plan(n, b_init)
    labels, centers = kmeans_1d(cs, k=k)
    is_small = labels == (k - 1)        # G3: highest cosine sim = least important
    n_small = int(is_small.sum())
    n_big = n - n_small
    if n_small == 0 or n_big == 0:      # degenerate clustering -> no reallocation
        return uniform_plan(n, b_init)

    b_small = b_init * p
    b_big = (n * b_init - n_small * b_small) / n_big

    # ---- bucket quantization (static-shape requirement) ----------------------
    b_small_q = max(min_budget, int(b_small // bucket) * bucket)
    freed = n * b_init - n_small * b_small_q
    b_big_q = max(min_budget, int((freed / n_big) // bucket) * bucket)

    return BudgetPlan(
        n_layers=n, b_init=b_init, p=p,
        group=tuple(int(v) for v in labels),
        is_small=tuple(bool(v) for v in is_small),
        b_small=int(b_small_q), b_big=int(b_big_q),
        centers=tuple(float(c) for c in centers),
    )


def allocate_jax(cos_sims, b_init: int, p: float = 0.35, k: int = 3):
    """jit-able Algorithm 1 (beyond-paper): returns per-layer budgets as a
    traced array so allocation can fuse into the prefill graph — useful when
    budgets feed *data* (masking/priorities) rather than static shapes.

    Returns (budgets [n] float32, is_small [n] bool).  The static-shape
    engine still uses the host `allocate` (shapes must be concrete); this
    path powers on-device telemetry and the property tests that pin the two
    implementations together.
    """
    import jax.numpy as jnp

    from repro.core.kmeans import kmeans_1d_jax

    cs = jnp.asarray(cos_sims, jnp.float32).reshape(-1)
    n = cs.shape[0]
    labels, _ = kmeans_1d_jax(cs, k=k)
    is_small = labels == (k - 1)
    n_small = is_small.sum()
    n_big = n - n_small
    b_small = b_init * p
    b_big = jnp.where(n_big > 0,
                      (n * b_init - n_small * b_small) / jnp.maximum(n_big, 1),
                      b_init)
    degenerate = (n_small == 0) | (n_big == 0)
    budgets = jnp.where(degenerate, jnp.full((n,), float(b_init)),
                        jnp.where(is_small, b_small, b_big))
    return budgets, is_small & ~degenerate


def plan_cache_bytes(plan: BudgetPlan, batch: int, kv_heads: int, head_dim: int,
                     bytes_per_el: int = 2) -> int:
    """Physical KV arena size implied by a plan (both K and V)."""
    slots = plan.n_small * plan.b_small + plan.n_big * plan.b_big
    return 2 * slots * batch * kv_heads * head_dim * bytes_per_el


# --------------------------------------------------------------------------- #
# paged arenas: tier budgets as page quotas (core/paging.py)
# --------------------------------------------------------------------------- #

def page_quota(budget: int, page_size: int) -> int:
    """Pages one (layer, row) of a `budget`-slot tier can occupy at most:
    ceil(budget / page_size).  Under paging this IS the tier budget — the
    arena's slot count stays `budget`, but the quota is only *reached* by
    rows that actually fill the arena; `paging.pages_needed` gives the
    tight per-request bound below it."""
    assert page_size > 0
    return -(-int(budget) // int(page_size))


def plan_page_quota(plan: BudgetPlan, page_size: int) -> int:
    """Worst-case pages ONE row needs across all layers of a plan — the
    paged reading of Algorithm 1's output: squeezed (G3) layers hold
    ``page_quota(b_small)`` pages, boosted layers ``page_quota(b_big)``."""
    return (plan.n_small * page_quota(plan.b_small, page_size)
            + plan.n_big * page_quota(plan.b_big, page_size))


def plan_pool_pages(plan: BudgetPlan, batch: int, page_size: int,
                    prefix_pages: int = 0, overcommit: float = 1.0) -> int:
    """Global pool size for a paged engine: the reserved null page, the
    row-demand region, and the prefix cache's residency headroom.

    ``overcommit = 1.0`` sizes the row region for the worst case (every row
    at quota) so admission-time allocation always succeeds.  ``overcommit <
    1.0`` is the capacity win paging buys (DESIGN.md §5): squeezed layers'
    `pages_needed` release means typical rows use well under quota, so a
    smaller pool hosts the same — or more — resident rows, with the engine's
    watermark backpressure / preemption ladder absorbing the worst case.
    The row region never shrinks below one full row quota, so a lone
    request can always eventually admit (liveness floor)."""
    overcommit = float(overcommit)
    if overcommit <= 0.0:
        raise ValueError(f"overcommit must be positive, got {overcommit}")
    quota = plan_page_quota(plan, page_size)
    rows_region = max(quota, math.ceil(batch * quota * overcommit))
    return 1 + rows_region + int(prefix_pages)


# --------------------------------------------------------------------------- #
# recurrent layers: the fixed-cost tier
# --------------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class RecurrentTier:
    """The degenerate budget tier of SSM / hybrid models.

    A recurrent layer's state is O(1) in sequence length — its "budget" is a
    constant that Algorithm 1 can neither squeeze nor boost.  The allocator
    therefore treats recurrent layers as a *fixed-cost* tier: they are
    excluded from the KMeans clustering and the budget split entirely (a
    hybrid model splits ``n_attn * b_init`` across its attention layers
    only), and this record carries the per-row cost the tier pins so memory
    accounting (`total_state_bytes`) stays honest about it.
    """
    n_layers: int
    state_elems: int           # per-layer per-row SSD state elements (H*P*N)
    conv_elems: int            # per-layer per-row conv-tail elements ((W-1)*C)

    @property
    def is_empty(self) -> bool:
        return self.n_layers == 0

    def bytes_per_row(self, state_bytes: int = 4, act_bytes: int = 2) -> int:
        """Fixed state bytes one batch row pins across all recurrent layers
        (SSD state accumulates fp32; the conv tail is model-dtype acts)."""
        return self.n_layers * (self.state_elems * state_bytes
                                + self.conv_elems * act_bytes)


def recurrent_tier(cfg) -> RecurrentTier:
    """Fixed-cost tier of a `ModelConfig` (empty for attention-only models)."""
    # deferred import: core stays importable without the models package at
    # module-load time, and the conv layout has exactly one owner (ssm.py)
    from repro.models.ssm import conv_channels

    if not (cfg.is_ssm_only or cfg.is_hybrid):
        return RecurrentTier(0, 0, 0)
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    conv = (cfg.ssm_conv_width - 1) * conv_channels(cfg)
    return RecurrentTier(cfg.n_layers, H * P * N, conv)


def total_state_bytes(plan: BudgetPlan, rtier: RecurrentTier, batch: int,
                      kv_heads: int, head_dim: int,
                      kv_bytes_per_el: int = 2) -> int:
    """Budgeted KV arenas + the fixed recurrent tier: the full per-batch
    decode-state footprint (the 2D budget picture for hybrid families)."""
    kv = 0 if plan is None else plan_cache_bytes(
        plan, batch, kv_heads, head_dim, kv_bytes_per_el)
    return kv + batch * rtier.bytes_per_row(act_bytes=kv_bytes_per_el)
