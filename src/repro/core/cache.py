"""Budgeted KV slot arenas: compaction (prefill -> budget) and decode updates.

A `SlotCache` is a fixed arena of `S` slots per attention layer.  Slots
remember the original token position (`pos`, -1 = empty) and the H2O
accumulated attention score.  Arenas are stacked over the layers of one
budget tier, so SqueezeAttention's two-tier allocation becomes two uniform
pytrees that `lax.scan` can carry.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.policies import (PolicyConfig, keep_priority, key_norms,
                                 uses_key_norms)


class SlotCache(NamedTuple):
    k: jnp.ndarray       # [L, B, S, Hkv, hd]
    v: jnp.ndarray       # [L, B, S, Hkv, hd]
    pos: jnp.ndarray     # [L, B, S] int32, -1 = empty
    score: jnp.ndarray   # [L, B, S] float32 accumulated attention mass

    @property
    def n_slots(self) -> int:
        return self.pos.shape[-1]

    @property
    def n_layers(self) -> int:
        return self.pos.shape[0]


def empty_cache(n_layers: int, batch: int, slots: int, kv_heads: int,
                head_dim: int, dtype=jnp.bfloat16) -> SlotCache:
    return SlotCache(
        k=jnp.zeros((n_layers, batch, slots, kv_heads, head_dim), dtype),
        v=jnp.zeros((n_layers, batch, slots, kv_heads, head_dim), dtype),
        pos=jnp.full((n_layers, batch, slots), -1, jnp.int32),
        score=jnp.zeros((n_layers, batch, slots), jnp.float32),
    )


def compact(
    pol: PolicyConfig,
    k: jnp.ndarray,        # [L, B, P, Hkv, hd] full prefill keys
    v: jnp.ndarray,
    pos: jnp.ndarray,      # [L, B, P] token positions (-1 for padding)
    score: jnp.ndarray,    # [L, B, P] prefill H2O column sums
    budget: int,
    t,                     # prompt length (scalar or [B])
) -> SlotCache:
    """Keep the top-`budget` slots by policy priority (prefill compaction).

    This is Algorithm 1 line 12 + the first `C_seq` application: the full
    prefill KV of a layer tier is squeezed into its allocated arena.

    Paged contract (core/paging.py): `top_k` returns indices in priority
    order and the `jnp.sort` restores ORIGINAL slot order, so the valid
    slots of a compacted row form a contiguous PREFIX of the arena whenever
    the input's valid slots did (the plain right-padded prefill layout).
    Decode then fills empties in index order (`write_token`: empties share
    priority -BIG and argmin takes the first), so a row that enters with
    `t` tokens and may write `max_new - 1` more never touches a slot past
    ``min(budget, t + max_new - 1)`` — `paging.pages_needed` turns that into
    a per-row page count and sequence-wise squeezing releases the tail
    pages to the pool instead of leaving torn half-pages resident.  The
    context-prefill layout (valid ctx | ctx padding | valid suffix | pad)
    breaks the prefix precondition; `sort_slots` restores it after
    compaction.
    """
    P = pos.shape[-1]
    assert budget <= P, f"budget {budget} > prefill len {P}: use pad_cache"
    pri = keep_priority(pol, pos, score, t, budget)
    _, idx = jax.lax.top_k(pri, budget)                       # [L, B, budget]
    idx_sorted = jnp.sort(idx, axis=-1)                       # keep original order
    gather = lambda a: jnp.take_along_axis(a, idx_sorted.reshape(
        idx_sorted.shape + (1,) * (a.ndim - idx_sorted.ndim)).astype(jnp.int32), axis=2)
    return SlotCache(
        k=gather(k), v=gather(v),
        pos=jnp.take_along_axis(pos, idx_sorted, axis=-1),
        score=jnp.take_along_axis(score, idx_sorted, axis=-1),
    )


def sort_slots(cache: SlotCache) -> SlotCache:
    """Canonicalize slot order: ascending position, empties last.

    `compact` preserves the INPUT's slot order, which for the plain prefill
    layout already is position order with empties trailing.  The
    context-prefill layout interleaves differently (gathered prefix pages,
    then the ctx region's padding, then the computed suffix), so when the
    budget exceeds the valid count, `compact`'s keep-set retains ctx-region
    empties BETWEEN the ctx and suffix valids.  A stable sort on
    ``pos (empties -> +inf)`` restores the exact slot order the plain path
    produces — making paged prefix-hit admissions slot-for-slot identical
    to cold admissions (and re-establishing the valid-prefix invariant that
    `paging.pages_needed` relies on).  Empties are interchangeable (pos -1,
    score 0, masked k/v), so stability only matters for determinism.
    """
    big = jnp.iinfo(jnp.int32).max
    idx = jnp.argsort(jnp.where(cache.pos < 0, big, cache.pos),
                      axis=-1, stable=True).astype(jnp.int32)

    def gather(a):
        ix = idx.reshape(idx.shape + (1,) * (a.ndim - idx.ndim))
        return jnp.take_along_axis(a, ix, axis=2)

    return SlotCache(k=gather(cache.k), v=gather(cache.v),
                     pos=jnp.take_along_axis(cache.pos, idx, axis=-1),
                     score=jnp.take_along_axis(cache.score, idx, axis=-1))


def pad_cache(cache: SlotCache, slots: int) -> SlotCache:
    """Grow an arena to `slots` (budget > prompt length): pad with empties."""
    extra = slots - cache.n_slots
    if extra <= 0:
        return cache
    L, B, S = cache.pos.shape
    padkv = jnp.zeros(cache.k.shape[:2] + (extra,) + cache.k.shape[3:], cache.k.dtype)
    return SlotCache(
        k=jnp.concatenate([cache.k, padkv], axis=2),
        v=jnp.concatenate([cache.v, padkv], axis=2),
        pos=jnp.concatenate([cache.pos, jnp.full((L, B, extra), -1, jnp.int32)], axis=2),
        score=jnp.concatenate([cache.score, jnp.zeros((L, B, extra), jnp.float32)], axis=2),
    )


def insert_row(arena: SlotCache, row_cache: SlotCache, row) -> SlotCache:
    """Write one request's [L, 1, S, ...] cache into batch row `row`.

    `row` may be a traced int32 scalar: continuous-batching admission compiles
    ONE insert executable per (max_concurrency, tier size) and reuses it for
    every slot — admitting a request never retraces the decode step.
    """
    def upd(a, u):
        return jax.lax.dynamic_update_slice_in_dim(a, u.astype(a.dtype),
                                                   row, axis=1)
    return SlotCache(*(upd(a, u) for a, u in zip(tuple(arena),
                                                 tuple(row_cache))))


def insert_rows(arena: SlotCache, rows_cache: SlotCache, rows) -> SlotCache:
    """Scatter `n` requests' [L, n, S, ...] caches into batch rows `rows`.

    Batched-admission analogue of `insert_row`: `rows` is a traced int32
    vector, so one compiled scatter serves every combination of free slots.
    Row indices >= the arena batch are DROPPED (``mode="drop"``) — a partial
    admit batch pads with the sentinel index `max_concurrency` and its pad
    rows never land.
    """
    def upd(a, u):
        return a.at[:, rows].set(u.astype(a.dtype), mode="drop")
    return SlotCache(*(upd(a, u) for a, u in zip(tuple(arena),
                                                 tuple(rows_cache))))


def clear_row(arena: SlotCache, row) -> SlotCache:
    """Mark every slot of batch row `row` empty (pos -1, score 0).

    Called at retirement so a recycled row carries no stale positions; the
    k/v bits are left in place — empty slots are masked out of attention by
    `pos < 0`, so only the metadata needs resetting.
    """
    L, _, S = arena.pos.shape
    return arena._replace(
        pos=jax.lax.dynamic_update_slice_in_dim(
            arena.pos, jnp.full((L, 1, S), -1, arena.pos.dtype), row, axis=1),
        score=jax.lax.dynamic_update_slice_in_dim(
            arena.score, jnp.zeros((L, 1, S), arena.score.dtype), row, axis=1),
    )


# --------------------------------------------------------------------------- #
# recurrent-state arenas (SSM / hybrid rows)
# --------------------------------------------------------------------------- #
# A recurrent layer's "KV cache" is a fixed-size state — the degenerate budget
# tier (DESIGN.md §4).  Continuous batching stores those states in the same
# [L, B, ...] stacked-arena layout as the KV tiers (batch on axis 1), and the
# three functions below are the exact counterparts of insert_row /
# insert_rows / clear_row: traced row indices, one compiled executable per
# arena shape, drop-sentinel scatter for pad rows of a partial admit batch.

def insert_state_row(arena: jnp.ndarray, row_state: jnp.ndarray,
                     row) -> jnp.ndarray:
    """Write one request's [L, 1, ...] recurrent state into batch row `row`.

    `row` may be a traced int32 scalar (same no-retrace discipline as
    `insert_row`)."""
    return jax.lax.dynamic_update_slice_in_dim(
        arena, row_state.astype(arena.dtype), row, axis=1)


def insert_state_rows(arena: jnp.ndarray, rows_state: jnp.ndarray,
                      rows) -> jnp.ndarray:
    """Scatter `n` requests' [L, n, ...] recurrent states into rows `rows`.

    Row indices >= the arena batch are DROPPED (``mode="drop"``), mirroring
    `insert_rows`: a partial admit batch pads with the sentinel index
    `max_concurrency` and its pad rows never land."""
    return arena.at[:, rows].set(rows_state.astype(arena.dtype), mode="drop")


def clear_state_row(arena: jnp.ndarray, row) -> jnp.ndarray:
    """Zero batch row `row` of a recurrent-state arena.

    Unlike KV slots (where stale k/v bits are masked by ``pos < 0``), a
    recurrent state has no per-slot emptiness sentinel — the whole row is
    the state — so retirement really zeroes it.  Together with the decode
    step freezing inactive rows, a cleared row stays exactly zero until a
    new request is inserted (asserted by tests/test_continuous_ssm.py)."""
    shape = (arena.shape[0], 1) + arena.shape[2:]
    return jax.lax.dynamic_update_slice_in_dim(
        arena, jnp.zeros(shape, arena.dtype), row, axis=1)


def gather_row_segments(arr: jnp.ndarray, rows, starts, size: int,
                        fill) -> jnp.ndarray:
    """Strided-slice gather from a packed-prefill layout (DESIGN.md §5).

    ``arr`` is [L, R, P, ...] stacked per packed row; request ``i`` owns the
    span ``arr[:, rows[i], starts[i] : starts[i]+size]``.  Returns the
    request-shaped [L, n, size, ...] stack the existing
    `Engine.build_state` → `insert_rows` admission path consumes.

    ``rows``/``starts`` are traced int32 vectors — one compiled gather per
    (R, P, n, size) serves every packing outcome.  The P axis is pre-padded
    with ``fill`` so a segment near the row's end slices into inert filler
    (pos fill = -1 reads as EMPTY slots) instead of `dynamic_slice` clamping
    back into a neighbour's tokens.
    """
    pad = [(0, 0), (0, 0), (0, size)] + [(0, 0)] * (arr.ndim - 3)
    ap = jnp.pad(arr, pad, constant_values=fill)
    sel = ap[:, rows]                                # [L, n, P+size, ...]

    def slice_one(a, s):                             # a: [L, P+size, ...]
        return jax.lax.dynamic_slice_in_dim(a, s, size, axis=1)

    return jax.vmap(slice_one, in_axes=(1, 0), out_axes=1)(sel, starts)


def write_token(
    pol: PolicyConfig,
    layer_cache: SlotCache,    # UNstacked: k/v [B, S, Hkv, hd], pos/score [B, S]
    k_new: jnp.ndarray,        # [B, 1, Hkv, hd]
    v_new: jnp.ndarray,
    t: jnp.ndarray,            # [B] position of the new token
    slot_probs: jnp.ndarray,   # [B, S+1] attention mass (incl. the new token)
) -> SlotCache:
    """Evict argmin(priority) and write the new token there (Alg. 1 line 17).

    Also folds the step's attention mass into the H2O scores — the fused
    statistic the Pallas decode kernel produces for free.
    """
    k, v, pos, score = layer_cache
    pos, score, victim = write_token_meta(pol, pos, score, t, slot_probs,
                                          k_new=k_new)
    b_idx = jnp.arange(pos.shape[0])
    k = k.at[b_idx, victim].set(k_new[:, 0])
    v = v.at[b_idx, victim].set(v_new[:, 0])
    return SlotCache(k, v, pos, score)


def write_token_meta(
    pol: PolicyConfig,
    pos: jnp.ndarray,          # [B, S]
    score: jnp.ndarray,        # [B, S]
    t: jnp.ndarray,            # [B]
    slot_probs: jnp.ndarray,   # [B, S+1]
    k_new: jnp.ndarray = None,  # [B, 1, Hkv, hd] (l2_norm slot score)
):
    """The metadata half of `write_token`: score fold, victim selection,
    pos/score update.  Returns ``(pos, score, victim [B])``.

    Shared with the paged decode path (`serving/decode.py`), where the k/v
    write cannot happen in place — the victim slot lives at
    ``(tbl[victim // page_size], victim % page_size)`` of the global pool,
    so the layer scan emits a write RECORD and the pool is updated in one
    batched scatter afterwards (`paging.write_decode_records`).  Keeping
    victim selection in one function is what makes paged and contiguous
    decode bit-identical: same pos/score stream -> same victims -> same
    arena contents, wherever the bytes live.

    Under `l2_norm` the score channel holds the slot's static ||K||_2:
    nothing accumulates (the H2O fold is skipped entirely) and the new
    token's score is its own key norm.
    """
    if uses_key_norms(pol):
        assert k_new is not None, "l2_norm needs k_new for the slot score"
        new_score = key_norms(k_new[:, 0])                    # [B]
    else:
        score = score + slot_probs[:, :-1]
        new_score = slot_probs[:, -1]
    pri = keep_priority(pol, pos, score, t, pos.shape[-1])    # [B, S]
    victim = jnp.argmin(pri, axis=-1)                         # [B]
    b_idx = jnp.arange(pos.shape[0])
    pos = pos.at[b_idx, victim].set(t.astype(jnp.int32))
    score = score.at[b_idx, victim].set(new_score)
    return pos, score, victim
