"""1-D KMeans (Lloyd's) for layer-importance clustering.

The paper clusters `n_layer` scalar cosine similarities into k=3 groups
(SqueezeAttention Algorithm 1, line 5).  The input is tiny (16–94 scalars) so
Lloyd's with quantile init converges in a handful of iterations and is exact
for our purposes.  Two implementations:

  * `kmeans_1d`      — host-side numpy (used by the serving engine between the
                       prefill and decode jit boundaries; matches the paper's
                       one-time host-side cost, Table 5).
  * `kmeans_1d_jax`  — pure-jnp, jit/vmap-able (used inside fused
                       prefill+allocate graphs and for property tests).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp


def _init_centers(x, k):
    # evenly spaced over the value range: deterministic and robust to the
    # skewed cluster sizes typical of layer similarities (a few special
    # layers + one big high-similarity mass)
    lo, hi = float(x.min()), float(x.max())
    qs = lo + (np.arange(k) + 0.5) / k * max(hi - lo, 1e-9)
    return qs


def kmeans_1d(x: np.ndarray, k: int = 3, iters: int = 25):
    """Returns (labels [n] int — sorted so cluster k-1 has the LARGEST center,
    centers [k])."""
    x = np.asarray(x, np.float64).reshape(-1)
    n = x.shape[0]
    if n <= k:  # degenerate: each point its own cluster, ordered
        order = np.argsort(np.argsort(x))
        return order.astype(np.int64), np.sort(x)
    c = _init_centers(x, k)
    for _ in range(iters):
        d = np.abs(x[:, None] - c[None, :])
        lab = d.argmin(1)
        newc = np.array([x[lab == j].mean() if (lab == j).any() else c[j]
                         for j in range(k)])
        if np.allclose(newc, c):
            c = newc
            break
        c = newc
    # canonical order: ascending center => label k-1 = highest cosine sim
    order = np.argsort(c)
    remap = np.empty(k, np.int64)
    remap[order] = np.arange(k)
    return remap[lab], c[order]


def kmeans_1d_jax(x: jnp.ndarray, k: int = 3, iters: int = 25):
    """jit-able variant; same canonical label order."""
    x = x.astype(jnp.float32).reshape(-1)
    lo, hi = x.min(), x.max()
    qs = lo + (jnp.arange(k, dtype=jnp.float32) + 0.5) / k \
        * jnp.maximum(hi - lo, 1e-9)

    def step(c, _):
        d = jnp.abs(x[:, None] - c[None, :])
        lab = d.argmin(1)
        onehot = jax.nn.one_hot(lab, k)                   # [n, k]
        cnt = onehot.sum(0)
        s = (onehot * x[:, None]).sum(0)
        newc = jnp.where(cnt > 0, s / jnp.clip(cnt, 1.0), c)
        return newc, None

    c, _ = jax.lax.scan(step, qs, None, length=iters)
    lab = jnp.abs(x[:, None] - c[None, :]).argmin(1)
    order = jnp.argsort(c)
    remap = jnp.zeros((k,), jnp.int32).at[order].set(jnp.arange(k, dtype=jnp.int32))
    return remap[lab], c[order]
