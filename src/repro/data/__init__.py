from repro.data.pipeline import ByteCorpus, DataConfig, batches
