"""Data pipeline: synthetic LM tasks + byte-level text corpus, deterministic
sharded batching.

No external datasets exist offline, so training examples use either
(a) procedurally generated sequence tasks with real learnable structure
    (copy / induction-head / modular arithmetic mixtures), or
(b) a byte-tokenized text corpus directory.

Both yield `TrainBatch`es and are reproducible from (seed, step) alone —
restarts resume exactly without data-state checkpoints.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Iterator, Optional

import numpy as np

from repro.training.train_step import TrainBatch


@dataclasses.dataclass(frozen=True)
class DataConfig:
    kind: str = "synthetic"       # synthetic | text
    seq_len: int = 256
    global_batch: int = 8
    vocab_size: int = 256
    seed: int = 1234
    text_path: Optional[str] = None
    # data-parallel sharding: this host yields rows [shard_id::n_shards]
    n_shards: int = 1
    shard_id: int = 0


# ------------------------------------------------------------------ synthetic
def _synthetic_batch(rng: np.random.Generator, cfg: DataConfig):
    """Mixture of structured tasks so a small model has something to learn:
       50% induction (`A B ... A -> B`), 30% copy-with-offset, 20% uniform."""
    B, S, V = cfg.global_batch, cfg.seq_len, cfg.vocab_size
    toks = rng.integers(2, V, size=(B, S), dtype=np.int64)
    kind = rng.random(B)
    # induction: repeat the first half
    half = S // 2
    ind = kind < 0.5
    toks[ind, half:half * 2] = toks[ind, :half]
    # copy-with-offset: x[t] = x[t-3]
    cpy = (kind >= 0.5) & (kind < 0.8)
    for off in (3,):
        rows = np.where(cpy)[0]
        for r in rows:
            toks[r, off:] = toks[r, :-off]
    tokens = toks[:, :-1].astype(np.int32)
    targets = toks[:, 1:].astype(np.int32)
    return tokens, targets


# ----------------------------------------------------------------------- text
class ByteCorpus:
    """Byte-level tokenizer over all files under `path` (vocab 256)."""

    def __init__(self, path: str):
        bufs = []
        for root, _, files in os.walk(path):
            for f in sorted(files):
                try:
                    with open(os.path.join(root, f), "rb") as fh:
                        bufs.append(np.frombuffer(fh.read(), np.uint8))
                except OSError:
                    continue
        if not bufs:
            raise FileNotFoundError(f"no readable files under {path}")
        self.data = np.concatenate(bufs).astype(np.int32)

    def sample(self, rng: np.random.Generator, batch: int, seq: int):
        starts = rng.integers(0, len(self.data) - seq - 1, size=batch)
        rows = np.stack([self.data[s:s + seq + 1] for s in starts])
        return rows[:, :-1], rows[:, 1:]


# ------------------------------------------------------------------- iterator
def batches(cfg: DataConfig) -> Iterator[TrainBatch]:
    corpus = ByteCorpus(cfg.text_path) if cfg.kind == "text" else None
    step = 0
    while True:
        rng = np.random.default_rng((cfg.seed, step))
        if corpus is not None:
            tokens, targets = corpus.sample(rng, cfg.global_batch, cfg.seq_len)
        else:
            tokens, targets = _synthetic_batch(rng, cfg)
        lo = cfg.shard_id * (len(tokens) // cfg.n_shards)
        hi = lo + len(tokens) // cfg.n_shards
        yield TrainBatch(tokens=tokens[lo:hi], targets=targets[lo:hi])
        step += 1
