"""Production meshes.

Target: TPU v5e pods — 256 chips (16x16) per pod, 2 pods for multi-pod runs.
Defined as functions so importing this module never touches jax device state
(the dry-run launcher must set XLA_FLAGS before the first jax call).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh with the production axis names (CPU tests/examples)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def batch_axes(mesh) -> tuple:
    """Axis names the global batch is sharded over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def model_size(mesh) -> int:
    return mesh.shape["model"]


def data_size(mesh) -> int:
    n = mesh.shape["data"]
    return n * mesh.shape.get("pod", 1)
