"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --reduced \
        --steps 200 --seq 256 --batch 8 --ckpt-dir /tmp/ckpt

On the production mesh this is the same code path the dry-run lowers
(train_step + sharded params); on this CPU container use --reduced (or
--preset 100m) sizes.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro import checkpoint as ckpt
from repro.configs import get_config, get_reduced
from repro.data import DataConfig, batches
from repro.models import init_params
from repro.training import (AdamWConfig, TrainBatch, init_opt_state,
                            train_step)


def preset_100m(arch: str):
    """~100M-param member of the arch's family (example end-to-end driver):
    12 layers x d_model 768 x d_ff 3072 + 8k vocab ~= 125M params dense."""
    cfg = get_reduced(arch)
    return dataclasses.replace(
        cfg, name=f"{arch}-100m", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=max(12 // max(cfg.n_heads // max(cfg.n_kv_heads, 1), 1), 1),
        head_dim=64, d_ff=3072 if cfg.d_ff else 0,
        vocab_size=8192,
        moe_d_ff=768 if cfg.is_moe else 0,
        n_experts=8 if cfg.is_moe else 0,
        experts_per_tok=2 if cfg.is_moe else 0,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--preset", default=None, choices=[None, "100m"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.preset == "100m":
        cfg = preset_100m(args.arch)
    elif args.reduced:
        cfg = get_reduced(args.arch)
    else:
        cfg = get_config(args.arch)
    print(f"arch={cfg.name} params={cfg.n_params()/1e6:.1f}M "
          f"active={cfg.n_active_params()/1e6:.1f}M")

    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    opt = init_opt_state(params)
    ocfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                       total_steps=args.steps)
    dcfg = DataConfig(seq_len=args.seq, global_batch=args.batch,
                      vocab_size=cfg.vocab_size, seed=args.seed)

    start = 0
    if args.ckpt_dir and (s := ckpt.latest_step(args.ckpt_dir)) is not None:
        state = ckpt.restore(args.ckpt_dir, s, {"params": params, "opt": opt})
        params, opt, start = state["params"], state["opt"], s
        print(f"resumed from step {s}")

    step_fn = jax.jit(lambda p, o, b: train_step(p, o, b, cfg, ocfg))
    it = batches(dcfg)
    for _ in range(start):     # deterministic data stream: skip to position
        next(it)
    t0 = time.perf_counter()
    for i in range(start, args.steps):
        batch = next(it)
        params, opt, m = step_fn(params, opt, batch)
        if (i + 1) % args.log_every == 0 or i == start:
            dt = time.perf_counter() - t0
            tps = (i + 1 - start) * args.batch * args.seq / max(dt, 1e-9)
            print(f"step {i+1:5d} loss={float(m['loss']):.4f} "
                  f"nll={float(m['nll']):.4f} gnorm={float(m['grad_norm']):.3f} "
                  f"lr={float(m['lr']):.2e} tok/s={tps:,.0f}", flush=True)
        if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
            ckpt.save(args.ckpt_dir, i + 1, {"params": params, "opt": opt})
            ckpt.prune(args.ckpt_dir, keep=2)
    if args.ckpt_dir:
        ckpt.save(args.ckpt_dir, args.steps, {"params": params, "opt": opt})
    print("done")


if __name__ == "__main__":
    main()
