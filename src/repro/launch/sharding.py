"""Sharding rules: parameter / optimizer / activation / cache PartitionSpecs.

Layout (MaxText-style 2-D):
  * params: FSDP on the ``data`` axis x tensor-parallel on ``model``.
    - attention: qkv projections shard (d_model->data, heads*hd->model),
      output projection the transpose.
    - MoE: experts shard on ``model`` when divisible, otherwise the expert
      hidden dim does (mixtral's 8 experts on a 16-way axis).
    - embeddings: vocab on ``model`` when divisible, else d_model.
  * batch: (``pod``, ``data``); the pod axis is pure data parallelism.
  * KV arenas: kv-heads on ``model`` when divisible (else head_dim); slots
    shard on ``data`` when the batch can't use it (long_500k, batch=1).
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig
from repro.launch.mesh import batch_axes, model_size


def _path_str(path) -> str:
    return "/".join(
        str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
        for p in path)


def _vocab_spec(cfg, mesh, transpose=False):
    if cfg.vocab_size % model_size(mesh) == 0:
        return P("data", "model") if transpose else P("model", "data")
    return P("model", None) if transpose else (P(None, "model"))


def param_spec(cfg: ModelConfig, mesh, path: str, ndim: int) -> P:
    """PartitionSpec for one parameter leaf, keyed on its tree path."""
    lead = ndim - 2          # stacked layer dims ([L] or [n_super, period])
    pre = (None,) * max(lead, 0)
    ms = model_size(mesh)

    if "unembed" in path:
        return _vocab_spec(cfg, mesh, transpose=True)
    if "embed" in path:
        return _vocab_spec(cfg, mesh)
    if path.endswith(("attn/wq", "attn/wk", "attn/wv")):
        return P(*pre, "data", "model")
    if path.endswith("attn/wo"):
        return P(*pre, "model", "data")
    if "moe/w_router" in path:
        return P(*(None,) * (ndim - 2), "data", None)
    if "moe/" in path:   # [.., E, d, f] / [.., E, f, d]
        e_shard = cfg.n_experts % ms == 0
        pre = (None,) * (ndim - 3)
        if path.endswith("w_down"):
            return P(*pre, "model", None, "data") if e_shard \
                else P(*pre, None, "model", "data")
        return P(*pre, "model", "data", None) if e_shard \
            else P(*pre, None, "data", "model")
    if path.endswith(("mlp/w_gate", "mlp/w_up")):
        return P(*pre, "data", "model")
    if path.endswith("mlp/w_down"):
        return P(*pre, "model", "data")
    if path.endswith("ssm/w_in"):
        return P(*pre, "data", "model")
    if path.endswith("ssm/w_out"):
        return P(*pre, "model", "data")
    if path.endswith("ssm/conv_w"):
        return P(*(None,) * (ndim - 1), "model")
    if path.endswith(("ssm/conv_b",)):
        return P(*(None,) * (ndim - 1), "model")
    # norms, scalars, dt_bias, a_log, d_skip, q/k norms: replicate
    return P(*(None,) * ndim)


def param_shardings(cfg: ModelConfig, mesh, params_shape):
    """Pytree of NamedShardings matching a (possibly abstract) params tree."""
    def rule(path, leaf):
        return NamedSharding(mesh, param_spec(cfg, mesh, _path_str(path), leaf.ndim))
    return jax.tree_util.tree_map_with_path(rule, params_shape)


def opt_shardings(cfg: ModelConfig, mesh, opt_shape):
    """Adam m/v follow their parameters; the step counter is replicated."""
    def rule(path, leaf):
        ps = _path_str(path)
        if ps.startswith(("m/", "v/")) or "/m/" in ps or "/v/" in ps:
            core = ps.split("/", 1)[1]
            return NamedSharding(mesh, param_spec(cfg, mesh, core, leaf.ndim))
        return NamedSharding(mesh, P())
    return jax.tree_util.tree_map_with_path(rule, opt_shape)


# ---------------------------------------------------------------- activations
def batch_spec(mesh, ndim: int) -> P:
    return P(batch_axes(mesh), *(None,) * (ndim - 1))


def kv_head_axis(cfg: ModelConfig, mesh) -> str:
    """Which trailing axis of [.., Hkv, hd] takes the model axis."""
    return "heads" if cfg.n_kv_heads % model_size(mesh) == 0 else "dim"


def cache_spec(cfg: ModelConfig, mesh, *, shard_slots: bool) -> P:
    """[L, B, S, Hkv, hd] arena spec.  shard_slots: long-context batch=1 mode
    (sequence-parallel decode: slots on `data`)."""
    b_ax = None if shard_slots else batch_axes(mesh)
    s_ax = "data" if shard_slots else None
    if kv_head_axis(cfg, mesh) == "heads":
        return P(None, b_ax, s_ax, "model", None)
    return P(None, b_ax, s_ax, None, "model")


def cache_meta_spec(mesh, *, shard_slots: bool) -> P:
    """[L, B, S] pos/score arrays."""
    b_ax = None if shard_slots else batch_axes(mesh)
    return P(None, b_ax, "data" if shard_slots else None)


def ssm_state_spec(cfg: ModelConfig, mesh, *, shard_batch: bool) -> P:
    """[L, B, H, P, N]: SSM heads shard on model (H always divides)."""
    b_ax = batch_axes(mesh) if shard_batch else None
    h_ax = "model" if cfg.ssm_heads % model_size(mesh) == 0 else None
    return P(None, b_ax, h_ax, None, None)


def conv_state_spec(cfg: ModelConfig, mesh, *, shard_batch: bool) -> P:
    """[L, B, W-1, C]: channels shard on model."""
    b_ax = batch_axes(mesh) if shard_batch else None
    return P(None, b_ax, None, "model")
