"""Serving launcher.

    PYTHONPATH=src python -m repro.launch.serve --arch mistral-7b --reduced \
        --mode squeeze --policy sliding_window --budget-frac 0.4

    # token-level continuous batching over the persistent budget-tier arenas
    PYTHONPATH=src python -m repro.launch.serve --arch mistral-7b --reduced \
        --batching continuous --batch 6 --max-concurrency 4

    # long prompts streamed in chunks co-scheduled with resident decode
    PYTHONPATH=src python -m repro.launch.serve --arch mistral-7b --reduced \
        --batching continuous --batch 6 --chunked-prefill --chunk-len 64

    # OpenAI-compatible HTTP endpoint (SSE streaming, /metrics SLOs)
    PYTHONPATH=src python -m repro.launch.serve --arch mistral-7b --reduced \
        --batching continuous --http 8000

Loads a config (reduced for CPU; full configs serve under the production
mesh proven by launch/dryrun.py), optionally restores a checkpoint, and
runs batched generation with the requested KV-cache mode.  `--policy`
accepts every registered sequence-wise policy (repro.core.policies.POLICIES),
including the composed `sink_h2o`.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import checkpoint as ckpt
from repro.configs import get_config, get_reduced
from repro.core import POLICIES, PolicyConfig
from repro.models import init_params
from repro.serving import (AudioSegment, ContinuousConfig,
                           ContinuousScheduler, Engine, EngineConfig,
                           ImageSegment, IntakeEncoder, MultimodalRequest,
                           SamplerConfig, TextSegment)


def _frontend_kind(cfg, args):
    """Resolve --frontend: 'auto' follows the config, 'none' forces token
    prompts, explicit kinds must match what the config can encode."""
    from repro.models.frontend import STUB_FRONTENDS
    if args.frontend == "none":
        return None
    auto = STUB_FRONTENDS.get(cfg.frontend)
    if args.frontend == "auto":
        return auto
    if args.frontend != auto:
        raise SystemExit(f"--frontend {args.frontend} needs a config with "
                         f"the matching stub frontend (got "
                         f"{cfg.frontend or 'none'})")
    return args.frontend


def _frontend_segment(kind, args):
    return ImageSegment(args.n_patches) if kind == "image" \
        else AudioSegment(args.n_frames)


def _run_oneshot(params, cfg, ecfg, args):
    eng = Engine(params, cfg, ecfg)
    rng = np.random.default_rng(args.seed)
    kind = _frontend_kind(cfg, args)
    if kind is not None:
        # frontend families: the batch arrives as precomputed embeddings
        # ([frontend | text] per request, encoded through the intake)
        n_front = args.n_patches if kind == "image" else args.n_frames
        n_text = max(args.prompt_len - n_front, 1)
        intake = IntakeEncoder(params, cfg)
        reqs = [MultimodalRequest(
            (_frontend_segment(kind, args),
             TextSegment(rng.integers(0, cfg.vocab_size,
                                      (n_text,)).astype(np.int32))),
            max_new=args.max_new, seed=args.seed + b)
            for b in range(args.batch)]
        embeds = np.stack(intake.encode_burst(reqs))
        print(f"intake: {intake.encode_dispatches} encoder dispatch(es) for "
              f"{intake.encoded_segments} segments "
              f"({intake.frontend_tokens_encoded} frontend tokens)")
        r = eng.generate(embeds=embeds, seed=args.seed)
    else:
        prompt = rng.integers(0, cfg.vocab_size,
                              (args.batch, args.prompt_len)).astype(np.int32)
        r = eng.generate(tokens=prompt, seed=args.seed)
    print(f"mode={args.mode} policy={args.policy}")
    if cfg.has_attention:
        print(f"plan: {r.plan.describe()} "
              f"(b_init={r.plan.b_init}, p={r.plan.p})")
        print(f"layer cosine sims: {np.round(r.cos_sims, 3)}")
    print(f"prefill {r.prefill_seconds*1e3:.1f}ms | allocate "
          f"{r.allocate_seconds*1e3:.1f}ms | decode {r.decode_seconds*1e3:.1f}ms "
          f"| {r.tokens_per_second:.1f} tok/s")
    for b in range(min(args.batch, 2)):
        print(f"out[{b}]: {r.tokens[b].tolist()}")


def _parse_watermark(spec: str):
    """``LOW:HIGH`` free-page fractions (e.g. ``0.05:0.25``) -> floats."""
    if not spec:
        return 0.0, 0.0
    try:
        low, high = (float(x) for x in spec.split(":"))
    except ValueError:
        raise SystemExit(f"--watermark expects LOW:HIGH fractions "
                         f"(e.g. 0.05:0.25), got {spec!r}")
    return low, high


def _run_continuous(params, cfg, ecfg, args):
    """Heterogeneous-length traffic through the persistent-arena core."""
    bucket = max(4, args.prompt_len // 2)   # two buckets: length-sorted path
    if args.packed_prefill and (cfg.is_ssm_only or cfg.is_hybrid):
        # packed recurrent segments must align with the SSD chunk grid
        # (ContinuousEngine enforces it); round the bucket up to a multiple
        bucket = -(-bucket // cfg.ssm_chunk) * cfg.ssm_chunk
    wm_low, wm_high = _parse_watermark(args.watermark)
    if args.chunked_prefill and (cfg.is_ssm_only or cfg.is_hybrid):
        # chunk boundaries must land on the SSD chunk grid for bit-exact
        # recurrent resume (ContinuousEngine enforces bucket % ssm_chunk)
        bucket = -(-bucket // cfg.ssm_chunk) * cfg.ssm_chunk
    chunk_len = args.chunk_len if args.chunk_len else 2 * bucket
    chunk_len = -(-chunk_len // bucket) * bucket   # bucket-multiple contract
    ccfg = ContinuousConfig(
        max_concurrency=args.max_concurrency, prompt_bucket=bucket,
        max_prompt_len=args.prompt_len, max_new_cap=args.max_new,
        sync_every=args.sync_every,
        length_sorted=not args.no_length_sort,
        packed_prefill=args.packed_prefill,
        page_size=args.page_size,
        prefix_cache=args.prefix_cache,
        overcommit=args.overcommit,
        watermark_low=wm_low, watermark_high=wm_high,
        chunked_prefill=args.chunked_prefill,
        chunk_len=chunk_len if args.chunked_prefill else 0)
    sched = ContinuousScheduler(params, cfg, ecfg, ccfg, seed=args.seed)
    print(f"capability: {sched.capability.describe()}")
    if args.http:
        # async front end: hand the scheduler to the background service
        # loop and serve the OpenAI-compatible HTTP API until Ctrl-C
        from repro.launch.http_api import serve_http
        from repro.serving import ServingService
        serve_http(ServingService(sched), host=args.http_host,
                   port=args.http)
        return
    rng = np.random.default_rng(args.seed)
    kind = _frontend_kind(cfg, args)
    n_front = 0 if kind is None else \
        (args.n_patches if kind == "image" else args.n_frames)
    if n_front >= args.prompt_len:
        raise SystemExit(f"--n-patches/--n-frames ({n_front}) must leave "
                         f"room for text below --prompt-len "
                         f"({args.prompt_len})")
    # with the prefix cache on, traffic shares a "system prompt" so later
    # arrivals actually hit the radix tree
    shared = rng.integers(0, cfg.vocab_size,
                          (max(args.page_size, args.prompt_len // 2),)
                          ).astype(np.int32) if args.prefix_cache else None
    t0 = time.perf_counter()
    for i in range(args.batch):
        lo = max(4, (args.prompt_len - n_front) // 2)
        plen = int(rng.integers(min(lo, args.prompt_len - n_front),
                                args.prompt_len - n_front + 1))
        max_new = int(rng.integers(max(2, args.max_new // 4),
                                   args.max_new + 1))
        text = rng.integers(0, cfg.vocab_size, (plen,)).astype(np.int32)
        if shared is not None:
            text = np.concatenate([shared, text])[:args.prompt_len]
        if kind is not None and (i % 2 == 0 or args.batch == 1):
            # frontend traffic; odd arrivals stay token prompts so the
            # admission polls see mixed text+multimodal bursts
            sched.submit_multimodal(MultimodalRequest(
                (_frontend_segment(kind, args), TextSegment(text)),
                max_new=max_new, seed=args.seed + i))
        else:
            sched.submit(text, max_new)
    n_tok = 0
    while sched.queue or sched.core.n_occupied or sched.core.n_pending:
        for r in sched.poll():     # stream completions as they finish
            n_tok += r.tokens.size
            print(f"rid={r.rid} done: {r.tokens.size} tokens, "
                  f"latency {r.latency_s*1e3:.1f}ms")
    wall = time.perf_counter() - t0
    plan = sched.core.plan
    print(f"mode={args.mode} policy={args.policy} "
          f"concurrency={args.max_concurrency}")
    cap = sched.capability
    if cap.budgeted and plan is not None:  # calibrated on the first request
        print(f"plan: {plan.describe()} slots per row")
    if cap.n_recurrent_layers:
        act_bytes = np.dtype(cfg.dtype).itemsize    # match state_bytes below
        print(f"fixed recurrent tier: {cap.n_recurrent_layers} layer(s), "
              f"{cap.recurrent.bytes_per_row(act_bytes=act_bytes)} bytes/row")
    core = sched.core
    print(f"decode-state footprint: {core.state_bytes} bytes "
          f"across {args.max_concurrency} rows")
    print(f"{args.batch} requests, {n_tok} tokens in {wall*1e3:.1f}ms "
          f"({n_tok/max(wall, 1e-9):.1f} tok/s incl. compile)")
    layout = ("packed" if ccfg.packed_prefill
              else "sorted" if ccfg.length_sorted else "padded")
    print(f"host dispatches: {core.decode_dispatches} fused decode blocks "
          f"for {core.decode_steps} steps (sync_every={args.sync_every}), "
          f"{core.admit_dispatches} admissions for {core.admitted} requests; "
          f"prefill pad tokens {core.prefill_pad_tokens} for "
          f"{core.prompt_tokens} prompt tokens"
          f" (admission={layout})")
    if ccfg.chunked_prefill:
        print(f"chunked prefill: {core.chunked_admitted} long prompt(s) "
              f"streamed in {core.chunk_dispatches} chunk(s) of "
              f"{ccfg.resolved_chunk_len()} tokens "
              f"({core.chunk_tokens_prefilled} tokens co-scheduled with "
              f"decode)")
    if core.pool_pages:
        print(f"page pool: {core.pool_pages} pages of {ccfg.page_size} "
              f"tokens, occupancy {core.pool_occupancy:.2f} "
              f"({core.pool_pages_resident} resident)")
    if ccfg.overcommit != 1.0 or ccfg.watermark_high > 0.0:
        print(f"pool pressure: overcommit {ccfg.overcommit:.2f}, "
              f"watermarks {ccfg.watermark_low:.2f}:"
              f"{ccfg.watermark_high:.2f}; peak resident rows "
              f"{core.peak_resident_rows}, {core.stall_polls} stalled "
              f"poll(s), {core.watermark_hits} watermark hit(s), "
              f"{core.preemptions} preemption(s), {core.requeues} "
              f"requeue(s)")
    if ccfg.prefix_cache and core._prefix is not None:
        print(f"prefix cache: {core.prefix_hits} hit(s), "
              f"{core.prompt_tokens_referenced} prompt tokens admitted by "
              f"page reference, {core._prefix.n_nodes} resident node(s), "
              f"{core._prefix.evictions} eviction(s)")
    enc = sched.intake
    if enc.encode_dispatches:
        print(f"intake: {enc.encode_dispatches} encoder dispatch(es) for "
              f"{enc.encoded_segments} segments "
              f"({enc.frontend_tokens_encoded} frontend tokens); "
              f"kv unpack copies {core.admit_kv_copy_elems} elems")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mistral-7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--mode", default="squeeze",
                    choices=["full", "uniform", "squeeze", "zigzag"])
    ap.add_argument("--n-tiers", type=int, default=4,
                    help="zigzag mode: requested budget levels (the realized "
                         "plan merges tiers whose quantized budgets "
                         "coincide)")
    ap.add_argument("--policy", default="sliding_window",
                    choices=list(POLICIES))
    ap.add_argument("--batching", default="oneshot",
                    choices=["oneshot", "continuous"])
    ap.add_argument("--max-concurrency", type=int, default=4)
    ap.add_argument("--sync-every", type=int, default=4,
                    help="decode steps fused into one dispatched block "
                         "(continuous batching)")
    ap.add_argument("--no-length-sort", action="store_true",
                    help="disable length-sorted admission (pad every "
                         "burst to its longest prompt)")
    ap.add_argument("--packed-prefill", action="store_true",
                    help="packed admission: concatenate a burst's prompts "
                         "into few rows under a block-diagonal mask and "
                         "prefill them in one dispatch")
    ap.add_argument("--page-size", type=int, default=0,
                    help="paged KV arenas: tier slots live in fixed-size "
                         "pages of this many tokens inside one global pool "
                         "(0 = contiguous per-row arenas)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="radix-tree prefix reuse over page-aligned prompt "
                         "chunks: shared prompts prefill once and later "
                         "requests admit by page reference (requires "
                         "--page-size > 0)")
    ap.add_argument("--overcommit", type=float, default=1.0,
                    help="page-pool overcommit factor (continuous batching, "
                         "needs --page-size): <1.0 sizes the pool below the "
                         "worst case so squeezed pages host more rows; the "
                         "engine absorbs exhaustion with backpressure and "
                         "preemption instead of raising")
    ap.add_argument("--chunked-prefill", action="store_true",
                    help="split long prompts into fixed chunks co-scheduled "
                         "inside the fused decode blocks, so resident rows "
                         "keep decoding while a long admission streams in "
                         "(continuous batching)")
    ap.add_argument("--chunk-len", type=int, default=0,
                    help="prefill chunk length in tokens (rounded up to the "
                         "prompt bucket; 0 = 2x the prompt bucket)")
    ap.add_argument("--http", type=int, default=0,
                    help="serve an OpenAI-compatible HTTP endpoint on this "
                         "port instead of driving synthetic traffic "
                         "(continuous batching; /v1/completions with SSE "
                         "streaming, /metrics, /healthz)")
    ap.add_argument("--http-host", default="127.0.0.1")
    ap.add_argument("--watermark", default="",
                    help="LOW:HIGH free-page fractions for admission "
                         "backpressure hysteresis (e.g. 0.05:0.25); empty = "
                         "fit-based admission only")
    ap.add_argument("--flash-decode", action="store_true",
                    help="route decode attention through the Pallas "
                         "flash-decode kernel (interpret mode off-TPU)")
    ap.add_argument("--frontend", default="auto",
                    choices=["auto", "none", "image", "audio"],
                    help="multimodal intake: 'auto' follows the config's "
                         "stub frontend (vlm -> image patches, audio -> "
                         "codec frames), 'none' forces token prompts")
    ap.add_argument("--n-patches", type=int, default=16,
                    help="patch-grid size per image request "
                         "(vision frontend)")
    ap.add_argument("--n-frames", type=int, default=16,
                    help="codec frames per audio request (audio frontend)")
    ap.add_argument("--budget-frac", type=float, default=0.4)
    ap.add_argument("--p", type=float, default=0.35)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    if args.ckpt_dir and (s := ckpt.latest_step(args.ckpt_dir)) is not None:
        params = ckpt.restore(args.ckpt_dir, s, params)
        print(f"restored step {s} from {args.ckpt_dir}")

    ecfg = EngineConfig(
        mode=args.mode, policy=PolicyConfig(args.policy),
        budget_frac=args.budget_frac, p=args.p, n_tiers=args.n_tiers,
        max_new_tokens=args.max_new,
        bucket=16 if not args.reduced else 4,
        min_budget=16 if not args.reduced else 4,
        sampler=SamplerConfig(temperature=args.temperature),
        use_flash_decode=args.flash_decode)
    if args.batching == "continuous":
        _run_continuous(params, cfg, ecfg, args)
    else:
        _run_oneshot(params, cfg, ecfg, args)


if __name__ == "__main__":
    main()
