"""Abstract input specs + step builders for the multi-pod dry-run.

Everything here is ShapeDtypeStruct-based — no device allocation.  Each of
the four assigned input shapes lowers the step its kind dictates:

  train_4k     -> train_step   (fwd + bwd + AdamW)
  prefill_32k  -> prefill      (full KV + cosine sims + H2O stats out)
  decode_32k   -> serve_step   (1 new token against a seq_len KV arena)
  long_500k    -> serve_step   (batch=1; arena slots sharded on `data` —
                                sequence-parallel decode)

KV modes: "full" (paper's Full Cache baseline: arena == seq_len per layer)
and "squeeze" (Algorithm-1 allocation at b_init=40% of context, p=0.35,
60% of layers squeezed — the paper's typical operating point).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.allocation import BudgetPlan, allocate, uniform_plan
from repro.core.cache import SlotCache
from repro.core.policies import PolicyConfig
from repro.launch import sharding as shard_lib
from repro.launch.mesh import batch_axes
from repro.models.config import ModelConfig
from repro.models.transformer import init_params, n_attn_layers
from repro.serving.decode import DecodeState, serve_step
from repro.serving.prefill import prefill
from repro.training.optimizer import AdamWConfig, init_opt_state
from repro.training.train_step import TrainBatch, train_step


@dataclasses.dataclass(frozen=True)
class ShapeCase:
    name: str
    seq_len: int
    global_batch: int
    kind: str              # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCase("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCase("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCase("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCase("long_500k", 524_288, 1, "decode"),
}


def _sds(shape, dtype, mesh, spec: P):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, spec))


def abstract_params(cfg: ModelConfig, mesh):
    """Sharded ShapeDtypeStruct pytree of the model parameters."""
    shapes = jax.eval_shape(lambda k: init_params(k, cfg),
                            jax.random.PRNGKey(0))
    shardings = shard_lib.param_shardings(cfg, mesh, shapes)
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes, shardings)


def abstract_opt_state(cfg: ModelConfig, mesh, params_abs):
    shapes = jax.eval_shape(init_opt_state, params_abs)
    shardings = shard_lib.opt_shardings(cfg, mesh, shapes)
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes, shardings)


# --------------------------------------------------------------------- train
def train_inputs(cfg: ModelConfig, case: ShapeCase, mesh):
    B, S = case.global_batch, case.seq_len
    bspec = P(batch_axes(mesh), None)
    if cfg.frontend:   # vlm/audio: precomputed frontend embeddings (stub)
        tokens = None
        embeds = _sds((B, S, cfg.d_model), jnp.dtype(cfg.dtype), mesh,
                      P(batch_axes(mesh), None, None))
    else:
        tokens = _sds((B, S), jnp.int32, mesh, bspec)
        embeds = None
    positions = None
    if cfg.mrope_sections is not None:
        positions = _sds((B, S, 3), jnp.int32, mesh,
                         P(batch_axes(mesh), None, None))
    batch = TrainBatch(
        tokens=tokens,
        targets=_sds((B, S), jnp.int32, mesh, bspec),
        valid=None, embeds=embeds, positions=positions)
    return batch


def build_train_fn(cfg: ModelConfig, opt_cfg: Optional[AdamWConfig] = None,
                   microbatches: int = 4):
    """microbatches=4 is the production default: peak activation memory
    scales with the microbatch while the HBM roofline terms are unchanged
    (§Perf A7)."""
    ocfg = opt_cfg or AdamWConfig()

    def fn(params, opt_state, batch):
        return train_step(params, opt_state, batch, cfg, ocfg,
                          microbatches=microbatches)

    return fn


# -------------------------------------------------------------------- prefill
def prefill_inputs(cfg: ModelConfig, case: ShapeCase, mesh):
    B, S = case.global_batch, case.seq_len
    bspec = P(batch_axes(mesh), None)
    if cfg.frontend:
        tokens, embeds = None, _sds((B, S, cfg.d_model), jnp.dtype(cfg.dtype),
                                    mesh, P(batch_axes(mesh), None, None))
    else:
        tokens, embeds = _sds((B, S), jnp.int32, mesh, bspec), None
    positions = None
    if cfg.mrope_sections is not None:
        positions = _sds((B, S, 3), jnp.int32, mesh,
                         P(batch_axes(mesh), None, None))
    return tokens, embeds, positions


def build_prefill_fn(cfg: ModelConfig, mesh):
    kv_spec = shard_lib.cache_spec(cfg, mesh, shard_slots=False)

    def fn(params, tokens, embeds, positions):
        out = prefill(params, cfg, tokens=tokens, embeds=embeds,
                      positions=positions)
        if out.k is not None:
            k = jax.lax.with_sharding_constraint(out.k, NamedSharding(mesh, kv_spec))
            v = jax.lax.with_sharding_constraint(out.v, NamedSharding(mesh, kv_spec))
            out = out._replace(k=k, v=v)
        return out

    return fn


# --------------------------------------------------------------------- decode
def dryrun_plan(cfg: ModelConfig, seq_len: int, kv_mode: str) -> BudgetPlan:
    """Deterministic stand-in for the runtime KMeans outcome (dry-run only).

    full:    arena == seq_len everywhere (Full Cache baseline).
    squeeze: b_init = 40% of context, p = 0.35, G3 = 60% of layers (the
             paper's reported typical split) — alternating membership so the
             tier scan interleaves like a real clustering."""
    n_attn = max(n_attn_layers(cfg), 1)
    if kv_mode == "full":
        return uniform_plan(n_attn, seq_len)
    # Deterministic two-tier plan matching the paper's typical outcome
    # (b_init = 40% of context, p = 0.35, 60% of layers squeezed, budgets
    # bucket-quantized to 128 so every slots axis shards on data=16).
    b_init = int(0.4 * seq_len)
    p = 0.35
    bucket = 128
    n_small = min(max(int(0.6 * n_attn), 1), n_attn - 1) if n_attn > 1 else 0
    if n_small == 0:
        return uniform_plan(n_attn, (b_init // bucket) * bucket)
    n_big = n_attn - n_small
    b_small = max(bucket, int(b_init * p) // bucket * bucket)
    freed = n_attn * b_init - n_small * b_small
    b_big = max(bucket, int(freed / n_big) // bucket * bucket)
    # interleave tiers like a real clustering (first/last layers important)
    is_small = [False] * n_attn
    small_ix = np.unique(np.linspace(
        max(n_attn // 3, 1), n_attn - 2, n_small).astype(int))
    extra = iter([i for i in range(1, n_attn - 1)
                  if i not in set(small_ix)])
    picked = set(small_ix)
    while len(picked) < n_small:
        picked.add(next(extra))
    for i in picked:
        is_small[i] = True
    return BudgetPlan(
        n_layers=n_attn, b_init=b_init, p=p,
        group=tuple(2 if s else 1 for s in is_small),
        tier_of=tuple(int(s) for s in is_small),
        tier_budgets=(b_big, b_small),
        centers=(0.3, 0.6, 0.95))


def decode_state_specs(cfg: ModelConfig, case: ShapeCase, mesh,
                       plan: BudgetPlan):
    """Abstract DecodeState for a given budget plan."""
    from repro.serving.decode import make_tier_indices

    B = case.global_batch
    shard_slots = B == 1 and not cfg.is_ssm_only
    b_ax = batch_axes(mesh)
    cspec = shard_lib.cache_spec(cfg, mesh, shard_slots=shard_slots)
    mspec = shard_lib.cache_meta_spec(mesh, shard_slots=shard_slots)

    def tier(n_layers, slots):
        n_layers, slots = max(n_layers, 1), max(slots, 16)
        kd = jnp.dtype(cfg.dtype)
        return SlotCache(
            k=_sds((n_layers, B, slots, cfg.n_kv_heads, cfg.hd), kd, mesh, cspec),
            v=_sds((n_layers, B, slots, cfg.n_kv_heads, cfg.hd), kd, mesh, cspec),
            pos=_sds((n_layers, B, slots), jnp.int32, mesh, mspec),
            score=_sds((n_layers, B, slots), jnp.float32, mesh, mspec),
        )

    if cfg.is_ssm_only:
        tiers = ()
        tof, tix = (), ()
    else:
        tiers = tuple(tier(len(layers), budget)
                      for budget, layers in plan.layer_tiers())
        tof_c, tix_c = make_tier_indices(plan.tier_of)
        rep = P(None)
        tof = _sds(tof_c.shape, jnp.int32, mesh, rep)
        tix = _sds(tix_c.shape, jnp.int32, mesh, rep)

    if cfg.is_ssm_only or cfg.is_hybrid:
        n_ssm = cfg.n_layers
        sspec = shard_lib.ssm_state_spec(cfg, mesh, shard_batch=B > 1)
        cvspec = shard_lib.conv_state_spec(cfg, mesh, shard_batch=B > 1)
        ssm = _sds((n_ssm, B, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                   jnp.float32, mesh, sspec)
        conv = _sds((n_ssm, B, cfg.ssm_conv_width - 1,
                     cfg.d_inner + 2 * cfg.ssm_state), jnp.dtype(cfg.dtype),
                    mesh, cvspec)
    else:
        ssm = conv = ()

    t = _sds((B,), jnp.int32, mesh, P(b_ax) if B > 1 else P(None))
    token = _sds((B,), jnp.int32, mesh, P(b_ax) if B > 1 else P(None))
    state = DecodeState(tiers, tof, tix, ssm, conv, t)
    return state, token


def build_serve_fn(cfg: ModelConfig, pol: Optional[PolicyConfig] = None):
    pol = pol or PolicyConfig()

    def fn(params, state, token):
        return serve_step(params, cfg, pol, state, token)

    return fn
