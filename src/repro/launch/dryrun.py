import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combination.

The two lines above MUST run before any other import — jax locks the device
count on first initialization, and the production meshes need 512 host
placeholder devices (2 pods x 16 x 16).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-27b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
    PYTHONPATH=src python -m repro.launch.dryrun --all --kv-mode squeeze

Each successful combo writes experiments/dryrun/<arch>__<shape>__<mesh>__<kv>.json
with memory_analysis, cost_analysis, and the collective-byte parse — the
inputs to the §Roofline table (analysis/roofline.py)."""

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.analysis.hlo import collective_bytes          # noqa: E402
from repro.analysis.hlo_flops import analyze as hlo_analyze  # noqa: E402
from repro.analysis.roofline import (                    # noqa: E402
    from_cost_analysis, model_flops, wire_bytes)
from repro.configs import ALL_ARCHS, ASSIGNED_ARCHS, get_config  # noqa: E402
from repro.launch import specs as S                      # noqa: E402
from repro.launch.mesh import make_production_mesh       # noqa: E402


def _mem_dict(compiled) -> dict:
    ma = compiled.memory_analysis()
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
            "alias_size_in_bytes")
    out = {}
    for k in keys:
        try:
            out[k] = int(getattr(ma, k))
        except (AttributeError, TypeError):
            pass
    return out


def run_combo(arch: str, shape: str, mesh_name: str, kv_mode: str,
              outdir: str, force: bool = False, save_hlo: bool = False,
              microbatches: int = 4) -> dict:
    tag = f"{arch}__{shape}__{mesh_name}__{kv_mode}"
    path = os.path.join(outdir, tag + ".json")
    if os.path.exists(path) and not force:
        with open(path) as fh:
            return json.load(fh)

    cfg = get_config(arch)
    case = S.SHAPES[shape]
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    chips = mesh.size
    t0 = time.perf_counter()

    with mesh:
        if case.kind == "train":
            params = S.abstract_params(cfg, mesh)
            opt = S.abstract_opt_state(cfg, mesh, params)
            batch = S.train_inputs(cfg, case, mesh)
            fn = S.build_train_fn(cfg, microbatches=microbatches)
            lowered = jax.jit(fn, donate_argnums=(0, 1)).lower(params, opt, batch)
        elif case.kind == "prefill":
            params = S.abstract_params(cfg, mesh)
            tokens, embeds, positions = S.prefill_inputs(cfg, case, mesh)
            fn = S.build_prefill_fn(cfg, mesh)
            lowered = jax.jit(fn).lower(params, tokens, embeds, positions)
        else:
            params = S.abstract_params(cfg, mesh)
            plan = S.dryrun_plan(cfg, case.seq_len, kv_mode)
            state, token = S.decode_state_specs(cfg, case, mesh, plan)
            fn = S.build_serve_fn(cfg)
            lowered = jax.jit(fn, donate_argnums=(1,)).lower(params, state, token)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    cost = compiled.cost_analysis() or {}
    mem = _mem_dict(compiled)
    hlo = compiled.as_text()
    colls = collective_bytes(hlo)
    # loop-aware FLOPs/bytes: XLA's cost_analysis visits scan bodies once,
    # so re-derive both from the HLO with while trip counts applied.
    loop_aware = hlo_analyze(hlo)

    kv_slots = 0
    if case.kind == "decode" and cfg.has_attention:
        plan = S.dryrun_plan(cfg, case.seq_len, kv_mode)
        kv_slots = plan.total
    mflops = model_flops(cfg, case, kv_slots)
    rl = from_cost_analysis(
        arch, shape, mesh_name, chips,
        {"flops": loop_aware["flops"], "bytes accessed": loop_aware["bytes"]},
        wire_bytes(colls), mflops)

    rec = {
        "tag": tag, "arch": arch, "shape": shape, "mesh": mesh_name,
        "kv_mode": kv_mode, "chips": chips,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "cost_analysis": {k: float(v) for k, v in cost.items()
                          if isinstance(v, (int, float))},
        "loop_aware": loop_aware,
        "memory_analysis": mem,
        "collectives": colls,
        "roofline": rl.row(),
        "hlo_bytes": len(hlo),
    }
    os.makedirs(outdir, exist_ok=True)
    with open(path, "w") as fh:
        json.dump(rec, fh, indent=1)
    if save_hlo:
        with open(os.path.join(outdir, tag + ".hlo.txt"), "w") as fh:
            fh.write(hlo)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(S.SHAPES) + [None])
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--kv-mode", default="full", choices=["full", "squeeze"])
    ap.add_argument("--all", action="store_true",
                    help="all assigned archs x all shapes")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--microbatches", type=int, default=4)
    args = ap.parse_args()

    archs = list(ASSIGNED_ARCHS) if args.all or not args.arch else [args.arch]
    if args.arch and args.arch == "all-plus-paper":
        archs = list(ALL_ARCHS)
    # an explicit --shape narrows the sweep even under --all
    shapes = [args.shape] if args.shape else list(S.SHAPES)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mesh_name in meshes:
                tag = f"{arch}/{shape}/{mesh_name}/{args.kv_mode}"
                try:
                    rec = run_combo(arch, shape, mesh_name, args.kv_mode,
                                    args.out, args.force, args.save_hlo,
                                    args.microbatches)
                    rl = rec["roofline"]
                    print(f"OK   {tag:60s} compile={rec['compile_s']:7.1f}s "
                          f"bottleneck={rl['bottleneck']:10s} "
                          f"t_bound={max(rl['t_compute_s'], rl['t_memory_s'], rl['t_collective_s']):.4f}s",
                          flush=True)
                except Exception as e:  # noqa: BLE001
                    failures.append((tag, repr(e)))
                    print(f"FAIL {tag}: {e}", flush=True)
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for t, e in failures:
            print(" ", t, e)
        raise SystemExit(1)
    print("\nall dry-runs passed")


if __name__ == "__main__":
    main()
