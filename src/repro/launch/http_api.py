"""OpenAI-compatible HTTP front end over `ServingService` (stdlib only).

    PYTHONPATH=src python -m repro.launch.serve --arch mistral-7b --reduced \
        --batching continuous --http 8000

    curl -N localhost:8000/v1/completions -d \
        '{"prompt": "count with me", "max_tokens": 16, "stream": true}'

Endpoints (a deliberately small, dependency-free subset of the OpenAI
wire format — enough for any OpenAI-client smoke test to stream against):

  * ``POST /v1/completions`` — `prompt` is either a list of token ids
    (served verbatim) or a string run through the DEMO byte tokenizer
    below; `stream: true` switches the response to SSE, one
    ``data: {json}`` chunk per emitted token, closed by ``data: [DONE]``.
  * ``POST /v1/chat/completions`` — same engine path; `messages` are
    flattened to one prompt, chunks use the chat `delta` shape.
  * ``GET /metrics`` — service SLO aggregate (TTFT / ITL / queue-wait
    percentiles from `ServiceMetrics.snapshot`) plus the engine counters,
    one ``serving_<name> <value>`` line each (Prometheus text style).
  * ``GET /healthz`` — liveness (503 once the service is closed/failed).

Tokenization is NOT part of this repo's scope (the models speak raw ids):
a string prompt is mapped byte-by-byte into the vocab (`b % vocab_size`)
and output ids render as ``" <id>"`` — lossless for list-of-int clients,
demo-readable for curl.  Concurrency comes from `ThreadingHTTPServer`
(one thread per connection) fronting the service's single loop thread;
client disconnect mid-stream cancels the request so its slot recycles.
"""
from __future__ import annotations

import json
import time
import uuid
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import List, Optional, Tuple

import numpy as np

from repro.serving.service import RequestHandle, ServingService

_MAX_BODY = 1 << 20                                   # 1 MiB request cap


def encode_prompt(prompt, vocab_size: int) -> np.ndarray:
    """List of ids -> verbatim int32 array; string -> demo byte tokenizer
    (UTF-8 bytes folded into the vocab)."""
    if isinstance(prompt, str):
        if not prompt:
            raise ValueError("prompt must be non-empty")
        return np.asarray([b % vocab_size for b in prompt.encode("utf-8")],
                          np.int32)
    toks = np.asarray(prompt, np.int32)
    if toks.ndim != 1 or toks.size == 0:
        raise ValueError("prompt must be a string or a flat non-empty "
                         "list of token ids")
    if (toks < 0).any() or (toks >= vocab_size).any():
        raise ValueError(f"token ids must be in [0, {vocab_size})")
    return toks


def detok(tok: int) -> str:
    """Demo rendering of one output id (no tokenizer in scope)."""
    return f" {int(tok)}"


def _flatten_messages(messages) -> str:
    if not isinstance(messages, list) or not messages:
        raise ValueError("messages must be a non-empty list")
    parts: List[str] = []
    for m in messages:
        if not isinstance(m, dict) or "content" not in m:
            raise ValueError("each message needs a 'content' field")
        parts.append(f"{m.get('role', 'user')}: {m['content']}")
    return "\n".join(parts)


class _Handler(BaseHTTPRequestHandler):
    server_version = "repro-serve/1.0"
    protocol_version = "HTTP/1.1"

    # the ThreadingHTTPServer subclass below carries the service handle
    @property
    def svc(self) -> ServingService:
        return self.server.service                     # type: ignore

    def log_message(self, fmt, *args):                 # quiet by default
        if getattr(self.server, "verbose", False):
            super().log_message(fmt, *args)

    # ---- plumbing ---------------------------------------------------------
    def _json(self, code: int, obj: dict) -> None:
        body = json.dumps(obj).encode()
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _error(self, code: int, msg: str) -> None:
        self._json(code, {"error": {"message": msg, "type": "invalid_request_error"}})

    def _read_body(self) -> dict:
        n = int(self.headers.get("Content-Length") or 0)
        if not 0 < n <= _MAX_BODY:
            raise ValueError(f"Content-Length must be in (0, {_MAX_BODY}]")
        obj = json.loads(self.rfile.read(n))
        if not isinstance(obj, dict):
            raise ValueError("request body must be a JSON object")
        return obj

    # ---- GET --------------------------------------------------------------
    def do_GET(self):
        if self.path == "/healthz":
            alive = not self.svc._closed and self.svc.error is None
            self._json(200 if alive else 503,
                       {"status": "ok" if alive else "closed"})
        elif self.path == "/metrics":
            rows = dict(self.svc.metrics.snapshot())
            rows.update(self.svc.counters())
            body = "".join(f"serving_{k} {v:.6g}\n" if isinstance(v, float)
                           else f"serving_{k} {v}\n"
                           for k, v in rows.items()).encode()
            self.send_response(200)
            self.send_header("Content-Type", "text/plain; version=0.0.4")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)
        else:
            self._error(404, f"no route for GET {self.path}")

    # ---- POST -------------------------------------------------------------
    def do_POST(self):
        chat = self.path == "/v1/chat/completions"
        if not chat and self.path != "/v1/completions":
            self._error(404, f"no route for POST {self.path}")
            return
        try:
            body = self._read_body()
            raw = _flatten_messages(body["messages"]) if chat \
                else body.get("prompt")
            if raw is None:
                raise ValueError("missing 'prompt'")
            vocab = self.svc.sched.core.cfg.vocab_size
            toks = encode_prompt(raw, vocab)
            max_new = int(body.get("max_tokens", 16))
            handle = self.svc.submit(toks, max_new=max_new)
        except (ValueError, KeyError, TypeError, json.JSONDecodeError) as e:
            self._error(400, str(e))
            return
        except RuntimeError as e:                      # service closed
            self._error(503, str(e))
            return
        rid = f"cmpl-{uuid.uuid4().hex[:24]}"
        model = body.get("model", "repro")
        if body.get("stream"):
            self._stream_response(handle, rid, model, chat)
        else:
            self._full_response(handle, rid, model, chat, len(toks))

    # ---- response shapes --------------------------------------------------
    def _full_response(self, h: RequestHandle, rid: str, model: str,
                       chat: bool, n_prompt: int) -> None:
        toks = h.result(timeout=600.0)
        text = "".join(detok(t) for t in toks)
        msg = ({"message": {"role": "assistant", "content": text}}
               if chat else {"text": text})
        self._json(200, {
            "id": rid, "model": model, "created": int(time.time()),
            "object": "chat.completion" if chat else "text_completion",
            "choices": [{"index": 0, "finish_reason": "length",
                         "tokens": [int(t) for t in toks], **msg}],
            "usage": {"prompt_tokens": n_prompt,
                      "completion_tokens": int(toks.size),
                      "total_tokens": n_prompt + int(toks.size)},
            "slo": {"ttft_ms": h.slo.ttft_s * 1e3,
                    "itl_p50_ms": h.slo.itl_p50_ms,
                    "queue_wait_ms": h.slo.queue_wait_s * 1e3,
                    "preemptions": h.slo.preemptions},
        })

    def _stream_response(self, h: RequestHandle, rid: str, model: str,
                         chat: bool) -> None:
        self.send_response(200)
        self.send_header("Content-Type", "text/event-stream")
        self.send_header("Cache-Control", "no-cache")
        self.send_header("Connection", "close")
        self.end_headers()
        created = int(time.time())

        def chunk(tok: Optional[int], fin: Optional[str]) -> bytes:
            piece = "" if tok is None else detok(tok)
            delta = ({"delta": {"content": piece} if tok is not None else {}}
                     if chat else {"text": piece})
            obj = {"id": rid, "model": model, "created": created,
                   "object": ("chat.completion.chunk" if chat
                              else "text_completion"),
                   "choices": [{"index": 0, "finish_reason": fin,
                                **({"token": int(tok)} if tok is not None
                                   else {}), **delta}]}
            return f"data: {json.dumps(obj)}\n\n".encode()

        try:
            for tok in h.stream(timeout=600.0):
                self.wfile.write(chunk(tok, None))
                self.wfile.flush()
            fin = "cancelled" if h.cancelled else "length"
            self.wfile.write(chunk(None, fin))
            self.wfile.write(b"data: [DONE]\n\n")
            self.wfile.flush()
        except (BrokenPipeError, ConnectionResetError):
            h.cancel()                 # client went away: recycle the slot


class ServingHTTPServer(ThreadingHTTPServer):
    """Threaded HTTP server bound to one `ServingService`."""
    daemon_threads = True

    def __init__(self, addr: Tuple[str, int], service: ServingService,
                 verbose: bool = False):
        super().__init__(addr, _Handler)
        self.service = service
        self.verbose = verbose


def make_server(service: ServingService, host: str = "127.0.0.1",
                port: int = 8000, verbose: bool = False) -> ServingHTTPServer:
    """Bind (port 0 picks a free one — tests) without starting the serve
    loop; call `serve_forever()` on a thread of your choosing."""
    return ServingHTTPServer((host, port), service, verbose=verbose)


def serve_http(service: ServingService, host: str = "127.0.0.1",
               port: int = 8000, verbose: bool = True) -> None:
    """Blocking front end: serve until KeyboardInterrupt, then drain."""
    httpd = make_server(service, host, port, verbose=verbose)
    print(f"serving on http://{host}:{httpd.server_address[1]} "
          f"(POST /v1/completions, GET /metrics; Ctrl-C drains and exits)")
    try:
        httpd.serve_forever(poll_interval=0.2)
    except KeyboardInterrupt:
        print("\nshutting down: draining in-flight requests...")
    finally:
        httpd.shutdown()
        httpd.server_close()
        service.close(drain=True)
