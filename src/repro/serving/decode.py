"""Budget-tiered decode step (one token) for every architecture family.

The allocator gives every layer one of a small number of budgets — the
paper's 2-tier split (`allocate`), the uniform 1-tier baseline, or
`allocate_zigzag`'s N tiers.  The decode step therefore carries one stacked
slot arena PER TIER and scans the layers *in model order*, selecting the
layer's arena with `lax.switch` — the compiled HLO contains exactly one
attention body per tier regardless of depth, which keeps 94-layer models
cheap to compile and lets XLA alias the scan-carried arenas in place.

`tier_of` / `tier_index` vectors are **data**, so one compiled step serves
any clustering outcome with the same tier shapes (the engine re-compiles
only when the quantized budget buckets change).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.cache import SlotCache, write_token, write_token_meta
from repro.core.paging import KVPool, PagedTier, write_decode_records
from repro.core.policies import PolicyConfig
from repro.models import attention as attn_lib
from repro.models import mlp as mlp_lib
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.config import ModelConfig
from repro.models.norms import apply_norm
from repro.models.transformer import embed_tokens, layer_windows
from repro.serving.sampler import sample


class DecodeState(NamedTuple):
    """Carried between decode steps.  Unused fields are () placeholders."""
    # one stacked arena per budget tier, ordered like BudgetPlan.tier_budgets
    # (tier 0 = biggest budget): SlotCache [n_t, B, b_t, Hkv, hd] each, or
    # PagedTier under paging.  () = no attention layers (ssm-only).
    tiers: tuple
    tier_of: jnp.ndarray | tuple          # [n_attn] int32 tier id — data
    tier_index: jnp.ndarray | tuple       # [n_attn] index within its tier
    ssm_state: jnp.ndarray | tuple        # [n_ssm, B, H, P, N]
    conv_state: jnp.ndarray | tuple       # [n_ssm, B, W-1, C]
    t: jnp.ndarray                # [B] next token's position
    # [B] bool row liveness for continuous batching: retirement lowers a
    # row's flag ON DEVICE (no host sync) and its position stops advancing;
    # () = every row live forever (the one-shot generate/wave paths).
    active: jnp.ndarray | tuple = ()
    # Paged engines (core/paging.py): tiers are PagedTiers (page tables +
    # slot metadata) and the KV bytes live here, in ONE global page pool
    # shared by all tiers and the prefix cache.  () = contiguous arenas.
    kv_pool: KVPool | tuple = ()


def make_tier_indices(tier_of) -> tuple:
    """Per-layer (tier id, index-within-tier) as int32 arrays.

    Accepts any per-layer tier-id sequence (`BudgetPlan.tier_of`; a bool
    is_small vector still reads as the 2-tier 0=big/1=small labelling)."""
    import numpy as np
    tids = np.asarray(tier_of).astype(np.int64)
    idx = np.zeros(len(tids), np.int32)
    counts: dict = {}
    for i, q in enumerate(tids):
        q = int(q)
        idx[i] = counts.get(q, 0)
        counts[q] = idx[i] + 1
    return jnp.asarray(tids.astype(np.int32)), jnp.asarray(idx)


def _tier_read(tier: SlotCache, j) -> SlotCache:
    return SlotCache(*jax.tree.map(
        lambda a: jax.lax.dynamic_index_in_dim(a, j, 0, keepdims=False), tuple(tier)))


def _tier_write(tier: SlotCache, lc: SlotCache, j) -> SlotCache:
    return SlotCache(*jax.tree.map(
        lambda a, u: jax.lax.dynamic_update_index_in_dim(a, u, j, 0),
        tuple(tier), tuple(lc)))


def _attend_tier(bp, cfg, pol, h, t, tier, j, window, use_flash=False):
    """Attention over one layer's arena in `tier`; in-place arena update.

    ``use_flash`` routes the arena read through the Pallas flash-decode
    kernel (split-S partials + combine epilogue) instead of the dense einsum
    — same masking, same H2O statistic, chosen by `EngineConfig`."""
    lc = _tier_read(tier, j)
    ap = attn_lib.AttnParams(**bp["attn"])
    out = attn_lib.decode_attention(ap, h, t, lc.k, lc.v, lc.pos, cfg, window,
                                    use_flash=use_flash)
    probs = out.slot_probs.mean(axis=1)          # [B, S+1] kv-head mean
    # barrier: k/v_new are bf16 casts of f32 rope outputs; without it XLA's
    # convert-sinking rewrites the slot write into an f32 scatter over the
    # WHOLE arena + convert back — 3 full-arena round-trips/layer (§Perf D4)
    k_new, v_new = jax.lax.optimization_barrier((out.k_new, out.v_new))
    new_lc = write_token(pol, lc, k_new, v_new, t, probs)
    return out.out, _tier_write(tier, new_lc, j)


def _attn_decode_block(bp, cfg, pol, x, t, tiers, tier_id, j, window,
                       use_flash=False):
    """norm -> tiered cached attention -> residual.

    One `lax.switch` branch per budget tier: branch ``i`` attends layer
    ``j`` of tier ``i``'s arena and passes the other tiers through — every
    branch returns the same pytree structure, so the compiled step holds
    exactly one attention body per tier."""
    h = apply_norm(bp["attn_norm"], x, cfg)

    if len(tiers) == 1:
        out, t0 = _attend_tier(bp, cfg, pol, h, t, tiers[0], j, window,
                               use_flash)
        tiers = (t0,)
    else:
        def branch(i):
            def f(_):
                o, ti = _attend_tier(bp, cfg, pol, h, t, tiers[i], j, window,
                                     use_flash)
                return o, tuple(ti if q == i else tiers[q]
                                for q in range(len(tiers)))
            return f

        out, tiers = jax.lax.switch(
            tier_id, [branch(i) for i in range(len(tiers))], None)
    if cfg.use_post_norms:
        out = apply_norm(bp["post_attn_norm"], out, cfg)
    return x + out, tiers


def _attend_tier_paged(bp, cfg, pol, h, t, tier: PagedTier, pool: KVPool, j,
                       window, use_flash=False):
    """`_attend_tier` over a paged arena: metadata updates in place, the
    KV write DEFERRED as a record.

    The pool rides the layer scan as a closure constant (read-only there);
    scattering it inside the `lax.switch` tier branches would fork a
    pool-sized copy per branch, so each layer instead emits
    ``(k_new, v_new, page, offset)`` as scan outputs and
    `paging.write_decode_records` lands all layers' writes in one batched
    scatter after the scan.  Victim selection is `cache.write_token_meta` —
    the SAME function the contiguous path uses, which is what keeps paged
    decode bit-identical to contiguous decode."""
    tbl_j = jax.lax.dynamic_index_in_dim(tier.tbl, j, 0, keepdims=False)
    pos_j = jax.lax.dynamic_index_in_dim(tier.pos, j, 0, keepdims=False)
    score_j = jax.lax.dynamic_index_in_dim(tier.score, j, 0, keepdims=False)
    ap = attn_lib.AttnParams(**bp["attn"])
    out = attn_lib.paged_decode_attention(ap, h, t, pool.kp, pool.vp, tbl_j,
                                          pos_j, cfg, window,
                                          use_flash=use_flash)
    probs = out.slot_probs.mean(axis=1)          # [B, S+1] kv-head mean
    # same convert-sinking barrier as the contiguous path (§Perf D4)
    k_new, v_new = jax.lax.optimization_barrier((out.k_new, out.v_new))
    pos2, score2, victim = write_token_meta(pol, pos_j, score_j, t, probs,
                                            k_new=k_new)
    psize = pool.page_size
    page = jnp.take_along_axis(tbl_j, (victim // psize)[:, None],
                               axis=1)[:, 0]
    # frozen rows: the cleared table points every entry at the null page 0,
    # so their unconditional eviction write scribbles harmlessly there
    rec = (k_new[:, 0], v_new[:, 0], page.astype(jnp.int32),
           (victim % psize).astype(jnp.int32))
    tier2 = tier._replace(
        pos=jax.lax.dynamic_update_index_in_dim(tier.pos, pos2, j, 0),
        score=jax.lax.dynamic_update_index_in_dim(tier.score, score2, j, 0))
    return out.out, tier2, rec


def _attn_decode_block_paged(bp, cfg, pol, x, t, tiers, tier_id, j,
                             window, pool, use_flash=False):
    """`_attn_decode_block` for paged tiers; also returns the layer's
    deferred KV write record (every switch branch emits the same shapes)."""
    h = apply_norm(bp["attn_norm"], x, cfg)

    if len(tiers) == 1:
        out, t0, rec = _attend_tier_paged(bp, cfg, pol, h, t, tiers[0], pool,
                                          j, window, use_flash)
        tiers = (t0,)
    else:
        def branch(i):
            def f(_):
                o, ti, rec = _attend_tier_paged(bp, cfg, pol, h, t, tiers[i],
                                                pool, j, window, use_flash)
                return o, tuple(ti if q == i else tiers[q]
                                for q in range(len(tiers))), rec
            return f

        out, tiers, rec = jax.lax.switch(
            tier_id, [branch(i) for i in range(len(tiers))], None)
    if cfg.use_post_norms:
        out = apply_norm(bp["post_attn_norm"], out, cfg)
    return x + out, tiers, rec


def _ffn_decode(bp, cfg, x):
    h = apply_norm(bp["mlp_norm"], x, cfg)
    if cfg.is_moe:
        out, _ = moe_lib.apply_moe(moe_lib.MoeParams(**bp["moe"]), h, cfg)
    else:
        out = mlp_lib.apply_mlp(mlp_lib.MlpParams(**bp["mlp"]), h, cfg)
    if cfg.use_post_norms:
        out = apply_norm(bp["post_mlp_norm"], out, cfg)
    return x + out


def _embed_token(params, cfg, token):
    return embed_tokens(params, cfg, token[:, None])  # [B, 1, d]


def serve_step(
    params,
    cfg: ModelConfig,
    pol: PolicyConfig,
    state: DecodeState,
    token: jnp.ndarray,          # [B] int32 current input token
    embeds: Optional[jnp.ndarray] = None,   # [B, 1, d] overrides token embed
    use_flash: bool = False,     # Pallas flash-decode for the arena reads
):
    """One decode step: token -> logits [B, V], updated DecodeState."""
    x = _embed_token(params, cfg, token) if embeds is None else embeds
    t = state.t
    if isinstance(state.active, tuple):
        inc = 1
        act = None
    else:
        # Retired rows freeze: their position stops advancing, and their
        # effective position becomes -1 — the empty-slot sentinel — so the
        # unconditional eviction write below lands as an EMPTY slot and a
        # cleared row stays logically empty until a new request is inserted.
        inc = state.active.astype(state.t.dtype)
        t = jnp.where(state.active, t, -1)
        act = state.active

    # Recurrent rows freeze the same way: a retired row's SSD/conv state has
    # no empty-slot sentinel to hide behind, so the carry itself must stop
    # integrating — a cleared slot stays exactly zero until re-admission.
    def _freeze(new, old):
        if act is None:
            return new
        mask = act.reshape((-1,) + (1,) * (new.ndim - 1))
        return jnp.where(mask, new, old)

    if cfg.is_ssm_only:
        def body(carry, inp):
            x = carry
            bp, st, cv = inp
            h = apply_norm(bp["norm"], x, cfg)
            out, (st2, cv2) = ssm_lib.ssm_decode_step(
                ssm_lib.SsmParams(**bp["ssm"]), h, cfg, st, cv)
            return x + out, (_freeze(st2, st), _freeze(cv2, cv))

        x, (sts, cvs) = jax.lax.scan(
            body, x, (params["layers"], state.ssm_state, state.conv_state))
        new_state = state._replace(ssm_state=sts, conv_state=cvs, t=state.t + inc)

    elif cfg.is_hybrid:
        sp = params["shared_attn"]
        period = cfg.attn_period
        n_super = cfg.n_layers // period
        paged = isinstance(state.tiers[0], PagedTier)
        pool = state.kv_pool
        sts = jax.tree.map(
            lambda a: a.reshape((n_super, period) + a.shape[1:]),
            (state.ssm_state, state.conv_state))

        def body(carry, inp):
            x, tiers = carry
            bps, (st_sb, cv_sb), tier_id, j = inp

            def inner(c, blk):
                bp, st, cv = blk
                h = apply_norm(bp["norm"], c, cfg)
                out, (st2, cv2) = ssm_lib.ssm_decode_step(
                    ssm_lib.SsmParams(**bp["ssm"]), h, cfg, st, cv)
                return c + out, (_freeze(st2, st), _freeze(cv2, cv))

            x, (st2, cv2) = jax.lax.scan(inner, x, (bps, st_sb, cv_sb))
            if paged:
                x, tiers, rec = _attn_decode_block_paged(
                    sp, cfg, pol, x, t, tiers, tier_id, j,
                    attn_lib.GLOBAL_WINDOW, pool, use_flash)
            else:
                x, tiers = _attn_decode_block(
                    sp, cfg, pol, x, t, tiers, tier_id, j,
                    attn_lib.GLOBAL_WINDOW, use_flash)
                rec = ()
            h2 = apply_norm(sp["mlp_norm"], x, cfg)
            x = x + mlp_lib.apply_mlp(mlp_lib.MlpParams(**sp["mlp"]), h2, cfg)
            return (x, tiers), ((st2, cv2), rec)

        (x, tiers), ((sts2, cvs2), recs) = jax.lax.scan(
            body, (x, state.tiers),
            (params["layers"], sts, state.tier_of, state.tier_index))
        flat = jax.tree.map(lambda a: a.reshape((-1,) + a.shape[2:]), (sts2, cvs2))
        new_state = state._replace(tiers=tiers,
                                   ssm_state=flat[0], conv_state=flat[1], t=state.t + inc)
        if paged:
            new_state = new_state._replace(
                kv_pool=write_decode_records(pool, *recs))

    else:
        windows = layer_windows(cfg)
        paged = isinstance(state.tiers[0], PagedTier)
        pool = state.kv_pool

        def body(carry, inp):
            x, tiers = carry
            bp, window, tier_id, j = inp
            if paged:
                x, tiers, rec = _attn_decode_block_paged(
                    bp, cfg, pol, x, t, tiers, tier_id, j, window,
                    pool, use_flash)
            else:
                x, tiers = _attn_decode_block(
                    bp, cfg, pol, x, t, tiers, tier_id, j, window,
                    use_flash)
                rec = ()
            x = _ffn_decode(bp, cfg, x)
            return (x, tiers), rec

        (x, tiers), recs = jax.lax.scan(
            body, (x, state.tiers),
            (params["layers"], windows, state.tier_of, state.tier_index))
        new_state = state._replace(tiers=tiers, t=state.t + inc)
        if paged:
            new_state = new_state._replace(
                kv_pool=write_decode_records(pool, *recs))

    x = apply_norm(params["final_norm"], x, cfg)
    logits = (x[:, 0] @ params["unembed"]).astype(jnp.float32)
    if cfg.final_softcap:
        logits = jnp.tanh(logits / cfg.final_softcap) * cfg.final_softcap
    if cfg.v_padded != cfg.vocab_size:
        logits = jnp.where(jnp.arange(cfg.v_padded) >= cfg.vocab_size,
                           -1e30, logits)
    return logits, new_state


def sampled_step(params, cfg, pol, sc, state: DecodeState, token, key,
                 use_flash: bool = False):
    """split key -> serve_step -> sample: the shared core of every fused
    decode scan body (one-shot `Engine._block_fn` blocks and the continuous
    engine's `_block_jit` blocks) — kept in ONE place so the per-step
    PRNG-split discipline can never diverge between the two paths.

    Returns (next_token [B], new DecodeState, advanced key)."""
    key, sub = jax.random.split(key)
    logits, state = serve_step(params, cfg, pol, state, token,
                               use_flash=use_flash)
    return sample(logits, sub, sc), state, key
