"""Continuous-batching serving core: persistent budget-tier arenas.

The wave path (scheduler.py) decodes whole fixed-shape batches in lock-step:
every request in a wave pays ``max(max_new)`` decode steps and pad rows burn
compute.  This module is the token-level alternative (DESIGN.md §5):

  * ONE persistent `DecodeState` holds `max_concurrency` request rows across
    the two SqueezeAttention budget tiers; tier sizes are fixed once (from
    the engine config, plus Algorithm-1 calibration on the first admitted
    request in squeeze mode), so the decode step compiles exactly once.
  * **Admission**: a request is prefilled alone (prompt bucketed, batch 1),
    then one fused admit executable per bucket compacts it into the fixed
    tier budgets (the same Algorithm-1 machinery the one-shot engine uses),
    samples its first token and writes the row slice (`insert_row`) — the
    row index is *traced*, so inserting into any slot reuses the executable
    and never touches the decode step.
  * **Retirement**: the decode step itself lowers a row's `active` flag when
    it emits EOS or exhausts its token budget — liveness is decided on
    device with no host round-trip in the hot loop.  The host reads the mask
    only every `sync_every` steps, clears the retired row's slots
    (`clear_row`) and recycles it.
  * **Streaming**: completed requests are harvested at every sync point, so
    short requests leave (and new ones enter) while long ones keep decoding.

Retired rows still occupy SIMD lanes until recycled (dense batched compute
cannot drop a row), but they stop extending their caches and — the actual
throughput lever — their slots immediately host new requests instead of
idling until the longest wave member finishes.
"""
from __future__ import annotations

import dataclasses
from typing import List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.allocation import BudgetPlan
from repro.core.cache import clear_row, empty_cache, insert_row
from repro.serving.decode import DecodeState, make_tier_indices, serve_step
from repro.serving.engine import Engine, EngineConfig
from repro.serving.prefill import pad_prompt
from repro.serving.sampler import sample


@dataclasses.dataclass(frozen=True)
class ContinuousConfig:
    max_concurrency: int = 8      # persistent batch rows (compiled once)
    prompt_bucket: int = 32       # admission prefill shape quantization
    max_prompt_len: int = 128     # admission cap (sizes full-cache arenas)
    max_new_cap: int = 64         # per-request max_new clamp (ditto)
    sync_every: int = 4           # decode steps between host syncs


class ContinuousState(NamedTuple):
    """Carried across decode blocks; `dec.active` is the on-device liveness."""
    dec: DecodeState
    token: jnp.ndarray       # [B] int32 next input token per row
    remaining: jnp.ndarray   # [B] int32 tokens each row may still emit
    key: jnp.ndarray         # PRNG key (stochastic sampling only)


@dataclasses.dataclass
class Completed:
    slot: int
    tokens: np.ndarray       # [n_emitted] int32 (includes EOS if hit)
    decode_steps: int        # steps this request spent in the decode loop


class ContinuousEngine:
    """Persistent-arena decode core.  Thin clients: `ContinuousScheduler`
    (request queue + interleave loop) and the benchmarks."""

    def __init__(self, params, cfg, ecfg: EngineConfig,
                 ccfg: ContinuousConfig = ContinuousConfig(), seed: int = 0):
        if cfg.is_ssm_only or cfg.is_hybrid:
            raise NotImplementedError(
                "continuous batching currently serves attention models; "
                "SSM/hybrid rows need per-row recurrent-state insertion "
                "(DESIGN.md §5)")
        self.engine = Engine(params, cfg, ecfg)   # shared prefill/compaction
        self.params = params
        self.cfg = cfg
        self.ecfg = ecfg
        self.ccfg = ccfg
        self.plan: Optional[BudgetPlan] = None
        self.state: Optional[ContinuousState] = None
        B = ccfg.max_concurrency
        self._free: List[int] = list(range(B))
        self._buf: List[List[int]] = [[] for _ in range(B)]
        self._max_new = [0] * B
        self._steps = [0] * B
        self._occupied: List[int] = []
        self._completed: List[Completed] = []
        # decode-lane accounting (cf. WaveScheduler): every block burns
        # max_concurrency rows per step; useful = rows that were live
        self.row_steps = 0
        self.useful_row_steps = 0
        # distinct streams: admission first-token sampling (host side) vs
        # the decode loop's per-step sampling key carried in the state —
        # reusing one key would draw correlated samples on both sides
        self._host_key, self._state_key = jax.random.split(
            jax.random.PRNGKey(seed))
        # donation lets XLA update the arenas in place; CPU ignores it
        self._donate = {} if jax.default_backend() == "cpu" \
            else {"donate_argnums": (1,)}
        self._step_fn = None
        self._clear_fn = None
        self._admit_fns = {}     # prompt bucket P -> compiled admit

    # ------------------------------------------------------------ properties
    @property
    def has_free(self) -> bool:
        return bool(self._free)

    @property
    def n_occupied(self) -> int:
        return len(self._occupied)

    # ---------------------------------------------------------------- jit fns
    def _build_fns(self):
        cfg, pol, sc = self.cfg, self.ecfg.policy, self.ecfg.sampler
        eos = self.ecfg.eos_token

        def step(params, state: ContinuousState):
            key, sub = jax.random.split(state.key)
            active_prev = state.dec.active
            logits, dec = serve_step(params, cfg, pol, state.dec, state.token)
            nxt = sample(logits, sub, sc)
            rem = state.remaining - active_prev.astype(jnp.int32)
            done = active_prev & (rem <= 0)
            if eos >= 0:
                done = done | (active_prev & (nxt == eos))
            dec = dec._replace(active=active_prev & ~done)
            return nxt, active_prev, ContinuousState(dec, nxt, rem, key)

        def clear(state: ContinuousState, row):
            dec = state.dec
            return state._replace(dec=dec._replace(
                big=clear_row(dec.big, row),
                small=clear_row(dec.small, row),
                active=dec.active.at[row].set(False)))

        donate0 = {} if not self._donate else {"donate_argnums": (0,)}
        self._step_fn = jax.jit(step, **self._donate)
        self._clear_fn = jax.jit(clear, **donate0)

    def _admit_jit(self, P: int):
        """Compiled admission for one prompt bucket: Algorithm-1 compaction
        of the prefill into row-shaped tier arenas, fused with the
        `insert_request` row write and first-token sampling.  One executable
        per (bucket, max_concurrency, tier sizes) — the row index is traced,
        so admitting into ANY slot reuses it.  (Running the compaction
        eagerly instead costs ~100ms of op-dispatch per admission — it
        dominated the serving trace before this was fused.)"""
        if P not in self._admit_fns:
            eng, plan, sc = self.engine, self.plan, self.ecfg.sampler
            eos = self.ecfg.eos_token

            def admit_fn(state: ContinuousState, row, pre, rem0, key):
                rs = eng.build_state(pre, plan, 1)     # [L, 1, S, ...] rows
                token0 = sample(pre.last_logits, key, sc)[0]
                act0 = jnp.asarray(rem0 > 0)
                if eos >= 0:
                    act0 = act0 & (token0 != eos)
                dec = state.dec
                dec = dec._replace(
                    big=insert_row(dec.big, rs.big, row),
                    small=insert_row(dec.small, rs.small, row),
                    t=dec.t.at[row].set(rs.t[0].astype(dec.t.dtype)),
                    active=dec.active.at[row].set(act0))
                return token0, ContinuousState(
                    dec,
                    state.token.at[row].set(token0.astype(state.token.dtype)),
                    state.remaining.at[row].set(rem0),
                    state.key)

            donate0 = {} if not self._donate else {"donate_argnums": (0,)}
            self._admit_fns[P] = jax.jit(admit_fn, **donate0)
        return self._admit_fns[P]

    # ------------------------------------------------------------- state init
    def _init_state(self) -> ContinuousState:
        cfg, plan = self.cfg, self.plan
        B = self.ccfg.max_concurrency
        dtype = jnp.dtype(cfg.dtype)

        def tier(n_layers, budget):
            if n_layers == 0:    # mirror Engine's dummy arena for empty tiers
                return empty_cache(1, B, 16, cfg.n_kv_heads, cfg.hd, dtype)
            return empty_cache(n_layers, B, budget, cfg.n_kv_heads, cfg.hd,
                               dtype)

        is_small, tier_index = make_tier_indices(plan.is_small)
        dec = DecodeState(
            big=tier(plan.n_big, plan.b_big),
            small=tier(plan.n_small, plan.b_small),
            group_is_small=is_small, tier_index=tier_index,
            ssm_state=(), conv_state=(),
            t=jnp.zeros((B,), jnp.int32),
            active=jnp.zeros((B,), bool))
        return ContinuousState(
            dec,
            token=jnp.zeros((B,), jnp.int32),
            remaining=jnp.zeros((B,), jnp.int32),
            key=self._state_key)

    def _ensure_plan(self, pre):
        """Fix (tier sizes, layer grouping) on first admission.

        In squeeze mode the grouping calibrates on the first request's
        cosine sims (Algorithm 1); full/uniform are request-independent.
        Everything afterwards reuses the same compiled executables.
        """
        if self.plan is not None:
            return
        cos = np.asarray(pre.cos_sims).mean(axis=-1) if pre.cos_sims.size \
            else np.zeros(0)
        self.plan = self.engine.plan_budgets(
            cos, self.ccfg.max_prompt_len, self.ccfg.max_new_cap)
        self.state = self._init_state()
        self._build_fns()

    # -------------------------------------------------------------- admission
    def admit(self, prompt: np.ndarray, max_new: int) -> int:
        """Prefill one request and insert it into a free row; returns the
        slot.  Raises if no row is free (callers check `has_free`)."""
        assert self._free, "no free slot — check has_free before admit"
        max_new = min(max_new, self.ccfg.max_new_cap)
        toks, valid = pad_prompt(np.asarray(prompt, np.int32),
                                 self.ccfg.prompt_bucket,
                                 self.ccfg.max_prompt_len)
        B, P = toks.shape
        pre = self.engine.prefill_jit(B, P)(self.params, toks, None, None,
                                            valid)
        self._ensure_plan(pre)

        self._host_key, sub = jax.random.split(self._host_key)
        rem0 = max_new - 1
        slot = self._free.pop(0)
        token0, self.state = self._admit_jit(P)(
            self.state, slot, pre, rem0, sub)
        tok0 = int(token0)
        eos = self.ecfg.eos_token
        act0 = rem0 > 0 and not (eos >= 0 and tok0 == eos)
        self._buf[slot] = [tok0]
        self._max_new[slot] = max_new
        self._steps[slot] = 0
        self._occupied.append(slot)
        if not act0:
            self._retire(slot)
        return slot

    # ------------------------------------------------------------ decode loop
    def decode_block(self) -> int:
        """Run `sync_every` decode steps, harvest emissions, retire finished
        rows.  Returns the number of requests completed in this block."""
        if not self._occupied:
            return 0
        # the host knows an exact upper bound on useful steps this block:
        # EOS can only retire rows EARLIER, so don't burn whole-batch steps
        # past the longest remaining token budget
        bound = max(self._max_new[s] - 1 - self._steps[s]
                    for s in self._occupied)
        trace = []
        for _ in range(max(1, min(self.ccfg.sync_every, bound))):
            nxt, act_prev, self.state = self._step_fn(self.params, self.state)
            trace.append((nxt, act_prev))
        before = len(self._completed)
        for nxt, act_prev in trace:
            nxt, act_prev = np.asarray(nxt), np.asarray(act_prev)
            self.row_steps += self.ccfg.max_concurrency
            self.useful_row_steps += int(act_prev.sum())
            for s in self._occupied:
                if act_prev[s]:
                    self._buf[s].append(int(nxt[s]))
                    self._steps[s] += 1
        active_now = np.asarray(self.state.dec.active)
        for s in list(self._occupied):
            if not active_now[s]:
                self._retire(s)
        return len(self._completed) - before

    def _retire(self, slot: int):
        """Free a finished row: clear its slots on-device and recycle it."""
        self.state = self._clear_fn(self.state, slot)
        self._occupied.remove(slot)
        self._free.append(slot)
        toks = np.asarray(self._buf[slot], np.int32)
        eos = self.ecfg.eos_token
        if eos >= 0 and toks.size < self._max_new[slot]:
            # parity with Engine.generate's post-EOS masking: the tail of a
            # request that stopped early reads as EOS
            toks = np.concatenate(
                [toks, np.full(self._max_new[slot] - toks.size, eos,
                               np.int32)])
        self._completed.append(Completed(slot, toks, self._steps[slot]))
        self._buf[slot] = []

    def pop_completed(self) -> List[Completed]:
        out, self._completed = self._completed, []
        return out


