"""Continuous-batching serving core: persistent budget-tier arenas.

The wave path (scheduler.py) decodes whole fixed-shape batches in lock-step:
every request in a wave pays ``max(max_new)`` decode steps and pad rows burn
compute.  This module is the token-level alternative (DESIGN.md §5):

  * ONE persistent `DecodeState` holds `max_concurrency` request rows across
    the SqueezeAttention budget tiers (two in "squeeze" mode, up to
    `n_tiers` in "zigzag" mode, one in "uniform"); tier sizes are fixed
    once (from the engine config, plus calibration on the first admitted
    request in squeeze/zigzag mode), so the decode step compiles exactly
    once.
  * **Admission**: queued arrivals are prefilled *together* (prompts
    bucketed to one shape, the admission batch padded to a power of two so
    burst sizes reuse executables), then one fused admit executable per
    (batch, prompt) bucket compacts them into the fixed tier budgets (the
    same Algorithm-1 machinery the one-shot engine uses), samples their
    first tokens and scatters the row slices (`insert_rows`) — row indices
    are *traced*, so inserting into any slots reuses the executable and
    never touches the decode step.
  * **Fused decode blocks**: the host does NOT dispatch per token.  One
    donated `lax.scan` executable runs `sync_every` decode steps back to
    back, appending each step's ``(token, active)`` into an on-device
    emission buffer carried in `ContinuousState`; `decode_block` launches
    it once and drains the buffer with one device→host read per block.
  * **Retirement**: the decode step itself lowers a row's `active` flag when
    it emits EOS or exhausts its token budget — liveness is decided on
    device with no host round-trip in the hot loop.  The host reads the mask
    only at block boundaries, clears the retired row's slots (`clear_row`)
    and recycles it.
  * **Streaming**: completed requests are harvested at every block boundary,
    so short requests leave (and new ones enter) while long ones decode.

The engine is **family-agnostic** (DESIGN.md §4/§5): recurrent (SSM /
hybrid) rows carry per-row `ssm_state`/`conv_state` arenas alongside the KV
tiers — the degenerate fixed-cost budget tier — with the same traced-row
insert/clear discipline (`core.cache.insert_state_rows`), so mamba2 and
zamba2 configs run the identical admission → fused decode → retirement →
recycling path as dense models; the Algorithm-1 budget split applies to the
attention layers only.

Admission has three layouts (DESIGN.md §5): **pad-to-longest** (the
baseline), **length-sorted** (bursts partitioned by padded length bucket,
each bucket prefilled at its own length), and **packed** — the burst's
prompts concatenated into few `pack_len` rows under a block-diagonal mask
(positions reset per segment, recurrent scans reset at segment boundaries)
and prefilled in ONE dispatch, with a fused unpack+admit compacting each
request's KV straight from the packed layout into its row (no
request-shaped intermediate).  All three are token-identical per request
given a layout-independent tier plan (see `admit_many` for the exact
scope); `prefill_pad_tokens` counts what is actually dispatched.

Admission is also **modality-agnostic**: a request is either a 1-D token
prompt or a 2-D ``[len, d]`` embedding sequence produced by the multimodal
intake (`serving/intake.py` — vision patch grids, audio frames, interleaved
text).  Embeds bursts run the same three layouts through embeds-mode
prefill executables and the very same fused admit executables, so vlm and
audio families are first-class continuous-batching citizens
(`continuous_capability` reports every config family admissible).

Retired rows still occupy SIMD lanes until recycled (dense batched compute
cannot drop a row), but they stop extending their caches and — the actual
throughput lever — their slots immediately host new requests instead of
idling until the longest wave member finishes.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.allocation import (BudgetPlan, RecurrentTier, plan_pool_pages,
                                   recurrent_tier, total_state_bytes)
from repro.core.cache import (SlotCache, clear_row, clear_state_row,
                              empty_cache, gather_row_segments, insert_rows,
                              insert_state_rows, pad_cache)
from repro.core.paging import (KVPool, PagePool, audit_pool_accounting,
                               clear_tier_row, empty_pool, empty_paged_tier,
                               insert_tier_rows, pages_for, pages_needed,
                               scatter_rows_to_pages)
from repro.core.policies import (H2O, SINK_H2O, keep_priority, key_norms,
                                 uses_key_norms)
from repro.models.frontend import STUB_FRONTENDS
from repro.models.ssm import empty_decode_state
from repro.models.transformer import n_attn_layers
from repro.serving.decode import (DecodeState, make_tier_indices,
                                  sampled_step)
from repro.serving.engine import Engine, EngineConfig
from repro.serving.prefill import (ChunkPlan, PrefillOut, chunk_prefill,
                                   group_by_bucket, pack_embeds, pad_embeds,
                                   pad_prompts, plan_chunks, plan_pack,
                                   plan_pack_lengths)
from repro.serving.prefix import PrefixCache, PrefixMatch
from repro.serving.sampler import sample


@dataclasses.dataclass(frozen=True)
class ContinuousConfig:
    """Static knobs of the persistent-arena engine (all sizes fix compiled
    shapes — changing any of them means new executables, never a retrace of
    an existing one).  See `docs/API.md` for the full field reference."""
    #: persistent decode rows; the decode block is compiled once for this
    #: batch and every request lives in one row from admission to retirement
    max_concurrency: int = 8
    #: admission prefill shape quantization: prompts right-pad to multiples
    #: of this, so repeated traffic hits memoized prefill executables
    prompt_bucket: int = 32
    #: admission cap; together with `max_new_cap` it sizes the full-cache
    #: arenas, so over-long prompts are rejected at submit time
    max_prompt_len: int = 128
    #: per-request clamp on requested max_new (arena sizing, like above)
    max_new_cap: int = 64
    #: decode steps fused into one dispatched block (emission-buffer depth)
    sync_every: int = 4
    #: length-sorted admission: partition a burst by padded prompt bucket and
    #: prefill each bucket separately instead of padding the whole burst to
    #: its longest arrival.  Off = the pad-to-longest baseline (benchmarked).
    length_sorted: bool = True
    #: packed admission: concatenate a burst's prompts into few rows under a
    #: block-diagonal mask and prefill them in ONE dispatch (DESIGN.md §5);
    #: supersedes `length_sorted` when on.  Token-identical to the bucketed
    #: path; recurrent families additionally require
    #: `prompt_bucket % cfg.ssm_chunk == 0` (checked at construction).
    packed_prefill: bool = False
    #: packed row capacity in tokens; 0 = auto (twice the bucketed
    #: `max_prompt_len`, so one long prompt never forces a row of its own
    #: shape and short bursts still fill a single row)
    pack_len: int = 0
    #: paged KV arenas (DESIGN.md §3): 0 = contiguous per-row arenas (the
    #: baseline), >0 = tier slots live in fixed-size pages of this many
    #: tokens inside ONE global pool; per-row page tables are traced, so
    #: admission / fused decode / retirement keep their zero-retrace
    #: contract.  Any size works (no divisibility constraints); rows only
    #: hold pages for slots they can ever fill, so short requests in big
    #: arenas stop paying for their budget ceiling.
    page_size: int = 0
    #: radix-tree prefix reuse (requires `page_size`>0): admission looks
    #: the prompt up in a host-side radix tree over page-aligned token
    #: chunks and prefills ONLY the unmatched suffix, attending to the
    #: cached prefix pages as read-only context.  Incompatible with
    #: `packed_prefill`, recurrent families and score-based policies
    #: (checked at construction).
    prefix_cache: bool = False
    #: page-pool headroom reserved for cached prefixes; 0 = auto (room for
    #: ~8 full-length prompts).  Cache inserts are best-effort: under pool
    #: pressure LRU leaves evict first, then inserts cache a shorter
    #: prefix
    prefix_pages: int = 0
    #: pool overcommit factor (requires `page_size`>0 when != 1.0): the row
    #: region of the page pool is sized to `overcommit` x the worst case, so
    #: squeezed layers' released pages host MORE resident rows than the
    #: worst-case sizing allows (DESIGN.md §5).  < 1.0 makes admission-time
    #: exhaustion reachable — the engine absorbs it with the degradation
    #: ladder (prefix eviction -> backpressure -> preemption) instead of
    #: raising.  Never drops below one full row quota (liveness floor).
    overcommit: float = 1.0
    #: low watermark, a fraction of usable pool pages: admission stalls
    #: (backpressure) once admitting would leave <= this many pages free
    #: after counting reclaimable prefix residency.  0 = fit-based only.
    watermark_low: float = 0.0
    #: high watermark fraction: a stalled engine resumes admission only once
    #: effective free pages recover PAST this mark (hysteresis, so admission
    #: doesn't flap at the low mark).  Must be >= watermark_low.
    watermark_high: float = 0.0
    #: consecutive fully-stalled scheduler polls tolerated before the ladder
    #: escalates to preempting a victim row (fewest decoded tokens first)
    preempt_after: int = 3
    #: run the pool-accounting audit after every scheduler poll (free list +
    #: refcounts + row tables + prefix residency must tile the pool); debug
    #: flag — tests and the `pool_pressure` bench keep it on
    audit_pool: bool = False
    #: chunked prefill (DESIGN.md §5): long prompts admit as a PENDING row
    #: whose prefill advances at most one `chunk_len` chunk per fused decode
    #: block — inside the SAME dispatch as the resident rows' decode steps —
    #: instead of one monolithic prefill that stalls every resident row.
    #: The final chunk flips the row live for sampling.  Recurrent families
    #: additionally require `prompt_bucket % cfg.ssm_chunk == 0` (checked
    #: at construction) so chunk boundaries sit on the SSD chunk grid.
    chunked_prefill: bool = False
    #: prefill tokens advanced per decode block for a pending chunked row;
    #: must be a multiple of `prompt_bucket` (checked at construction).
    #: 0 = auto (2 buckets).
    chunk_len: int = 0

    def resolved_pack_len(self) -> int:
        b = self.prompt_bucket
        return self.pack_len or 2 * (-(-self.max_prompt_len // b) * b)

    def resolved_chunk_len(self) -> int:
        return self.chunk_len or 2 * self.prompt_bucket


@dataclasses.dataclass(frozen=True)
class Capability:
    """Config-driven report of what the continuous engine does with a model.

    Every architecture family in `configs/` maps onto the persistent-arena
    core — token prompts for text decoders, embeds-carrying requests
    (`serving/intake.py`) for frontend families — and `ok=False` carries
    the one precise reason a config cannot admit
    (`ContinuousEngine.__init__` raises it verbatim).
    """
    family: str                # dense | moe | vlm | audio | ssm | hybrid
    ok: bool
    reason: str                # "" when ok; the exact refusal otherwise
    n_attn_layers: int         # layers under Algorithm-1 budget tiers
    n_recurrent_layers: int    # layers in the fixed-cost recurrent tier
    recurrent: RecurrentTier   # per-row fixed state cost of those layers
    frontend: Optional[str] = None   # stub frontend the intake encodes with
    frontend_tokens: int = 0         # spec patch/frame budget per request

    @property
    def budgeted(self) -> bool:
        """Algorithm 1 has something to reallocate (attention layers exist)."""
        return self.n_attn_layers > 0

    @property
    def embeds_native(self) -> bool:
        """Requests arrive as precomputed frontend embeddings — admitted
        through the intake's embeds paths (`IntakeEncoder` ->
        `admit_many`), not refused."""
        return self.frontend is not None

    def describe(self) -> str:
        if not self.ok:
            return f"{self.family}: NOT admissible — {self.reason}"
        parts = []
        if self.n_attn_layers:
            parts.append(f"{self.n_attn_layers} budget-tiered attention "
                         f"layer(s)")
        if self.n_recurrent_layers:
            parts.append(f"{self.n_recurrent_layers} fixed-cost recurrent "
                         f"layer(s)")
        if self.embeds_native:
            parts.append(f"embeds-native intake ({self.frontend}, "
                         f"~{self.frontend_tokens} frontend tokens/request)")
        return f"{self.family}: " + " + ".join(parts)


def continuous_capability(cfg) -> Capability:
    """What the continuous engine can do with `cfg`, decided from config
    alone (no params, no tracing).  Single source of truth for the
    admission-time check — tests sweep every family in `configs/` through
    this and assert admit-or-precise-error.  Frontend families (vlm/audio)
    admit through the embeds-native intake (`serving/intake.py`); the only
    refusal left is a frontend name no intake encoder exists for."""
    rec = cfg.n_layers if (cfg.is_ssm_only or cfg.is_hybrid) else 0
    ok, reason = True, ""
    if cfg.frontend is not None and cfg.frontend not in STUB_FRONTENDS:
        ok = False
        reason = (f"{cfg.name!r} declares frontend {cfg.frontend!r}, which "
                  f"no intake encoder exists for (known: "
                  f"{', '.join(STUB_FRONTENDS)})")
    return Capability(family=cfg.arch_type, ok=ok, reason=reason,
                      n_attn_layers=n_attn_layers(cfg),
                      n_recurrent_layers=rec,
                      recurrent=recurrent_tier(cfg),
                      frontend=cfg.frontend if ok else None,
                      frontend_tokens=cfg.frontend_tokens)


class ContinuousState(NamedTuple):
    """Carried across decode blocks; `dec.active` is the on-device liveness.

    ``emit_tok`` / ``emit_act`` are the on-device emission ring: a
    DOUBLE-BUFFERED pair of banks ``[2, sync_every, B]`` with the swap
    index ``emit_bank`` carried in the state.  Each fused block writes
    step ``i``-of-the-block's sampled tokens and the pre-step active mask
    (whether the emission counts for that row) into bank ``emit_bank``
    and flips the index, so consecutive blocks alternate banks.  The ring
    lives on device so a fused block never ships per-step arrays to the
    host; the host drains rows ``[0, n_block)`` of the retired bank once
    per block — and because block N+1 writes the OTHER bank, an async
    drain of block N's emissions can overlap block N+1's compute
    (`ContinuousEngine.async_drain`).
    """
    dec: DecodeState
    token: jnp.ndarray       # [B] int32 next input token per row
    remaining: jnp.ndarray   # [B] int32 tokens each row may still emit
    key: jnp.ndarray         # PRNG key (stochastic sampling only)
    emit_tok: jnp.ndarray    # [2, sync_every, B] int32 emission ring
    emit_act: jnp.ndarray    # [2, sync_every, B] bool: emission was live
    emit_bank: jnp.ndarray   # [] int32 bank the NEXT block writes (0/1)
    #: chunked-prefill staging (empty tuple unless `chunked_prefill` is on):
    #: ``(k, v, pos, score, ssm, conv)`` with ``()`` placeholders per family
    #: — the ONE in-flight pending row's accumulated prompt KV
    #: ([n_attn, 1, Cstage, Hkv, hd], pos/score [.., 1, Cstage], -1 = not
    #: yet prefilled) and its recurrent carries, living on device so a
    #: chunk advance is a single fused dispatch (DESIGN.md §5)
    chunk: tuple = ()


@dataclasses.dataclass
class Completed:
    slot: int
    tokens: np.ndarray       # [n_emitted] int32 (includes EOS if hit)
    decode_steps: int        # steps this request spent in the decode loop


def _pow2(n: int) -> int:
    return 1 << (n - 1).bit_length()


class ContinuousEngine:
    """Persistent-arena decode core.  Thin clients: `ContinuousScheduler`
    (request queue + interleave loop) and the benchmarks."""

    def __init__(self, params, cfg, ecfg: EngineConfig,
                 ccfg: ContinuousConfig = ContinuousConfig(), seed: int = 0):
        cfg.validate()   # e.g. hybrid layer count divisible by attn_period
        self.cap = continuous_capability(cfg)
        if not self.cap.ok:
            raise ValueError(self.cap.reason)
        if ccfg.packed_prefill and self.cap.n_recurrent_layers > 0 \
                and ccfg.prompt_bucket % cfg.ssm_chunk != 0:
            # packed segments start at prompt_bucket multiples; aligning
            # them to the SSD chunk grid is what makes a packed segment's
            # recurrent state BIT-identical to its solo prefill
            raise ValueError(
                f"packed prefill with recurrent layers requires "
                f"prompt_bucket ({ccfg.prompt_bucket}) to be a multiple of "
                f"ssm_chunk ({cfg.ssm_chunk}) so segment boundaries align "
                f"with the SSD chunk grid")
        if ccfg.page_size < 0:
            raise ValueError(f"page_size must be >= 0, got {ccfg.page_size}")
        if ccfg.overcommit <= 0:
            raise ValueError(
                f"overcommit must be positive, got {ccfg.overcommit}")
        if not 0.0 <= ccfg.watermark_low <= ccfg.watermark_high < 1.0:
            raise ValueError(
                f"watermarks must satisfy 0 <= low <= high < 1; got "
                f"low={ccfg.watermark_low} high={ccfg.watermark_high}")
        if ccfg.preempt_after < 1:
            raise ValueError(
                f"preempt_after must be >= 1, got {ccfg.preempt_after}")
        if (ccfg.overcommit != 1.0 or ccfg.watermark_high > 0.0) \
                and ccfg.page_size <= 0:
            raise ValueError(
                "overcommit / watermarks require page_size > 0: contiguous "
                "arenas are sized per row, there is no shared pool to "
                "overcommit")
        if ccfg.prefix_cache:
            if ccfg.page_size <= 0:
                raise ValueError(
                    "prefix_cache requires page_size > 0: cached prefixes "
                    "are refcounted KV pages, there is nothing to share in "
                    "contiguous arenas")
            if ccfg.packed_prefill:
                raise ValueError(
                    "prefix_cache is incompatible with packed_prefill: a "
                    "packed row has no per-request context region to attend "
                    "cached pages from")
            if self.cap.n_recurrent_layers > 0:
                raise ValueError(
                    "prefix_cache requires an attention-only model: cached "
                    "KV pages cannot restore a recurrent layer's state at "
                    "the match point")
            if ecfg.policy.name in (H2O, SINK_H2O):
                raise ValueError(
                    f"prefix_cache supports non-accumulating policies only "
                    f"(a reused prefix is never re-prefilled, so "
                    f"{ecfg.policy.name!r} column sums for it would be "
                    f"partial); use sliding_window, streaming_llm or "
                    f"l2_norm")
        if ccfg.chunked_prefill:
            cl = ccfg.resolved_chunk_len()
            if cl <= 0 or cl % ccfg.prompt_bucket != 0:
                raise ValueError(
                    f"chunk_len ({cl}) must be a positive multiple of "
                    f"prompt_bucket ({ccfg.prompt_bucket}) — chunk "
                    f"boundaries must sit on bucket edges so the final "
                    f"chunk always holds the last valid token")
            if (cfg.is_ssm_only or cfg.is_hybrid) \
                    and ccfg.prompt_bucket % cfg.ssm_chunk != 0:
                # chunk lengths are bucket multiples; putting buckets on the
                # SSD chunk grid is what makes a carried recurrent state
                # BIT-identical to the monolithic scan (ssd_chunked resumes
                # from initial_state at an aligned boundary)
                raise ValueError(
                    f"chunked prefill with recurrent layers requires "
                    f"prompt_bucket ({ccfg.prompt_bucket}) to be a "
                    f"multiple of ssm_chunk ({cfg.ssm_chunk}) so chunk "
                    f"boundaries align with the SSD chunk grid")
        self.engine = Engine(params, cfg, ecfg)   # shared prefill/compaction
        self.params = params
        self.cfg = cfg
        self.ecfg = ecfg
        self.ccfg = ccfg
        self._has_attn = cfg.has_attention
        self._has_rec = self.cap.n_recurrent_layers > 0
        # paged mode is an attention-tier concern; an ssm-only config with
        # page_size set simply has no pages (the flag is a no-op)
        self._paged = ccfg.page_size > 0 and self._has_attn
        self.plan: Optional[BudgetPlan] = None
        self.state: Optional[ContinuousState] = None
        B = ccfg.max_concurrency
        self._free: List[int] = list(range(B))
        self._buf: List[List[int]] = [[] for _ in range(B)]
        self._max_new = [0] * B
        self._steps = [0] * B
        self._occupied: List[int] = []
        self._completed: List[Completed] = []
        # decode-lane accounting (cf. WaveScheduler): every block burns
        # max_concurrency rows per step; useful = rows that were live
        self.row_steps = 0
        self.useful_row_steps = 0
        # host-interaction accounting for the perf trajectory
        # (benchmarks/serving_bench.py): a "dispatch" is one launched
        # executable; fused blocks make decode_dispatches ~ steps/sync_every
        self.decode_dispatches = 0
        self.decode_steps = 0
        self.admit_dispatches = 0     # prefill+admit launches (batched)
        self.admitted = 0             # requests admitted
        self.tokens_emitted = 0       # live tokens streamed to request bufs
        # admission prefill padding accounting (length-sorted admission):
        # pad tokens = what the prefill executables actually processed,
        # prompt tokens = what the requests actually contained
        self.prefill_pad_tokens = 0
        self.prompt_tokens = 0
        # KV elements staged through a REQUEST-SHAPED intermediate during
        # packed admission — the copy the direct packed->arena scatter
        # skips (DESIGN.md §5).  Stays 0 unless a tier's budget exceeds
        # the gathered slice (nothing to evict: the full slice is staged
        # and padded); asserted by benchmarks/serving_bench.py
        self.admit_kv_copy_elems = 0
        # distinct streams: admission first-token sampling (host side) vs
        # the decode loop's per-step sampling key carried in the state —
        # reusing one key would draw correlated samples on both sides
        self._host_key, self._state_key = jax.random.split(
            jax.random.PRNGKey(seed))
        # donation lets XLA update the arenas in place; CPU ignores it
        self._donate = {} if jax.default_backend() == "cpu" \
            else {"donate_argnums": (1,)}
        self._block_fns = {}     # n_steps -> compiled fused decode block
        self._clear_fn = None
        self._admit_fns = {}     # (admit batch NB, prompt bucket P) -> admit
        self._padmit_fns = {}    # (R, pack_len, K, NR, Pout) -> unpack+admit
        self._insert_fns = {}    # (NB, Ptot, M) -> prefix-cache page scatter
        # paged-arena host state (DESIGN.md §3): the page allocator and the
        # radix tree are created with the plan (_init_state); per-slot page
        # ids are freed back to the pool at retirement
        self._pool: Optional[PagePool] = None
        self._prefix: Optional[PrefixCache] = None
        self._row_pages: List[List[int]] = [[] for _ in range(B)]
        # prefix-reuse accounting (benchmarks/serving_bench.py): prompt
        # tokens admitted by page REFERENCE instead of prefill compute,
        # requests that hit the tree, and cache-insert launches
        self.prompt_tokens_referenced = 0
        self.prefix_hits = 0
        self.prefix_insert_dispatches = 0
        # pool-pressure accounting (the degradation ladder, DESIGN.md §5):
        # rows preempted mid-decode, their re-queued resumptions (the
        # scheduler increments requeues), polls that held queued requests
        # under pressure, low-watermark stall transitions, and the high
        # point of simultaneously resident rows — the number the
        # `pool_pressure` bench compares against worst-case sizing
        self.preemptions = 0
        self.requeues = 0
        self.stall_polls = 0
        self.watermark_hits = 0
        self.peak_resident_rows = 0
        self._stalled = False    # low-watermark hysteresis state
        # chunked-prefill host state (DESIGN.md §5): at most ONE pending
        # row accumulates prompt KV chunk-by-chunk in the on-device staging
        # buffers; `_pending` holds its slot / plan / progress until the
        # final chunk flips it live.  Latency counters for the SLO story
        # (benchmarks/serving_bench.py latency_trace): chunk-carrying block
        # launches (each rode an EXISTING decode dispatch — chunking never
        # adds dispatches), rows admitted chunked, and prompt tokens
        # prefilled through chunks.
        self._pending: Optional[dict] = None
        self._chunk_fns = {}     # (C, n_steps, final) -> chunk+decode block
        self._chunk_reset_fn = None
        self.chunked_admitted = 0
        self.chunk_dispatches = 0
        self.chunk_tokens_prefilled = 0
        # async emission drain (serving/service.py, DESIGN.md §5): when on,
        # `decode_block` drains block N-1's retired ring bank AFTER
        # dispatching block N, so the device→host read overlaps the
        # in-flight compute instead of stalling on it.  `_inflight` holds
        # the undrained record; `_bank` mirrors `state.emit_bank` on the
        # host (reading the device scalar back would itself stall);
        # `_slot_gen` is a per-slot tenancy counter so a drain that lags a
        # retire-and-readmit cycle can never retire the NEW tenant.
        self.async_drain = False
        self._inflight: Optional[dict] = None
        self._bank = 0
        self._slot_gen = [0] * B
        self.drain_stall_s = 0.0      # host time blocked inside the drain
        self.drained_blocks = 0
        self.cancellations = 0        # rows cancelled mid-flight (service)
        # per-token emission journal (the streaming tap): when a list, the
        # engine appends ``(slot, token, t_host)`` for every live emission
        # — admission first tokens at admit time, block emissions at DRAIN
        # time (the honest host-visibility timestamp).  The scheduler
        # flushes it to per-request hooks; None keeps the hot loop free of
        # journaling entirely.
        self.emit_journal: Optional[list] = None

    # ------------------------------------------------------------ properties
    @property
    def has_free(self) -> bool:
        return bool(self._free)

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_occupied(self) -> int:
        return len(self._occupied)

    @property
    def n_pending(self) -> int:
        """Chunk-admitted rows still prefilling (0 or 1): holding a slot
        but not yet live — not occupied, not preemptible, advanced one
        chunk per decode block until the final chunk flips them live."""
        return 0 if self._pending is None else 1

    @property
    def pending_slot(self) -> Optional[int]:
        """Slot reserved by the in-flight chunked admission (None if no
        row is pending) — how the service layer distinguishes cancelling
        a mid-prefill row from cancelling a live one."""
        return None if self._pending is None else self._pending["slot"]

    @property
    def pending_prefilled_len(self) -> int:
        """Prompt tokens the pending row has staged so far (0 if none
        pending) — `prefilled_len < prompt_len` is the partially-prefilled
        contract `scheduler.poll` admits under."""
        if self._pending is None:
            return 0
        plan = self._pending["plan"]
        return sum(plan.lens[:self._pending["next"]])

    @property
    def chunk_ready(self) -> bool:
        """True once chunked admission can run: mode on AND the plan is
        calibrated (the first request must go monolithic via `admit_many`
        so `_ensure_plan` sees a batched prefill)."""
        return self.ccfg.chunked_prefill and self._chunk_reset_fn is not None

    @property
    def occupied_slots(self) -> List[int]:
        """Live row indices, admission order (a copy)."""
        return list(self._occupied)

    def decoded_tokens(self, slot: int) -> int:
        """Tokens an occupied row has generated so far (admission token
        included) — the preemption cost the victim policy minimizes."""
        return len(self._buf[slot])

    @property
    def pool_pages(self) -> int:
        """Usable pages in the global pool (0 until the plan is calibrated
        or in contiguous mode); excludes the reserved null page."""
        return self._pool.n_pages - 1 if self._pool is not None else 0

    @property
    def pool_pages_resident(self) -> int:
        """Pages currently held by rows or the prefix cache."""
        return self._pool.n_resident if self._pool is not None else 0

    @property
    def pool_occupancy(self) -> float:
        """Resident fraction of the usable pool, in [0, 1]."""
        return self.pool_pages_resident / self.pool_pages \
            if self.pool_pages else 0.0

    @property
    def state_bytes(self) -> int:
        """Persistent decode-state footprint across all rows: budgeted KV
        arenas (0 until the plan is calibrated) plus the fixed-cost
        recurrent tier — the full 2D budget picture for hybrid families."""
        plan = self.plan if self._has_attn else None
        return total_state_bytes(plan, self.cap.recurrent,
                                 self.ccfg.max_concurrency,
                                 self.cfg.n_kv_heads, self.cfg.hd,
                                 jnp.dtype(self.cfg.dtype).itemsize)

    # ---------------------------------------------------------------- jit fns
    def _build_fns(self):
        has_attn, has_rec = self._has_attn, self._has_rec
        paged = self._paged

        def clear(state: ContinuousState, row):
            dec = state.dec
            upd = {"active": dec.active.at[row].set(False)}
            if has_attn:
                # paged: metadata-only — drop the page table, never touch
                # pool contents (the host frees the page ids separately)
                fn = clear_tier_row if paged else clear_row
                upd["tiers"] = tuple(fn(tr, row) for tr in dec.tiers)
            if has_rec:
                upd["ssm_state"] = clear_state_row(dec.ssm_state, row)
                upd["conv_state"] = clear_state_row(dec.conv_state, row)
            return state._replace(dec=dec._replace(**upd))

        donate0 = {} if not self._donate else {"donate_argnums": (0,)}
        self._clear_fn = jax.jit(clear, **donate0)

        if self.ccfg.chunked_prefill:
            def chunk_reset(state: ContinuousState):
                # wipe the staging METADATA between pending rows: stale pos
                # entries would unmask a previous prompt's keys, stale
                # scores/carries would leak into the next accumulation.
                # K/V values can stay (pos = -1 masks them everywhere).
                ck, cv, cpos, csc, cssm, cconv = state.chunk
                z = jax.tree.map(jnp.zeros_like, (csc, cssm, cconv))
                if self._has_attn:
                    cpos = jnp.full_like(cpos, -1)
                return state._replace(chunk=(ck, cv, cpos) + z)

            self._chunk_reset_fn = jax.jit(chunk_reset, **donate0)

    def _block_jit(self, n_steps: int):
        """Compiled fused decode block: `n_steps` serve_step iterations in
        ONE donated `lax.scan` executable.  Each step samples, updates the
        on-device `active` mask (EOS / budget exhaustion) and appends
        ``(token, pre-step active)`` to the emission buffer; the host sees
        nothing until it drains the buffer at the block boundary.  Memoized
        per block length — the tail of a drain runs shorter blocks, so at
        most `sync_every` executables exist."""
        if n_steps not in self._block_fns:
            def block(params, state: ContinuousState) -> ContinuousState:
                return self._scan_steps(params, state, n_steps)

            self._block_fns[n_steps] = jax.jit(block, **self._donate)
        return self._block_fns[n_steps]

    def _scan_steps(self, params, state: ContinuousState, n_steps: int):
        """Traced interior of every fused decode block (`_block_jit` AND the
        chunk-carrying executables `_chunk_jit`): `n_steps` sampled decode
        steps in one `lax.scan`, appending ``(token, pre-step active)`` to
        the on-device emission buffer each step.  Fields outside the decode
        loop (the chunk staging) pass through untouched."""
        cfg, pol, sc = self.cfg, self.ecfg.policy, self.ecfg.sampler
        eos = self.ecfg.eos_token
        use_flash = self.ecfg.use_flash_decode
        # the ring bank this block writes is loop-invariant: bind it
        # outside the scan body and flip it once after the scan, so the
        # next block lands in the OTHER bank (double-buffered drain)
        bank = state.emit_bank
        zero = jnp.int32(0)

        def body(st, i):
            active_prev = st.dec.active
            nxt, dec, key = sampled_step(
                params, cfg, pol, sc, st.dec, st.token, st.key,
                use_flash=use_flash)
            rem = st.remaining - active_prev.astype(jnp.int32)
            done = active_prev & (rem <= 0)
            if eos >= 0:
                done = done | (active_prev & (nxt == eos))
            dec = dec._replace(active=active_prev & ~done)
            return st._replace(
                dec=dec, token=nxt, remaining=rem, key=key,
                emit_tok=jax.lax.dynamic_update_slice(
                    st.emit_tok, nxt[None, None, :], (bank, i, zero)),
                emit_act=jax.lax.dynamic_update_slice(
                    st.emit_act, active_prev[None, None, :],
                    (bank, i, zero))), None

        # the chunk staging is loop-invariant: detach it from the scan
        # carry so plain decode blocks never shuttle the (multi-MB)
        # staging arrays through the while-loop state
        chunk = state.chunk
        state, _ = jax.lax.scan(body, state._replace(chunk=()),
                                jnp.arange(n_steps, dtype=jnp.int32))
        return state._replace(chunk=chunk, emit_bank=1 - bank)

    def _admit_jit(self, NB: int, P: int):
        """Compiled admission for one (admit batch, prompt) bucket:
        Algorithm-1 compaction of the batched prefill into row-shaped tier
        arenas, fused with the `insert_rows` scatter and first-token
        sampling.  One executable per (NB, P, max_concurrency, tier sizes) —
        row indices are traced, so admitting into ANY slots reuses it, and
        pad rows of a partial admit batch carry the drop sentinel
        ``max_concurrency`` so their scatter is discarded.  (Running the
        compaction eagerly instead costs ~100ms of op-dispatch per
        admission — it dominated the serving trace before this was fused.)"""
        key = (NB, P)
        if key not in self._admit_fns:
            def admit_fn(state: ContinuousState, rows, pre, rem0, akey, tbls):
                return self._admit_apply(state, rows, pre, rem0, akey, NB,
                                         tbls)

            donate0 = {} if not self._donate else {"donate_argnums": (0,)}
            self._admit_fns[key] = jax.jit(admit_fn, **donate0)
        return self._admit_fns[key]

    def _ctx_admit_jit(self, NB: int, Psuf: int):
        """Compiled admission for a prefix-HIT bucket: the suffix-only
        `prefill_ctx` output (context pages + suffix, request-shaped)
        compacts through the same Algorithm-1 machinery, but the ctx-concat
        slot layout interleaves empties with valid tokens, so the row
        arenas are canonicalized (`sort_slots`) back to the valid-prefix
        layout decode's in-order empty filling relies on.  Keyed separately
        from the plain buckets — the executables differ in the canonical
        sort only."""
        key = ("ctx", NB, Psuf)
        if key not in self._admit_fns:
            def admit_fn(state: ContinuousState, rows, pre, rem0, akey, tbls):
                rs = self.engine.build_state(pre, self.plan, NB,
                                             canonical=True)
                return self._apply_rows(state, rows, rs, pre.last_logits,
                                        rem0, akey, tbls)

            donate0 = {} if not self._donate else {"donate_argnums": (0,)}
            self._admit_fns[key] = jax.jit(admit_fn, **donate0)
        return self._admit_fns[key]

    def _admit_apply(self, state: ContinuousState, rows, pre: PrefillOut,
                     rem0, akey, NB: int, tbls):
        """Traced tail of the bucketed admit executables: Algorithm-1
        compaction of a request-shaped `PrefillOut` into row-shaped tier
        arenas (`Engine.build_state`), then the shared `_apply_rows`
        sampling + scatter."""
        rs = self.engine.build_state(pre, self.plan, NB)  # [L, NB, S, ...]
        return self._apply_rows(state, rows, rs, pre.last_logits, rem0, akey,
                                tbls)

    def _apply_rows(self, state: ContinuousState, rows, rs: DecodeState,
                    last_logits, rem0, akey, tbls=()):
        """Traced tail shared by the bucketed AND packed admit executables:
        first-token sampling and the drop-sentinel `insert_rows` scatter of
        pre-built row-shaped tier arenas into the persistent state.

        Paged mode receives `tbls` — one host-allocated ``[Lt, NB, npp]``
        page table per tier, ordered like ``plan.layer_tiers()`` (drop
        sentinel ``pool.n_pages`` on pad rows and released tail entries) —
        and splits the insert: pos/score metadata scatter into the tier
        rows while the K/V slots chunk-scatter into the global pool at
        those pages, both with traced indices (same zero-retrace contract
        as `insert_rows`)."""
        sc, eos = self.ecfg.sampler, self.ecfg.eos_token
        token0 = sample(last_logits, akey, sc)               # [NB]
        act0 = rem0 > 0
        if eos >= 0:
            act0 = act0 & (token0 != eos)
        dec = state.dec
        upd = {
            "t": dec.t.at[rows].set(rs.t.astype(dec.t.dtype), mode="drop"),
            "active": dec.active.at[rows].set(act0, mode="drop"),
        }
        if self._has_attn and self._paged:
            sent = self._pool.sentinel
            pool = dec.kv_pool
            new_tiers = []
            for tr, rt, tbl in zip(dec.tiers, rs.tiers, tbls):
                new_tiers.append(insert_tier_rows(tr, rt, rows, tbl, sent))
                pool = scatter_rows_to_pages(pool, rt.k, rt.v, tbl)
            upd["tiers"] = tuple(new_tiers)
            upd["kv_pool"] = pool
        elif self._has_attn:
            upd["tiers"] = tuple(insert_rows(tr, rt, rows)
                                 for tr, rt in zip(dec.tiers, rs.tiers))
        if self._has_rec:    # fixed-cost tier: whole-row state scatter
            upd["ssm_state"] = insert_state_rows(
                dec.ssm_state, rs.ssm_state, rows)
            upd["conv_state"] = insert_state_rows(
                dec.conv_state, rs.conv_state, rows)
        dec = dec._replace(**upd)
        return token0, state._replace(
            dec=dec,
            token=state.token.at[rows].set(
                token0.astype(state.token.dtype), mode="drop"),
            remaining=state.remaining.at[rows].set(rem0, mode="drop"))

    def _packed_tiers(self, kp, vp, cpos, scores, row_idx, start, t,
                      Pout: int, NR: int):
        """Direct packed->tier compaction (DESIGN.md §5, the scatter that
        skips the unpack copy).

        The top-k slot selection runs on the cheap request-shaped
        pos/score gathers ([L, NR, Pout] scalars); the heavy K/V tensors
        are then gathered ONCE, straight from the PACKED prefill layout
        into the budget-sized tier rows — ``arena[l, r, j] =
        packed[layer, row_of[r], start[r] + keep_idx[j]]`` — so the
        request-shaped ``[L, NR, Pout, Hkv, hd]`` KV intermediate the old
        unpack staged never materializes.  The only fallback is a tier
        whose budget exceeds the slice (nothing to evict): the full slice
        is staged and padded with empty slots, and
        ``admit_kv_copy_elems`` counts it.
        """
        pol, plan = self.ecfg.policy, self.plan
        Ppack = kp.shape[2]

        def tier(idx, budget):
            sel = jnp.asarray(idx, jnp.int32)
            pos_t = jnp.take(cpos, sel, axis=0)
            sc_t = jnp.take(scores, sel, axis=0)
            if budget <= Pout:
                pri = keep_priority(pol, pos_t, sc_t, t, budget)
                _, ix = jax.lax.top_k(pri, budget)      # [Lt, NR, budget]
                ix = jnp.sort(ix, axis=-1).astype(jnp.int32)
                # absolute packed coordinates; a keep index past the row's
                # end is clamped — its pos is already -1 (empty), so the
                # clamped k/v bits are masked everywhere downstream
                absp = jnp.minimum(start[None, :, None] + ix, Ppack - 1)
                li = sel[:, None, None]
                ri = row_idx[None, :, None]
                return SlotCache(
                    k=kp[li, ri, absp], v=vp[li, ri, absp],
                    pos=jnp.take_along_axis(pos_t, ix, axis=-1),
                    score=jnp.take_along_axis(sc_t, ix, axis=-1))
            # budget > slice: compaction is a no-op, so stage the full
            # request-shaped slice (counted host-side) and grow it
            k = gather_row_segments(jnp.take(kp, sel, axis=0), row_idx,
                                    start, Pout, 0)
            v = gather_row_segments(jnp.take(vp, sel, axis=0), row_idx,
                                    start, Pout, 0)
            return pad_cache(SlotCache(k, v, pos_t, sc_t), budget)

        return tuple(tier(idx, budget)
                     for budget, idx in plan.layer_tiers())

    def _padmit_jit(self, R: int, Ppack: int, K: int, NR: int, Pout: int):
        """Compiled unpack+admit for one packed-layout shape, with the
        DIRECT packed->arena scatter: logits / recurrent snapshots are
        gathered at their per-segment take positions, the H2O column sums
        are normalized by the request's own length, and the KV tiers are
        compacted straight out of the packed layout (`_packed_tiers`) —
        no request-shaped KV intermediate — before the shared
        `_apply_rows` scatter.  Row/start/segment indices are traced, so
        one executable per (rows, pack_len, segs, admit batch, slice len)
        serves every packing outcome, token AND embeds bursts alike (the
        packed prefill output has the same structure either way)."""
        key = (R, Ppack, K, NR, Pout)
        if key not in self._padmit_fns:
            has_attn, has_rec = self._has_attn, self._has_rec

            def padmit(state: ContinuousState, rows, ppre, row_idx, start,
                       seg_of, t_req, slot_len, rem0, akey, tbls):
                last = ppre.seg_logits[row_idx, seg_of]          # [NR, V]
                t32 = t_req.astype(jnp.int32)
                tiers = tier_of = tier_index = ()
                if has_attn:
                    cpos = gather_row_segments(ppre.cache_pos, row_idx,
                                               start, Pout, -1)
                    # a request's slice may extend past its own slot into a
                    # neighbouring segment (Pout is the burst-wide max):
                    # those slots must read EMPTY, exactly like the bucketed
                    # path's right padding
                    own = jnp.arange(Pout)[None, :] < slot_len[:, None]
                    cpos = jnp.where(own[None], cpos, -1)
                    if uses_key_norms(self.ecfg.policy):
                        # l2_norm: the score channel holds the slots' static
                        # key norms — no colsum gather, no /t normalization
                        nrm = gather_row_segments(key_norms(ppre.k), row_idx,
                                                  start, Pout, 0.0)
                        scores = jnp.where(own[None], nrm, 0.0)
                    else:
                        raw = gather_row_segments(ppre.colsums, row_idx,
                                                  start, Pout, 0.0)
                        scores = jnp.where(
                            own[None], raw, 0.0) / jnp.clip(
                                t_req.astype(jnp.float32)[None, :, None], 1.0)
                    tiers = self._packed_tiers(
                        ppre.k, ppre.v, cpos, scores, row_idx, start, t32,
                        Pout, NR)
                    tier_of, tier_index = make_tier_indices(
                        self.plan.tier_of)
                if has_rec:      # snapshots: one state per packed segment
                    st, cv = ppre.ssm_state
                    ssm, conv = st[:, row_idx, seg_of], cv[:, row_idx, seg_of]
                else:
                    ssm = conv = ()
                rs = DecodeState(tiers, tier_of, tier_index, ssm, conv, t32)
                return self._apply_rows(state, rows, rs, last, rem0, akey,
                                        tbls)

            donate0 = {} if not self._donate else {"donate_argnums": (0,)}
            self._padmit_fns[key] = jax.jit(padmit, **donate0)
        return self._padmit_fns[key]

    def _chunk_jit(self, C: int, n_steps: int, final: bool):
        """Compiled chunk-carrying fused block (DESIGN.md §5): ONE dispatch
        runs (a) the pending row's next prefill chunk — forward over ``C``
        tokens attending the staged previous chunks as read-only context
        (`prefill.chunk_prefill`), recurrent layers resuming from the
        staged carries — (b) the staging-buffer update, (c) on the FINAL
        chunk the whole admission tail (Algorithm-1 compaction of the
        assembled staging `PrefillOut`, first-token sampling, the
        row/paged scatters — the exact `_admit_apply` the monolithic path
        runs), and (d) `n_steps` decode steps for the resident rows
        (`_scan_steps`).  Decode therefore never waits on a prefill-only
        dispatch; the chunk rides the block it would have stalled.

        Memoized per (chunk length, block length, final?) — chunk lengths
        come from the tiny bucket-multiple set `prefill.plan_chunks`
        guarantees and ``start`` / row indices are traced, so repeated
        long-prompt traffic never retraces."""
        key = (C, n_steps, final)
        if key not in self._chunk_fns:
            has_attn, has_rec = self._has_attn, self._has_rec
            cfg = self.cfg

            def advance(params, state: ContinuousState, tok_c, val_c, start):
                ck, cv, cpos, csc, cssm, cconv = state.chunk
                ctx = (ck, cv, cpos) if has_attn else None
                st_in = (cssm, cconv) if has_rec else None
                out = chunk_prefill(params, cfg, tok_c, val_c, start,
                                    ctx=ctx, state_in=st_in)
                if has_attn:
                    ck = jax.lax.dynamic_update_slice(
                        ck, out.k.astype(ck.dtype), (0, 0, start, 0, 0))
                    cv = jax.lax.dynamic_update_slice(
                        cv, out.v.astype(cv.dtype), (0, 0, start, 0, 0))
                    cpos = jax.lax.dynamic_update_slice(
                        cpos, out.pos_row, (0, start))
                    Cs = csc.shape[-1]
                    if uses_key_norms(self.ecfg.policy):
                        # l2_norm: the score channel holds static key norms
                        # — write the chunk's norms at their offset, never
                        # accumulate (the colsum plumbing is bypassed;
                        # build_state recomputes norms from the staged K at
                        # the final chunk either way)
                        csc = jax.lax.dynamic_update_slice(
                            csc, key_norms(out.k), (0, 0, start))
                    else:
                        # the chunk's colsums cover [staged | chunk] keys:
                        # the staged part ACCUMULATES (later queries add
                        # mass to earlier keys, the H2O invariant), the
                        # chunk's own keys are fresh — write them at their
                        # offset (their staged-part contribution is exactly
                        # 0: pos=-1 masked)
                        csc = csc + out.colsums[..., :Cs]
                        csc = jax.lax.dynamic_update_slice(
                            csc, out.colsums[..., Cs:], (0, 0, start))
                if has_rec:
                    cssm, cconv = out.ssm_state
                return out, state._replace(
                    chunk=(ck, cv, cpos, csc, cssm, cconv))

            if final:
                def fn(params, state, tok_c, val_c, start, t_req, rows,
                       rem0, akey, tbls):
                    out, state = advance(params, state, tok_c, val_c, start)
                    ck, cv, cpos, csc, cssm, cconv = state.chunk
                    t32 = t_req.astype(jnp.int32)
                    if has_attn:
                        La, _, Cs = csc.shape
                        cache_pos = jnp.broadcast_to(cpos[None], (La, 1, Cs))
                        # l2_norm staging already holds norms (no /t);
                        # accumulating policies normalize by prompt length
                        scores = csc if uses_key_norms(self.ecfg.policy) \
                            else csc / jnp.clip(
                                t32.astype(jnp.float32)[None, :, None], 1.0)
                        pk, pv = ck, cv
                    else:
                        pk = pv = cache_pos = scores = None
                    pre = PrefillOut(
                        out.last_logits,
                        jnp.zeros((n_attn_layers(cfg), 1), jnp.float32),
                        pk, pv, cache_pos, scores,
                        (cssm, cconv) if has_rec else None, t32)
                    token0, state = self._admit_apply(state, rows, pre,
                                                      rem0, akey, 1, tbls)
                    return token0, self._scan_steps(params, state, n_steps)
            else:
                def fn(params, state, tok_c, val_c, start):
                    _, state = advance(params, state, tok_c, val_c, start)
                    return self._scan_steps(params, state, n_steps)

            self._chunk_fns[key] = jax.jit(fn, **self._donate)
        return self._chunk_fns[key]

    # ------------------------------------------------------------- state init
    def _prefix_budget(self) -> int:
        """Pool headroom reserved for the radix tree's resident pages."""
        if not self.ccfg.prefix_cache:
            return 0
        if self.ccfg.prefix_pages:
            return self.ccfg.prefix_pages
        psize = self.ccfg.page_size
        return 8 * pages_for(self.ccfg.max_prompt_len, psize) \
            * n_attn_layers(self.cfg)

    @property
    def _cmax(self) -> int:
        """Static page capacity of the context region in ctx-prefill
        executables: enough pages for the longest admissible prompt."""
        return pages_for(self.ccfg.max_prompt_len, self.ccfg.page_size)

    @property
    def _chunk_stage_len(self) -> int:
        """Static length of the chunk staging buffers: the bucket-rounded
        longest CHUNK-admissible prompt.  Chunked admission takes token
        prompts up to `max_prompt_len` only (resumed over-long prompts go
        monolithic), so this bounds every plan's padded total."""
        b = self.ccfg.prompt_bucket
        return -(-self.ccfg.max_prompt_len // b) * b

    @property
    def _admit_max_len(self) -> int:
        """Admission-time prompt cap.  A PREEMPTED request resumes as
        prompt + generated-so-far, which can legitimately exceed
        `max_prompt_len` by up to ``max_new_cap - 1`` tokens; the arenas
        are sized for ``max_prompt_len + max_new_cap`` total positions, so
        the relaxed cap never overflows a tier.  User-facing submission
        still enforces `max_prompt_len` (`ContinuousScheduler.submit`)."""
        return self.ccfg.max_prompt_len + self.ccfg.max_new_cap - 1

    def _init_state(self) -> ContinuousState:
        cfg, plan = self.cfg, self.plan
        B = self.ccfg.max_concurrency
        E = self.ccfg.sync_every
        dtype = jnp.dtype(cfg.dtype)

        kv_pool = ()
        if self._has_attn:
            # plans never produce empty tiers (uniform collapses to one tier,
            # allocate/zigzag merge away empty sides), so every arena below
            # holds at least one layer — no dummy tiers needed
            tier_of, tier_index = make_tier_indices(plan.tier_of)
            if self._paged:
                psize = self.ccfg.page_size
                tiers = tuple(
                    empty_paged_tier(len(layers), B, budget, psize)
                    for budget, layers in plan.layer_tiers())
                n_pool = plan_pool_pages(plan, B, psize,
                                         prefix_pages=self._prefix_budget(),
                                         overcommit=self.ccfg.overcommit)
                self._pool = PagePool(n_pool)
                usable = n_pool - 1
                self._pool.set_watermarks(
                    int(self.ccfg.watermark_low * usable),
                    int(self.ccfg.watermark_high * usable))
                kv_pool = empty_pool(n_pool, psize, cfg.n_kv_heads, cfg.hd,
                                     dtype)
                if self.ccfg.prefix_cache:
                    self._prefix = PrefixCache(self._pool, psize,
                                               n_attn_layers(cfg))
            else:
                tiers = tuple(
                    empty_cache(len(layers), B, budget, cfg.n_kv_heads,
                                cfg.hd, dtype)
                    for budget, layers in plan.layer_tiers())
        else:                     # ssm-only: no KV tiers exist at all
            tier_of = tier_index = tiers = ()
        if self._has_rec:         # fixed-cost recurrent tier, one row each
            ssm, conv = empty_decode_state(cfg, self.cap.n_recurrent_layers,
                                           B)
        else:
            ssm = conv = ()
        dec = DecodeState(
            tiers=tiers, tier_of=tier_of, tier_index=tier_index,
            ssm_state=ssm, conv_state=conv,
            t=jnp.zeros((B,), jnp.int32),
            active=jnp.zeros((B,), bool),
            kv_pool=kv_pool)
        chunk = ()
        if self.ccfg.chunked_prefill:
            # staging for the ONE pending chunked row: full-prompt KV
            # accumulates here chunk by chunk, assembled into a PrefillOut
            # at the final chunk.  Sized for the longest admissible prompt
            # (bucket-rounded); positions -1 mask the not-yet-written tail
            # exactly like empty cache slots.
            Cs = self._chunk_stage_len
            ck = cv = cpos = csc = cssm = cconv = ()
            if self._has_attn:
                La = n_attn_layers(cfg)
                ck = jnp.zeros((La, 1, Cs, cfg.n_kv_heads, cfg.hd), dtype)
                cv = jnp.zeros((La, 1, Cs, cfg.n_kv_heads, cfg.hd), dtype)
                cpos = jnp.full((1, Cs), -1, jnp.int32)
                csc = jnp.zeros((La, 1, Cs), jnp.float32)
            if self._has_rec:
                cssm, cconv = empty_decode_state(
                    cfg, self.cap.n_recurrent_layers, 1)
            chunk = (ck, cv, cpos, csc, cssm, cconv)
        return ContinuousState(
            dec,
            token=jnp.zeros((B,), jnp.int32),
            remaining=jnp.zeros((B,), jnp.int32),
            key=self._state_key,
            emit_tok=jnp.zeros((2, E, B), jnp.int32),
            emit_act=jnp.zeros((2, E, B), bool),
            emit_bank=jnp.zeros((), jnp.int32),
            chunk=chunk)

    def _ensure_plan(self, pre):
        """Fix (tier sizes, layer grouping) on first admission.

        In squeeze mode the grouping calibrates on the first admitted
        batch's cosine sims (Algorithm 1, batch-averaged); full/uniform are
        request-independent.  Everything afterwards reuses the same
        compiled executables.
        """
        if self.plan is not None:
            return
        cos = np.asarray(pre.cos_sims).mean(axis=-1) if pre.cos_sims.size \
            else np.zeros(0)
        self.plan = self.engine.plan_budgets(
            cos, self.ccfg.max_prompt_len, self.ccfg.max_new_cap)
        self.state = self._init_state()
        self._bank = 0           # host mirror of the fresh ring's swap index
        self._build_fns()

    # -------------------------------------------------------------- admission
    def _alloc_row_tables(self, slots: List[int], t_list: Sequence[int],
                          mn_list: Sequence[int], NB: int):
        """Allocate per-row page tables for one admit batch (paged mode).

        Returns one ``[Lt, NB, npp]`` int32 host array per tier, ordered
        like ``plan.layer_tiers()``.  Each row gets
        `pages_needed(t, budget, max_new)` pages per layer — the tight
        bound on slots it can EVER fill (decode fills empties in index
        order, see `core.cache.compact`'s paged contract) — so short
        requests in large arenas stop paying for the budget ceiling.
        Unused tail entries and pad rows carry the pool's drop sentinel:
        the K/V scatter discards them and the stored table remaps them to
        the null page.  Allocated ids are recorded per slot and freed at
        retirement."""
        psize = self.ccfg.page_size
        pool, plan = self._pool, self.plan
        sent = pool.sentinel

        def tier_tbl(n_layers, budget):
            npp = pages_for(budget, psize)
            tbl = np.full((n_layers, NB, npp), sent, np.int32)
            for r, (slot, t, mn) in enumerate(zip(slots, t_list, mn_list)):
                need = pages_needed(t, budget, mn, psize)
                for lay in range(n_layers):
                    ids = pool.alloc(need)
                    tbl[lay, r, :need] = ids
                    self._row_pages[slot].extend(int(i) for i in ids)
            return tbl

        return tuple(tier_tbl(len(layers), budget)
                     for budget, layers in plan.layer_tiers())

    # ------------------------------------------------- pool-pressure ladder
    def req_pages(self, prompt_len: int, max_new: int) -> int:
        """Pages ONE request will allocate at admission, across every
        attention layer of every tier (the host twin of
        `_alloc_row_tables`'s per-layer `pages_needed` calls)."""
        plan, psize = self.plan, self.ccfg.page_size
        mn = min(max_new, self.ccfg.max_new_cap)
        return sum(len(layers) * pages_needed(prompt_len, budget, mn, psize)
                   for budget, layers in plan.layer_tiers())

    def admissible_prefix(self, reqs: Sequence[Tuple[np.ndarray, int]]
                          ) -> int:
        """How many leading requests of `reqs` the pool can admit NOW —
        the scheduler's backpressure gate (DESIGN.md §5 degradation
        ladder).

        Contiguous mode admits everything (rows are the only capacity).
        Paged mode charges each request its exact `req_pages` demand
        against the pool's effective headroom: free pages plus the prefix
        cache's reclaimable residency (the ladder's first rung — `alloc`
        LRU-evicts those on demand), minus the low watermark.  Returning 0
        enters the STALLED state; a stalled engine keeps refusing until
        effective free pages recover past the HIGH watermark (hysteresis),
        or a preemption (`preempt`) clears the stall outright.  Scripted
        `PagePool.forced_failures` are consumed here — one refused poll
        per owed failure — so fault injection exercises exactly the
        backpressure path real exhaustion takes.  The low watermark is
        waived when no rows are resident: a lone over-quota-priced
        request must always eventually admit (liveness)."""
        if not self._paged:
            return len(reqs)
        if self._pool is None:
            # the plan (and the pool) calibrate on the first admission;
            # under overcommit admit ONE request so the calibration burst
            # itself cannot overrun the undersized pool
            return 1 if self.ccfg.overcommit < 1.0 else len(reqs)
        pool = self._pool
        if pool.forced_failures > 0:
            pool.forced_failures -= 1
            self._enter_stall()
            return 0
        reclaim = self._prefix.reclaimable_pages if self._prefix else 0
        if self._stalled:
            if pool.above_high(reclaim):
                self._stalled = False
            else:
                return 0
        floor = pool.low_pages if self._occupied else 0
        headroom = pool.n_free + reclaim - floor
        ok = 0
        for p, mn in reqs:
            need = self.req_pages(len(p), mn)
            if need > headroom:
                break
            headroom -= need
            ok += 1
        if ok == 0:
            self._enter_stall()
        return ok

    def _enter_stall(self):
        if not self._stalled:
            self._stalled = True
            self.watermark_hits += 1

    def _release_row(self, slot: int) -> np.ndarray:
        """Evict a LIVE row mid-decode: drain any lagging async record
        first (so the row's banked emissions land in its buffer instead of
        leaking to the slot's next tenant), clear its device slots, release
        its pages, recycle the row, and return the tokens it had generated
        so far (admission token included).  No `Completed` is emitted.
        Shared tail of `preempt` and `cancel`."""
        self.drain_pending()
        if slot not in self._occupied:
            raise ValueError(f"slot {slot} is not occupied")
        self.state = self._clear_fn(self.state, slot)
        if self._paged and self._row_pages[slot]:
            self._pool.free(np.asarray(self._row_pages[slot], np.int32))
            self._row_pages[slot] = []
        self._occupied.remove(slot)
        self._free.append(slot)
        self._slot_gen[slot] += 1       # tenancy over: lagging drains skip it
        toks = np.asarray(self._buf[slot], np.int32)
        self._buf[slot] = []
        self._max_new[slot] = 0
        self._steps[slot] = 0
        return toks

    def preempt(self, slot: int) -> np.ndarray:
        """Evict a LIVE row mid-decode (the ladder's last rung) and return
        its generated tokens — the scheduler re-queues the request as
        prompt + these tokens, so a resumed run re-prefills its own
        history and (greedy, position-based policies) continues
        token-identically.  Clears a watermark stall: the released pages
        are exactly what the stalled admission was waiting for."""
        toks = self._release_row(slot)
        self.preemptions += 1
        self._stalled = False
        return toks

    def cancel(self, slot: int) -> np.ndarray:
        """Cancel a LIVE row (client abandoned the request — the service
        layer's path, never the pressure ladder's): same release as
        `preempt` — pages freed, slot recycled immediately for the next
        admission — but counted separately and with no resume contract;
        the returned partial tokens are informational.  Also clears a
        watermark stall, for the same reason a preemption does."""
        toks = self._release_row(slot)
        self.cancellations += 1
        self._stalled = False
        return toks

    def cancel_pending(self) -> None:
        """Cancel the in-flight CHUNKED admission: free the page tables it
        allocated up front and recycle its slot.  Nothing was scattered to
        the device yet (pages land at the final chunk) and the staging
        metadata is wiped by the next `begin_chunked`, so the release is
        pure host bookkeeping — the pool audit stays clean."""
        if self._pending is None:
            raise ValueError("no pending chunked admission to cancel")
        slot = self._pending["slot"]
        self._pending = None
        if self._paged and self._row_pages[slot]:
            self._pool.free(np.asarray(self._row_pages[slot], np.int32))
            self._row_pages[slot] = []
        self._free.append(slot)
        self._slot_gen[slot] += 1
        self.cancellations += 1
        self._stalled = False

    def audit_pool(self, extra_owned: Sequence[np.ndarray] = (),
                   deep: bool = False) -> None:
        """Assert the page pool's books balance (free list + refcounts +
        row page ids + prefix residency tile ``{1..n_pages-1}``); `deep`
        additionally checks every live device page-table entry is owned.
        No-op in contiguous mode or before the plan is calibrated.
        `extra_owned` names pages held outside the engine (a
        `PoolFaultInjector`'s steals)."""
        if self._pool is None:
            return
        owners = {"rows": [np.asarray(ids, np.int32)
                           for ids in self._row_pages if ids]}
        if self._prefix is not None:
            owners["prefix"] = self._prefix.page_ids()
        if len(extra_owned):
            owners["injected"] = [np.asarray(a, np.int32)
                                  for a in extra_owned]
        tbls = ()
        if deep and self._has_attn:
            tbls = [np.asarray(tr.tbl) for tr in self.state.dec.tiers]
        audit_pool_accounting(self._pool, owners, tbls)

    def admit(self, prompt: np.ndarray, max_new: int) -> int:
        """Prefill one request and insert it into a free row; returns the
        slot.  Raises if no row is free (callers check `has_free`)."""
        return self.admit_many([(prompt, max_new)])[0]

    def admit_many(self, reqs: Sequence[Tuple[np.ndarray, int]]) -> List[int]:
        """Admit up to `n_free` queued requests in one batched admission.

        `reqs` is ``[(prompt, max_new), ...]`` where each prompt is either
        a 1-D int32 token array OR a 2-D float ``[len, d]`` embedding
        sequence (an embeds-carrying vlm/audio request from the intake,
        `serving/intake.py`); the return is the persistent row each
        request landed in, in submission order.  A mixed burst is
        partitioned by modality — embeddings cannot share a prefill
        dispatch with token ids — and each partition runs the configured
        layout below; everything after prefill (the fused admit
        executables, the decode blocks) is modality-blind.  Callers must
        check `n_free` first (asserted).  Three admission layouts, chosen
        by `ContinuousConfig`:

        * **packed** (`packed_prefill=True`) — the burst's prompts are
          concatenated into few `pack_len`-token rows under a
          block-diagonal attention mask (positions reset per segment,
          recurrent scans reset at segment boundaries) and prefilled in
          ONE dispatch; a second fused executable unpacks each request's
          KV slice / recurrent snapshot and scatters it into its row.
          Intra-bucket padding disappears (`prefill_pad_tokens` counts
          rows x pack length actually dispatched).
        * **length-sorted** (default) — the burst is partitioned by padded
          prompt-length bucket (`group_by_bucket`) and each bucket runs
          one batched prefill + one fused admit at ITS OWN length, at the
          cost of one extra dispatch per extra bucket present (both sides
          of the trade are counted: `prefill_pad_tokens`,
          `admit_dispatches`).
        * **pad-to-longest** (`length_sorted=False`) — the whole burst
          pads to the longest prompt in one dispatch (the PR-2 baseline).

        Token-identity scope (greedy sampling, pinned by
        `tests/test_packed_prefill.py`): the bucketed layouts match each
        other and solo `Engine.generate` on the bucket-PADDED prompt for
        every policy.  Packed matches them exactly for position-based
        policies (sliding_window, streaming_llm) and for recurrent
        families (which pack the same bucket-padded slots).  Under
        score-based policies (h2o, sink_h2o) a packed attention-only
        request instead matches solo generate on the UNPADDED prompt: the
        bucketed layouts' pad *queries* inject artifact H2O mass into
        real keys' column sums, which raw-length packing (correctly)
        never produces.

        Every identity claim additionally assumes the tier PLAN is
        layout-independent: mode "full"/"uniform", or squeeze mode with
        an already-calibrated plan.  In squeeze mode the FIRST admission
        calibrates the Algorithm-1 grouping from batch-averaged cosine
        sims, and the packed layout averages over packed ROWS (several
        requests each) rather than per-request columns — so a
        first-burst calibration may group layers differently across
        layouts, after which outputs legitimately diverge.
        """
        assert reqs, "admit_many needs at least one request"
        assert len(reqs) <= len(self._free), \
            "not enough free slots — check n_free before admit_many"
        slots: List[Optional[int]] = [None] * len(reqs)
        tok_idx, emb_idx = [], []
        for i, (p, _) in enumerate(reqs):
            a = np.asarray(p)
            if a.ndim == 2:
                if a.shape[-1] != self.cfg.d_model:
                    raise ValueError(
                        f"embeds prompt has width {a.shape[-1]}, expected "
                        f"d_model={self.cfg.d_model}")
                emb_idx.append(i)
            else:
                tok_idx.append(i)
        for idxs, embeds in ((tok_idx, False), (emb_idx, True)):
            if not idxs:
                continue
            sub = [reqs[i] for i in idxs]
            for i, slot in zip(idxs, self._admit_modality(sub, embeds)):
                slots[i] = slot
        return slots

    def _admit_modality(self, reqs, embeds: bool) -> List[int]:
        """One modality partition of a burst through the configured
        admission layout.

        With the prefix cache live (token prompts only — embeds carry no
        token identity to key the radix tree on), the partition splits
        again by cache outcome: misses run the ordinary bucketed path
        (and then insert their prompt pages), hits prefill ONLY their
        unmatched suffix with the cached pages as context
        (`_admit_ctx_group`).  Matched paths stay pinned until every
        admission of the burst has dispatched its gathers, so same-burst
        allocations cannot LRU-evict pages in flight."""
        if self.ccfg.packed_prefill:
            return self._admit_packed(reqs, embeds=embeds)
        if self._prefix is None or embeds:
            return self._admit_bucketed(reqs, embeds)
        # resumed (preempted) prompts can exceed max_prompt_len; the ctx
        # executables' context region is sized for max_prompt_len pages, so
        # over-long prompts bypass the tree (treated as a miss)
        no_match = PrefixMatch(
            0, np.zeros((self._prefix.n_layers, 0), np.int32), ())
        matches = [self._prefix.lookup(np.asarray(p, np.int32))
                   if len(p) <= self.ccfg.max_prompt_len else no_match
                   for p, _ in reqs]
        try:
            miss = [i for i, m in enumerate(matches) if m.matched == 0]
            hit = [i for i, m in enumerate(matches) if m.matched > 0]
            slots: List[Optional[int]] = [None] * len(reqs)
            if miss:
                got = self._admit_bucketed([reqs[i] for i in miss], embeds)
                for i, slot in zip(miss, got):
                    slots[i] = slot
            if hit:
                # group hits by bucketed SUFFIX length: the ctx executables
                # are keyed on the suffix shape, exactly like plain buckets
                suf = [len(reqs[i][0]) - matches[i].matched for i in hit]
                if self.ccfg.length_sorted and len(hit) > 1:
                    groups = group_by_bucket(suf, self.ccfg.prompt_bucket)
                else:
                    groups = [(0, list(range(len(hit))))]
                for _, idxs in groups:
                    sel = [hit[j] for j in idxs]
                    got = self._admit_ctx_group(
                        [reqs[i] for i in sel], [matches[i] for i in sel])
                    for i, slot in zip(sel, got):
                        slots[i] = slot
            return slots
        finally:
            for m in matches:
                self._prefix.release(m)

    def _admit_bucketed(self, reqs, embeds: bool) -> List[int]:
        """The non-prefix layouts: length-sorted buckets or pad-to-longest."""
        if self.ccfg.length_sorted and len(reqs) > 1:
            groups = group_by_bucket([len(p) for p, _ in reqs],
                                     self.ccfg.prompt_bucket)
        else:
            groups = [(0, list(range(len(reqs))))]
        slots: List[Optional[int]] = [None] * len(reqs)
        for _, idxs in groups:
            got = self._admit_group([reqs[i] for i in idxs], embeds=embeds)
            for i, slot in zip(idxs, got):
                slots[i] = slot
        return slots

    def _admit_group(self, reqs: Sequence[Tuple[np.ndarray, int]],
                     embeds: bool = False) -> List[int]:
        """One admission bucket: ONE prefill dispatch and ONE fused admit
        executable (MaxText `prefill_insert_batch` style).

        Prompts are bucketed together (`pad_prompts`, or `pad_embeds` for
        an embeds-carrying vlm/audio bucket — same shapes, float payload),
        the admit batch is padded to a power of two (pad rows replicate
        request 0 and are dropped by the scatter's sentinel row index), so
        a handful of (batch, prompt) buckets serves any arrival burst.
        The embeds layout gets its own prefill executable but reuses the
        SAME fused admit executable — `PrefillOut` is modality-blind.
        Returns the slot per request, in order.
        """
        max_news = [min(mn, self.ccfg.max_new_cap) for _, mn in reqs]
        n = len(reqs)
        NB = _pow2(n)
        if embeds:
            prompts = [np.asarray(e, np.float32) for e, _ in reqs]
            emb, valid = pad_embeds(prompts, self.ccfg.prompt_bucket,
                                    batch=NB,
                                    max_len=self._admit_max_len)
            for i in range(n, NB):    # pad rows replicate request 0
                emb[i], valid[i] = emb[0], valid[0]
            P = emb.shape[1]
            pre = self.engine.prefill_jit(NB, P, embeds=True)(
                self.params, None, emb, None, valid)
        else:
            prompts = [np.asarray(p, np.int32) for p, _ in reqs]
            toks, valid = pad_prompts(prompts, self.ccfg.prompt_bucket,
                                      batch=NB,
                                      max_len=self._admit_max_len)
            for i in range(n, NB):    # pad rows replicate request 0
                toks[i], valid[i] = toks[0], valid[0]
            P = toks.shape[1]
            pre = self.engine.prefill_jit(NB, P)(self.params, toks, None,
                                                 None, valid)
        self._ensure_plan(pre)
        self.admit_dispatches += 1
        self.prefill_pad_tokens += NB * P
        self.prompt_tokens += sum(len(p) for p in prompts)

        self._host_key, sub = jax.random.split(self._host_key)
        slots = [self._free.pop(0) for _ in range(n)]
        B = self.ccfg.max_concurrency
        rows = np.asarray(slots + [B] * (NB - n), np.int32)   # B = drop
        rem0 = np.asarray([mn - 1 for mn in max_news] + [0] * (NB - n),
                          np.int32)
        tbls = self._alloc_row_tables(slots, [len(p) for p in prompts],
                                      max_news, NB) if self._paged else ()
        token0, self.state = self._admit_jit(NB, P)(
            self.state, rows, pre, rem0, sub, tbls)
        self._register_admitted(slots, np.asarray(token0), max_news, rem0)
        if self._prefix is not None and not embeds:
            # cache this burst's prefixes for later arrivals (best-effort;
            # matched=0: a miss prefilled the whole prompt at slot c*psize)
            self._prefix_insert(prompts, [0] * n, pre, ctx_off=0)
        return slots

    def _admit_ctx_group(self, reqs: Sequence[Tuple[np.ndarray, int]],
                         matches: Sequence[PrefixMatch]) -> List[int]:
        """One prefix-HIT admission bucket: suffix-only prefill.

        Each request's matched pages (pinned by the caller) enter the
        context-prefill executable (`Engine.prefill_ctx_jit`) as traced
        page ids: the kernel gathers them from the pool as read-only
        context K/V — a fixed ``Cmax = pages(max_prompt_len)`` region, the
        unmatched tail masked by ``pos = -1`` — and runs the transformer
        over the suffix tokens ONLY, at their absolute positions.  The
        concatenated (context + suffix) request-shaped output then admits
        through `_ctx_admit_jit` (canonical slot sort included).  Rows
        still copy: the gather writes into privately-owned pages, so cache
        eviction and row retirement never alias (copy-on-admit).
        """
        max_news = [min(mn, self.ccfg.max_new_cap) for _, mn in reqs]
        n = len(reqs)
        NB = _pow2(n)
        prompts = [np.asarray(p, np.int32) for p, _ in reqs]
        suffixes = [p[m.matched:] for p, m in zip(prompts, matches)]
        toks, valid = pad_prompts(suffixes, self.ccfg.prompt_bucket,
                                  batch=NB,
                                  max_len=self._admit_max_len)
        Lat = n_attn_layers(self.cfg)
        Cmax = self._cmax
        ctx_ids = np.zeros((Lat, NB, Cmax), np.int32)   # default: null page
        matched = np.zeros((NB,), np.int32)
        for i, m in enumerate(matches):
            ctx_ids[:, i, :m.ids.shape[1]] = m.ids
            matched[i] = m.matched
        for i in range(n, NB):    # pad rows replicate request 0
            toks[i], valid[i] = toks[0], valid[0]
            ctx_ids[:, i] = ctx_ids[:, 0]
            matched[i] = matched[0]
        Psuf = toks.shape[1]
        pool_dev = self.state.dec.kv_pool
        pre = self.engine.prefill_ctx_jit(NB, Psuf)(
            self.params, toks, valid, matched, pool_dev.kp, pool_dev.vp,
            ctx_ids)
        # a hit implies the tree exists, which implies the plan is fixed —
        # the first burst ever admitted always takes the miss path
        assert self.plan is not None
        self.admit_dispatches += 1
        self.prefill_pad_tokens += NB * Psuf
        self.prompt_tokens += sum(len(p) for p in prompts)
        self.prompt_tokens_referenced += sum(int(m.matched) for m in matches)
        self.prefix_hits += n

        self._host_key, sub = jax.random.split(self._host_key)
        slots = [self._free.pop(0) for _ in range(n)]
        B = self.ccfg.max_concurrency
        rows = np.asarray(slots + [B] * (NB - n), np.int32)   # B = drop
        rem0 = np.asarray([mn - 1 for mn in max_news] + [0] * (NB - n),
                          np.int32)
        tbls = self._alloc_row_tables(slots, [len(p) for p in prompts],
                                      max_news, NB)
        token0, self.state = self._ctx_admit_jit(NB, Psuf)(
            self.state, rows, pre, rem0, sub, tbls)
        self._register_admitted(slots, np.asarray(token0), max_news, rem0)
        # cache the suffix chunks too: the hit's own continuation becomes
        # tomorrow's prefix (pre's slot layout: [Cmax pages | suffix])
        self._prefix_insert(prompts, [int(m.matched) for m in matches], pre,
                            ctx_off=Cmax)
        return slots

    def _insert_jit(self, NB: int, Ptot: int, M: int):
        """Compiled prefix-cache page scatter: copy `M` (row, chunk) slices
        of a request-shaped prefill's K/V into cache-owned pages.  Chunk
        and page indices are traced; pad entries carry the drop sentinel."""
        key = (NB, Ptot, M)
        if key not in self._insert_fns:
            psize = self.ccfg.page_size
            nch = pages_for(Ptot, psize)

            def ins(state: ContinuousState, pre_k, pre_v, rows_sel,
                    chunk_sel, ids):
                pool = state.dec.kv_pool
                L = pre_k.shape[0]

                def chunked(a):
                    pad = [(0, 0), (0, 0), (0, nch * psize - Ptot)] \
                        + [(0, 0)] * (a.ndim - 3)
                    return jnp.pad(a, pad).reshape(
                        L, a.shape[1], nch, psize, *a.shape[3:])

                kc = chunked(pre_k)[:, rows_sel, chunk_sel]  # [L,M,psize,..]
                vc = chunked(pre_v)[:, rows_sel, chunk_sel]
                pool = KVPool(
                    kp=pool.kp.at[ids].set(kc.astype(pool.kp.dtype),
                                           mode="drop"),
                    vp=pool.vp.at[ids].set(vc.astype(pool.vp.dtype),
                                           mode="drop"))
                return state._replace(dec=state.dec._replace(kv_pool=pool))

            donate0 = {} if not self._donate else {"donate_argnums": (0,)}
            self._insert_fns[key] = jax.jit(ins, **donate0)
        return self._insert_fns[key]

    def _prefix_insert(self, prompts: Sequence[np.ndarray],
                       matched_list: Sequence[int], pre: PrefillOut,
                       ctx_off: int):
        """Insert a just-prefilled group's prompt chunks into the radix
        tree and scatter their K/V into the fresh cache pages.

        `insert` returns only NEWLY created nodes (existing chunks already
        hold identical KV — same tokens, same pages — which also dedupes
        identical prompts within one burst), so the scatter copies exactly
        the new chunks.  Source slot of global chunk ``c`` in `pre`'s
        request-shaped layout: plain prefill stores token ``j`` at slot
        ``j`` (``ctx_off = 0``), the ctx layout prepends ``Cmax`` context
        pages before the suffix — both collapse to chunk
        ``ctx_off + c - matched // psize``.  Best-effort: under pool
        pressure the tree caches a shorter prefix and the scatter shrinks
        with it."""
        psize = self.ccfg.page_size
        rows_sel: List[int] = []
        chunk_sel: List[int] = []
        id_cols: List[np.ndarray] = []
        for i, (p, m) in enumerate(zip(prompts, matched_list)):
            for c, ids in self._prefix.insert(p, max_chunks=len(p) // psize):
                rows_sel.append(i)
                chunk_sel.append(ctx_off + c - m // psize)
                id_cols.append(ids)
        if not rows_sel:
            return
        M = _pow2(len(rows_sel))
        sent = self._pool.sentinel
        pad_n = M - len(rows_sel)
        rows = np.asarray(rows_sel + [0] * pad_n, np.int32)
        chunks = np.asarray(chunk_sel + [0] * pad_n, np.int32)
        idm = np.full((self._prefix.n_layers, M), sent, np.int32)
        idm[:, :len(id_cols)] = np.stack(id_cols, axis=1)
        NB, Ptot = pre.k.shape[1], pre.k.shape[2]
        self.state = self._insert_jit(NB, Ptot, M)(
            self.state, pre.k, pre.v, rows, chunks, idm)
        self.prefix_insert_dispatches += 1

    def _admit_packed(self, reqs: Sequence[Tuple[np.ndarray, int]],
                      embeds: bool = False) -> List[int]:
        """Packed admission: ONE packed prefill dispatch for the whole burst
        plus ONE fused unpack+admit executable (DESIGN.md §5).

        The host plans the packing (`prefill.plan_pack_lengths`): prompts
        become segments of few `pack_len`-capacity rows, longest-first
        onto the lightest row.  Recurrent families pack bucket-quantized
        slots — the exact padded shape the bucketed path prefills — so
        segment boundaries stay aligned to the SSD chunk grid and every
        admitted state is bit-identical to its bucketed/solo counterpart;
        attention-only families pack raw prompt lengths (no intra-bucket
        pad tokens at all).  An embeds-carrying burst packs its
        ``[len, d]`` sequences into the ``[R, P, d]`` twin of the token
        rows (`prefill.pack_embeds`) — planner, masks, take-position
        gathers and the unpack+admit executable are all layout-agnostic.
        Returns the slot per request, in order.
        """
        max_news = [min(mn, self.ccfg.max_new_cap) for _, mn in reqs]
        n = len(reqs)
        bucket = self.ccfg.prompt_bucket
        quantum = bucket if self._has_rec else 1
        if embeds:
            prompts = [np.asarray(e, np.float32) for e, _ in reqs]
            plan = plan_pack_lengths([len(e) for e in prompts], bucket,
                                     self.ccfg.resolved_pack_len(),
                                     quantum=quantum,
                                     max_len=self._admit_max_len)
            packed = pack_embeds(plan, prompts)
            ppre = self.engine.packed_prefill_jit(
                plan.n_rows, plan.pack_len, plan.max_segments, embeds=True)(
                    self.params, None, packed, plan.positions, plan.valid,
                    plan.segments, plan.take_last, plan.take_state)
        else:
            prompts = [np.asarray(p, np.int32) for p, _ in reqs]
            plan = plan_pack(prompts, bucket, self.ccfg.resolved_pack_len(),
                             quantum=quantum,
                             max_len=self._admit_max_len)
            ppre = self.engine.packed_prefill_jit(
                plan.n_rows, plan.pack_len, plan.max_segments)(
                    self.params, plan.tokens, None, plan.positions,
                    plan.valid, plan.segments, plan.take_last,
                    plan.take_state)
        self._ensure_plan(ppre)
        self.admit_dispatches += 1
        self.prefill_pad_tokens += plan.packed_tokens
        self.prompt_tokens += int(plan.lengths.sum())

        self._host_key, sub = jax.random.split(self._host_key)
        slots = [self._free.pop(0) for _ in range(n)]
        B = self.ccfg.max_concurrency
        NR = _pow2(n)
        rows = np.asarray(slots + [B] * (NR - n), np.int32)   # B = drop
        rem0 = np.asarray([mn - 1 for mn in max_news] + [0] * (NR - n),
                          np.int32)
        # pad requests replicate request 0's coordinates; their scatter rows
        # carry the drop sentinel, so the duplicate gather never lands
        def pad(a):
            return np.concatenate([a, np.repeat(a[:1], NR - n, 0)])
        Pout = -(-int(plan.slot_len.max()) // bucket) * bucket
        if self._has_attn:
            # request-shaped KV staging happens ONLY in the budget>slice
            # fallback of `_packed_tiers`; mirror its shapes host-side so
            # the bench can assert the direct scatter stayed copy-free
            per = 2 * NR * Pout * self.cfg.n_kv_heads * self.cfg.hd
            for b_t, layers in self.plan.layer_tiers():
                if b_t > Pout:
                    self.admit_kv_copy_elems += len(layers) * per
        tbls = self._alloc_row_tables(
            slots, [int(t) for t in plan.lengths[:n]], max_news,
            NR) if self._paged else ()
        token0, self.state = self._padmit_jit(
            plan.n_rows, plan.pack_len, plan.max_segments, NR, Pout)(
                self.state, rows, ppre, pad(plan.row), pad(plan.start),
                pad(plan.seg), pad(plan.lengths), pad(plan.slot_len),
                rem0, sub, tbls)
        self._register_admitted(slots, np.asarray(token0), max_news, rem0)
        return slots

    def _register_admitted(self, slots: List[int], tok0: np.ndarray,
                           max_news: Sequence[int], rem0: np.ndarray):
        """Host bookkeeping after an admit executable: open emission
        buffers, mark rows occupied (bumping the slot's tenancy generation
        so a lagging async drain cannot touch the new tenant), retire
        instant-EOS / max_new==1 rows."""
        eos = self.ecfg.eos_token
        now = time.perf_counter() if self.emit_journal is not None else 0.0
        for i, slot in enumerate(slots):
            t0 = int(tok0[i])
            self._buf[slot] = [t0]
            self._max_new[slot] = max_news[i]
            self._steps[slot] = 0
            self._slot_gen[slot] += 1
            self._occupied.append(slot)
            self.peak_resident_rows = max(self.peak_resident_rows,
                                          len(self._occupied))
            self.admitted += 1
            self.tokens_emitted += 1
            if self.emit_journal is not None:
                self.emit_journal.append((slot, t0, now))
            if not (rem0[i] > 0 and not (eos >= 0 and t0 == eos)):
                self._retire(slot)

    # --------------------------------------------------------- chunked admit
    def begin_chunked(self, prompt, max_new: int) -> int:
        """Open a chunked admission (DESIGN.md §5): reserve a decode slot
        for ``prompt`` NOW, but prefill it one `chunk_len`-token chunk per
        subsequent `decode_block` instead of in a monolithic dispatch.
        Resident decode rows keep stepping while the prompt streams in;
        the final chunk flips the row live inside the same fused block.

        Preconditions (asserted): `chunked_prefill` on, a free slot, no
        other pending row (the staging buffers hold exactly one), and a
        calibrated plan — the FIRST request of a session must go through
        `admit_many`, whose batched prefill feeds `_ensure_plan`.  Paged
        mode allocates the row's full page tables here, up front, so
        `admissible_prefix` headroom accounting is identical to the
        monolithic path; the pages stay unscattered until the final chunk.

        Returns the slot.  The row is NOT occupied until the final chunk
        lands — track it via `n_pending` / `pending_prefilled_len`."""
        assert self.ccfg.chunked_prefill, "chunked_prefill is off"
        assert self._pending is None, \
            "one pending chunked row at a time (staging buffers hold one)"
        assert self._free, "no free slot for chunked admission"
        assert self._chunk_reset_fn is not None, \
            "chunked admission needs a calibrated plan; admit the first " \
            "request via admit_many"
        p = np.asarray(prompt, np.int32)
        plan = plan_chunks(
            p, self.ccfg.resolved_chunk_len(), self.ccfg.prompt_bucket,
            ssm_chunk=self.cfg.ssm_chunk if self._has_rec else 0,
            max_len=self.ccfg.max_prompt_len)
        mn = min(max_new, self.ccfg.max_new_cap)
        slot = self._free.pop(0)
        tbls = self._alloc_row_tables([slot], [plan.t], [mn], 1) \
            if self._paged else ()
        self.state = self._chunk_reset_fn(self.state)
        self._pending = {"slot": slot, "plan": plan, "next": 0,
                         "max_new": mn, "tbls": tbls}
        self.chunked_admitted += 1
        return slot

    def _advance_chunk(self, pending: dict, n_steps: int):
        """Launch the chunk-carrying fused block for the pending row's next
        chunk (plus `n_steps` decode steps); on the final chunk, run the
        admit tail and open the row's emission buffer."""
        plan: ChunkPlan = pending["plan"]
        c = pending["next"]
        s0, C = plan.starts[c], plan.lens[c]
        tok_c = plan.tokens[None, s0:s0 + C]
        val_c = plan.valid[None, s0:s0 + C]
        start = np.int32(s0)
        if c == plan.n_chunks - 1:
            slot, mn = pending["slot"], pending["max_new"]
            self._host_key, sub = jax.random.split(self._host_key)
            rows = np.asarray([slot], np.int32)
            rem0 = np.asarray([mn - 1], np.int32)
            t_req = np.asarray([plan.t], np.int32)
            token0, self.state = self._chunk_jit(C, n_steps, True)(
                self.params, self.state, tok_c, val_c, start, t_req,
                rows, rem0, sub, pending["tbls"])
            self._pending = None
            self.prefill_pad_tokens += plan.total
            self.prompt_tokens += plan.t
            self._register_admitted([slot], np.asarray(token0), [mn], rem0)
        else:
            self.state = self._chunk_jit(C, n_steps, False)(
                self.params, self.state, tok_c, val_c, start)
            pending["next"] = c + 1
        self.chunk_dispatches += 1
        self.chunk_tokens_prefilled += C

    # ------------------------------------------------------------ decode loop
    def decode_block(self) -> int:
        """Run one fused block (ONE dispatch): up to `sync_every` decode
        steps, plus — when a chunked admission is pending — that row's next
        prefill chunk co-scheduled in the same dispatch.  Drain the
        emission ring (ONE device→host read), retire finished rows.

        Two drain disciplines over the same double-buffered ring:

        * **sync** (default) — drain the bank this block just wrote before
          returning; the `device_get` blocks for the block's full compute
          (that wait is counted in `drain_stall_s`).  Completions are
          visible immediately — the contract every existing caller holds.
        * **async** (`self.async_drain = True`, set by `ServingService`) —
          the just-written bank is parked as the in-flight record and the
          PREVIOUS block's record is drained instead.  Its data finished
          computing while the host was scheduling this block, so the
          `device_get` returns without stalling and the drain overlaps the
          dispatch now in flight.  Emissions and retirements lag one block;
          `drain_pending` flushes the final record.

        Returns the number of requests completed in this call."""
        pending = self._pending
        if not self._occupied and pending is None:
            # nothing to dispatch: in async mode the LAST block may still
            # be parked undrained — flush it so the loop terminates
            return self.drain_pending()
        before = len(self._completed)
        if pending is not None:
            # fixed block length for chunk-carrying dispatches: the bound
            # clamp below would key extra (chunk_len, n) executables for no
            # compute win (rows past their budget go inactive and mask
            # their steps), so every chunk of a given length reuses ONE
            # mid and ONE final executable
            n = self.ccfg.sync_every
            self._advance_chunk(pending, n)
        else:
            # the host knows an exact upper bound on useful steps this
            # block: EOS can only retire rows EARLIER, so don't burn
            # whole-batch steps past the longest remaining token budget
            # (in async mode `_steps` lags one undrained block, so the
            # bound only ever over-estimates — extra steps are masked)
            bound = max(self._max_new[s] - 1 - self._steps[s]
                        for s in self._occupied)
            n = max(1, min(self.ccfg.sync_every, bound))
            self.state = self._block_jit(n)(self.params, self.state)
        self.decode_dispatches += 1
        self.decode_steps += n
        bank = self._bank
        self._bank ^= 1
        if self.async_drain:
            # park this block's bank; drain the previous one.  The record
            # holds eagerly-sliced COPIES of the retired bank (and the
            # liveness vector): tiny [n, B] arrays whose buffers are
            # independent of the state pytree, so the next dispatch may
            # donate the state without invalidating an undrained record.
            rec = {"tok": self.state.emit_tok[bank],
                   "act": self.state.emit_act[bank],
                   "active": jnp.copy(self.state.dec.active),
                   "n": n,
                   "occ": [(s, self._slot_gen[s]) for s in self._occupied]}
            prev, self._inflight = self._inflight, rec
            if prev is not None:
                self._drain_record(prev)
        else:
            self._drain_record(
                {"tok": self.state.emit_tok, "act": self.state.emit_act,
                 "active": self.state.dec.active, "n": n, "bank": bank,
                 "occ": [(s, self._slot_gen[s]) for s in self._occupied]})
        return len(self._completed) - before

    def _drain_record(self, rec: dict) -> None:
        """Drain one block's emissions: ONE device→host read of the
        retired ring bank + liveness, then host bookkeeping — append live
        tokens to request buffers (journaling them with the drain
        timestamp), retire rows that went inactive.  Only slots from the
        record's tenancy snapshot are touched: a slot retired and
        re-admitted between dispatch and drain carries a bumped
        generation, so a lagging record can never credit tokens to (or
        retire) the new tenant."""
        t0 = time.perf_counter()
        emit_tok, emit_act, active_now = jax.device_get(
            (rec["tok"], rec["act"], rec["active"]))
        now = time.perf_counter()
        self.drain_stall_s += now - t0
        self.drained_blocks += 1
        if "bank" in rec:                  # sync path ships the full ring
            emit_tok, emit_act = emit_tok[rec["bank"]], emit_act[rec["bank"]]
        occ = [(s, g) for s, g in rec["occ"] if self._slot_gen[s] == g]
        journal = self.emit_journal
        for i in range(rec["n"]):
            nxt, act_prev = emit_tok[i], emit_act[i]
            self.row_steps += self.ccfg.max_concurrency
            self.useful_row_steps += int(act_prev.sum())
            for s, _ in occ:
                if act_prev[s]:
                    tok = int(nxt[s])
                    self._buf[s].append(tok)
                    self._steps[s] += 1
                    self.tokens_emitted += 1
                    if journal is not None:
                        journal.append((s, tok, now))
        for s, _ in occ:
            if not active_now[s]:
                self._retire(s)

    def drain_pending(self) -> int:
        """Flush the async in-flight drain record, if any (no-op in sync
        mode); returns the number of requests it completed.  Callers that
        stop dispatching (idle service loop, shutdown, end of a
        run-until-empty drive) call this so the final block's emissions
        are not stranded on device."""
        before = len(self._completed)
        rec, self._inflight = self._inflight, None
        if rec is not None:
            self._drain_record(rec)
        return len(self._completed) - before

    def _retire(self, slot: int):
        """Free a finished row: clear its slots on-device and recycle it."""
        self.state = self._clear_fn(self.state, slot)
        if self._paged and self._row_pages[slot]:
            # the clear above nulled the row's page table on device, and any
            # executable reusing these ids is enqueued after it — the pool
            # can hand them out again immediately
            self._pool.free(np.asarray(self._row_pages[slot], np.int32))
            self._row_pages[slot] = []
        self._occupied.remove(slot)
        self._free.append(slot)
        self._slot_gen[slot] += 1       # tenancy over: lagging drains skip it
        toks = np.asarray(self._buf[slot], np.int32)
        eos = self.ecfg.eos_token
        if eos >= 0 and toks.size < self._max_new[slot]:
            # parity with Engine.generate's post-EOS masking: the tail of a
            # request that stopped early reads as EOS
            toks = np.concatenate(
                [toks, np.full(self._max_new[slot] - toks.size, eos,
                               np.int32)])
        self._completed.append(Completed(slot, toks, self._steps[slot]))
        self._buf[slot] = []

    def pop_completed(self) -> List[Completed]:
        out, self._completed = self._completed, []
        return out
