"""Continuous-batching serving core: persistent budget-tier arenas.

The wave path (scheduler.py) decodes whole fixed-shape batches in lock-step:
every request in a wave pays ``max(max_new)`` decode steps and pad rows burn
compute.  This module is the token-level alternative (DESIGN.md §5):

  * ONE persistent `DecodeState` holds `max_concurrency` request rows across
    the two SqueezeAttention budget tiers; tier sizes are fixed once (from
    the engine config, plus Algorithm-1 calibration on the first admitted
    request in squeeze mode), so the decode step compiles exactly once.
  * **Admission**: queued arrivals are prefilled *together* (prompts
    bucketed to one shape, the admission batch padded to a power of two so
    burst sizes reuse executables), then one fused admit executable per
    (batch, prompt) bucket compacts them into the fixed tier budgets (the
    same Algorithm-1 machinery the one-shot engine uses), samples their
    first tokens and scatters the row slices (`insert_rows`) — row indices
    are *traced*, so inserting into any slots reuses the executable and
    never touches the decode step.
  * **Fused decode blocks**: the host does NOT dispatch per token.  One
    donated `lax.scan` executable runs `sync_every` decode steps back to
    back, appending each step's ``(token, active)`` into an on-device
    emission buffer carried in `ContinuousState`; `decode_block` launches
    it once and drains the buffer with one device→host read per block.
  * **Retirement**: the decode step itself lowers a row's `active` flag when
    it emits EOS or exhausts its token budget — liveness is decided on
    device with no host round-trip in the hot loop.  The host reads the mask
    only at block boundaries, clears the retired row's slots (`clear_row`)
    and recycles it.
  * **Streaming**: completed requests are harvested at every block boundary,
    so short requests leave (and new ones enter) while long ones decode.

Retired rows still occupy SIMD lanes until recycled (dense batched compute
cannot drop a row), but they stop extending their caches and — the actual
throughput lever — their slots immediately host new requests instead of
idling until the longest wave member finishes.
"""
from __future__ import annotations

import dataclasses
from typing import List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.allocation import BudgetPlan
from repro.core.cache import clear_row, empty_cache, insert_rows
from repro.serving.decode import (DecodeState, make_tier_indices,
                                  sampled_step)
from repro.serving.engine import Engine, EngineConfig
from repro.serving.prefill import pad_prompts
from repro.serving.sampler import sample


@dataclasses.dataclass(frozen=True)
class ContinuousConfig:
    max_concurrency: int = 8      # persistent batch rows (compiled once)
    prompt_bucket: int = 32       # admission prefill shape quantization
    max_prompt_len: int = 128     # admission cap (sizes full-cache arenas)
    max_new_cap: int = 64         # per-request max_new clamp (ditto)
    sync_every: int = 4           # decode steps fused into one block


class ContinuousState(NamedTuple):
    """Carried across decode blocks; `dec.active` is the on-device liveness.

    ``emit_tok`` / ``emit_act`` are the on-device emission buffer: slot ``i``
    holds step ``i``-of-the-block's sampled tokens and the pre-step active
    mask (whether the emission counts for that row).  The buffer lives on
    device so a fused block never ships per-step arrays to the host; the
    host drains rows ``[0, n_block)`` once per block.
    """
    dec: DecodeState
    token: jnp.ndarray       # [B] int32 next input token per row
    remaining: jnp.ndarray   # [B] int32 tokens each row may still emit
    key: jnp.ndarray         # PRNG key (stochastic sampling only)
    emit_tok: jnp.ndarray    # [sync_every, B] int32 emission buffer
    emit_act: jnp.ndarray    # [sync_every, B] bool: emission was live


@dataclasses.dataclass
class Completed:
    slot: int
    tokens: np.ndarray       # [n_emitted] int32 (includes EOS if hit)
    decode_steps: int        # steps this request spent in the decode loop


def _pow2(n: int) -> int:
    return 1 << (n - 1).bit_length()


class ContinuousEngine:
    """Persistent-arena decode core.  Thin clients: `ContinuousScheduler`
    (request queue + interleave loop) and the benchmarks."""

    def __init__(self, params, cfg, ecfg: EngineConfig,
                 ccfg: ContinuousConfig = ContinuousConfig(), seed: int = 0):
        if cfg.is_ssm_only or cfg.is_hybrid:
            raise NotImplementedError(
                "continuous batching currently serves attention models; "
                "SSM/hybrid rows need per-row recurrent-state insertion "
                "(DESIGN.md §5)")
        self.engine = Engine(params, cfg, ecfg)   # shared prefill/compaction
        self.params = params
        self.cfg = cfg
        self.ecfg = ecfg
        self.ccfg = ccfg
        self.plan: Optional[BudgetPlan] = None
        self.state: Optional[ContinuousState] = None
        B = ccfg.max_concurrency
        self._free: List[int] = list(range(B))
        self._buf: List[List[int]] = [[] for _ in range(B)]
        self._max_new = [0] * B
        self._steps = [0] * B
        self._occupied: List[int] = []
        self._completed: List[Completed] = []
        # decode-lane accounting (cf. WaveScheduler): every block burns
        # max_concurrency rows per step; useful = rows that were live
        self.row_steps = 0
        self.useful_row_steps = 0
        # host-interaction accounting for the perf trajectory
        # (benchmarks/serving_bench.py): a "dispatch" is one launched
        # executable; fused blocks make decode_dispatches ~ steps/sync_every
        self.decode_dispatches = 0
        self.decode_steps = 0
        self.admit_dispatches = 0     # prefill+admit launches (batched)
        self.admitted = 0             # requests admitted
        self.tokens_emitted = 0       # live tokens streamed to request bufs
        # distinct streams: admission first-token sampling (host side) vs
        # the decode loop's per-step sampling key carried in the state —
        # reusing one key would draw correlated samples on both sides
        self._host_key, self._state_key = jax.random.split(
            jax.random.PRNGKey(seed))
        # donation lets XLA update the arenas in place; CPU ignores it
        self._donate = {} if jax.default_backend() == "cpu" \
            else {"donate_argnums": (1,)}
        self._block_fns = {}     # n_steps -> compiled fused decode block
        self._clear_fn = None
        self._admit_fns = {}     # (admit batch NB, prompt bucket P) -> admit

    # ------------------------------------------------------------ properties
    @property
    def has_free(self) -> bool:
        return bool(self._free)

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_occupied(self) -> int:
        return len(self._occupied)

    # ---------------------------------------------------------------- jit fns
    def _build_fns(self):
        def clear(state: ContinuousState, row):
            dec = state.dec
            return state._replace(dec=dec._replace(
                big=clear_row(dec.big, row),
                small=clear_row(dec.small, row),
                active=dec.active.at[row].set(False)))

        donate0 = {} if not self._donate else {"donate_argnums": (0,)}
        self._clear_fn = jax.jit(clear, **donate0)

    def _block_jit(self, n_steps: int):
        """Compiled fused decode block: `n_steps` serve_step iterations in
        ONE donated `lax.scan` executable.  Each step samples, updates the
        on-device `active` mask (EOS / budget exhaustion) and appends
        ``(token, pre-step active)`` to the emission buffer; the host sees
        nothing until it drains the buffer at the block boundary.  Memoized
        per block length — the tail of a drain runs shorter blocks, so at
        most `sync_every` executables exist."""
        if n_steps not in self._block_fns:
            cfg, pol, sc = self.cfg, self.ecfg.policy, self.ecfg.sampler
            eos = self.ecfg.eos_token
            use_flash = self.ecfg.use_flash_decode

            def block(params, state: ContinuousState) -> ContinuousState:
                def body(st, i):
                    active_prev = st.dec.active
                    nxt, dec, key = sampled_step(
                        params, cfg, pol, sc, st.dec, st.token, st.key,
                        use_flash=use_flash)
                    rem = st.remaining - active_prev.astype(jnp.int32)
                    done = active_prev & (rem <= 0)
                    if eos >= 0:
                        done = done | (active_prev & (nxt == eos))
                    dec = dec._replace(active=active_prev & ~done)
                    return ContinuousState(
                        dec, nxt, rem, key,
                        jax.lax.dynamic_update_index_in_dim(
                            st.emit_tok, nxt, i, 0),
                        jax.lax.dynamic_update_index_in_dim(
                            st.emit_act, active_prev, i, 0)), None

                state, _ = jax.lax.scan(body, state,
                                        jnp.arange(n_steps, dtype=jnp.int32))
                return state

            self._block_fns[n_steps] = jax.jit(block, **self._donate)
        return self._block_fns[n_steps]

    def _admit_jit(self, NB: int, P: int):
        """Compiled admission for one (admit batch, prompt) bucket:
        Algorithm-1 compaction of the batched prefill into row-shaped tier
        arenas, fused with the `insert_rows` scatter and first-token
        sampling.  One executable per (NB, P, max_concurrency, tier sizes) —
        row indices are traced, so admitting into ANY slots reuses it, and
        pad rows of a partial admit batch carry the drop sentinel
        ``max_concurrency`` so their scatter is discarded.  (Running the
        compaction eagerly instead costs ~100ms of op-dispatch per
        admission — it dominated the serving trace before this was fused.)"""
        key = (NB, P)
        if key not in self._admit_fns:
            eng, plan, sc = self.engine, self.plan, self.ecfg.sampler
            eos = self.ecfg.eos_token

            def admit_fn(state: ContinuousState, rows, pre, rem0, akey):
                rs = eng.build_state(pre, plan, NB)   # [L, NB, S, ...] rows
                token0 = sample(pre.last_logits, akey, sc)       # [NB]
                act0 = rem0 > 0
                if eos >= 0:
                    act0 = act0 & (token0 != eos)
                dec = state.dec
                dec = dec._replace(
                    big=insert_rows(dec.big, rs.big, rows),
                    small=insert_rows(dec.small, rs.small, rows),
                    t=dec.t.at[rows].set(rs.t.astype(dec.t.dtype),
                                         mode="drop"),
                    active=dec.active.at[rows].set(act0, mode="drop"))
                return token0, ContinuousState(
                    dec,
                    state.token.at[rows].set(
                        token0.astype(state.token.dtype), mode="drop"),
                    state.remaining.at[rows].set(rem0, mode="drop"),
                    state.key, state.emit_tok, state.emit_act)

            donate0 = {} if not self._donate else {"donate_argnums": (0,)}
            self._admit_fns[key] = jax.jit(admit_fn, **donate0)
        return self._admit_fns[key]

    # ------------------------------------------------------------- state init
    def _init_state(self) -> ContinuousState:
        cfg, plan = self.cfg, self.plan
        B = self.ccfg.max_concurrency
        E = self.ccfg.sync_every
        dtype = jnp.dtype(cfg.dtype)

        def tier(n_layers, budget):
            if n_layers == 0:    # mirror Engine's dummy arena for empty tiers
                return empty_cache(1, B, 16, cfg.n_kv_heads, cfg.hd, dtype)
            return empty_cache(n_layers, B, budget, cfg.n_kv_heads, cfg.hd,
                               dtype)

        is_small, tier_index = make_tier_indices(plan.is_small)
        dec = DecodeState(
            big=tier(plan.n_big, plan.b_big),
            small=tier(plan.n_small, plan.b_small),
            group_is_small=is_small, tier_index=tier_index,
            ssm_state=(), conv_state=(),
            t=jnp.zeros((B,), jnp.int32),
            active=jnp.zeros((B,), bool))
        return ContinuousState(
            dec,
            token=jnp.zeros((B,), jnp.int32),
            remaining=jnp.zeros((B,), jnp.int32),
            key=self._state_key,
            emit_tok=jnp.zeros((E, B), jnp.int32),
            emit_act=jnp.zeros((E, B), bool))

    def _ensure_plan(self, pre):
        """Fix (tier sizes, layer grouping) on first admission.

        In squeeze mode the grouping calibrates on the first admitted
        batch's cosine sims (Algorithm 1, batch-averaged); full/uniform are
        request-independent.  Everything afterwards reuses the same
        compiled executables.
        """
        if self.plan is not None:
            return
        cos = np.asarray(pre.cos_sims).mean(axis=-1) if pre.cos_sims.size \
            else np.zeros(0)
        self.plan = self.engine.plan_budgets(
            cos, self.ccfg.max_prompt_len, self.ccfg.max_new_cap)
        self.state = self._init_state()
        self._build_fns()

    # -------------------------------------------------------------- admission
    def admit(self, prompt: np.ndarray, max_new: int) -> int:
        """Prefill one request and insert it into a free row; returns the
        slot.  Raises if no row is free (callers check `has_free`)."""
        return self.admit_many([(prompt, max_new)])[0]

    def admit_many(self, reqs: Sequence[Tuple[np.ndarray, int]]) -> List[int]:
        """Admit up to `n_free` requests with ONE prefill dispatch and ONE
        fused admit executable (MaxText `prefill_insert_batch` style).

        Prompts are bucketed together (`pad_prompts`), the admit batch is
        padded to a power of two (pad rows replicate request 0 and are
        dropped by the scatter's sentinel row index), so a handful of
        (batch, prompt) buckets serves any arrival burst.  Returns the slot
        per request, in order.
        """
        assert reqs, "admit_many needs at least one request"
        assert len(reqs) <= len(self._free), \
            "not enough free slots — check n_free before admit_many"
        prompts = [np.asarray(p, np.int32) for p, _ in reqs]
        max_news = [min(mn, self.ccfg.max_new_cap) for _, mn in reqs]
        n = len(reqs)
        NB = _pow2(n)
        toks, valid = pad_prompts(prompts, self.ccfg.prompt_bucket,
                                  batch=NB, max_len=self.ccfg.max_prompt_len)
        for i in range(n, NB):        # pad rows replicate request 0
            toks[i], valid[i] = toks[0], valid[0]
        P = toks.shape[1]
        pre = self.engine.prefill_jit(NB, P)(self.params, toks, None, None,
                                             valid)
        self._ensure_plan(pre)
        self.admit_dispatches += 1

        self._host_key, sub = jax.random.split(self._host_key)
        slots = [self._free.pop(0) for _ in range(n)]
        B = self.ccfg.max_concurrency
        rows = np.asarray(slots + [B] * (NB - n), np.int32)   # B = drop
        rem0 = np.asarray([mn - 1 for mn in max_news] + [0] * (NB - n),
                          np.int32)
        token0, self.state = self._admit_jit(NB, P)(
            self.state, rows, pre, rem0, sub)
        tok0 = np.asarray(token0)
        eos = self.ecfg.eos_token
        for i, slot in enumerate(slots):
            t0 = int(tok0[i])
            self._buf[slot] = [t0]
            self._max_new[slot] = max_news[i]
            self._steps[slot] = 0
            self._occupied.append(slot)
            self.admitted += 1
            self.tokens_emitted += 1
            if not (rem0[i] > 0 and not (eos >= 0 and t0 == eos)):
                self._retire(slot)
        return slots

    # ------------------------------------------------------------ decode loop
    def decode_block(self) -> int:
        """Run one fused block of up to `sync_every` decode steps (ONE
        dispatch), drain the on-device emission buffer (ONE device→host
        read), retire finished rows.  Returns the number of requests
        completed in this block."""
        if not self._occupied:
            return 0
        # the host knows an exact upper bound on useful steps this block:
        # EOS can only retire rows EARLIER, so don't burn whole-batch steps
        # past the longest remaining token budget
        bound = max(self._max_new[s] - 1 - self._steps[s]
                    for s in self._occupied)
        n = max(1, min(self.ccfg.sync_every, bound))
        self.state = self._block_jit(n)(self.params, self.state)
        self.decode_dispatches += 1
        self.decode_steps += n
        # the block's only device→host transfer: emissions + liveness
        emit_tok, emit_act, active_now = jax.device_get(
            (self.state.emit_tok, self.state.emit_act, self.state.dec.active))
        before = len(self._completed)
        for i in range(n):
            nxt, act_prev = emit_tok[i], emit_act[i]
            self.row_steps += self.ccfg.max_concurrency
            self.useful_row_steps += int(act_prev.sum())
            for s in self._occupied:
                if act_prev[s]:
                    self._buf[s].append(int(nxt[s]))
                    self._steps[s] += 1
                    self.tokens_emitted += 1
        for s in list(self._occupied):
            if not active_now[s]:
                self._retire(s)
        return len(self._completed) - before

    def _retire(self, slot: int):
        """Free a finished row: clear its slots on-device and recycle it."""
        self.state = self._clear_fn(self.state, slot)
        self._occupied.remove(slot)
        self._free.append(slot)
        toks = np.asarray(self._buf[slot], np.int32)
        eos = self.ecfg.eos_token
        if eos >= 0 and toks.size < self._max_new[slot]:
            # parity with Engine.generate's post-EOS masking: the tail of a
            # request that stopped early reads as EOS
            toks = np.concatenate(
                [toks, np.full(self._max_new[slot] - toks.size, eos,
                               np.int32)])
        self._completed.append(Completed(slot, toks, self._steps[slot]))
        self._buf[slot] = []

    def pop_completed(self) -> List[Completed]:
        out, self._completed = self._completed, []
        return out
