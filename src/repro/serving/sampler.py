"""Token sampling: greedy / temperature / top-k, jit-friendly."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 0.0     # 0 -> greedy
    top_k: int = 0               # 0 -> full distribution


def sample(logits: jnp.ndarray, key, sc: SamplerConfig) -> jnp.ndarray:
    """logits [B, V] -> tokens [B].  `key` may be None for greedy decoding
    (the continuous-batching admission path samples a request's first token
    without threading a per-request key)."""
    if sc.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    assert key is not None, "stochastic sampling needs a PRNG key"
    logits = logits / sc.temperature
    if sc.top_k > 0:
        vals, _ = jax.lax.top_k(logits, sc.top_k)
        kth = vals[..., -1:]
        logits = jnp.where(logits < kth, -1e30, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
