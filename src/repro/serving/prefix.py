"""Host-side radix tree over prompt tokens -> resident KV pages.

Prefix reuse (DESIGN.md §5): serving traffic is dominated by a handful of
shared system prompts / few-shot preambles, so the prompt KV of those
prefixes should be paid for ONCE.  After a request is prefilled, its prompt
KV is chunked at page granularity and inserted here; a later request whose
prompt shares a leading run of `page_size`-token chunks admits through the
**context prefill** path (`serving.prefill.prefill_ctx`): the matched pages
are gathered on-device as read-only context keys while only the unmatched
suffix runs through the transformer.

Granularity is the page: a node keys on one `page_size`-token chunk and owns
one page per attention layer (`ids [n_layers]`, model layer order — NOT the
tier split, which is a per-engine budget-plan detail).  Matching is
exact-chunk, so a "partial prefix" matches down to the last shared page
boundary — tokens past it are recomputed with the suffix.

Ownership and lifetime:
  * the tree holds one pool refcount per resident page (`PagePool.incref`
    semantics via `alloc`); **rows never alias cache pages** — admission
    copies (gathers) from them, so row retirement and budget compaction
    never interact with cache residency;
  * `lookup` **pins** every node on the matched path until `release`, so
    the LRU eviction a same-burst allocation triggers cannot free pages an
    in-flight admission is about to gather from;
  * under pool pressure `PagePool.alloc` calls `_evict_one` (installed as
    `pool.evict_hook`), which drops the least-recently-used unpinned LEAF —
    interior nodes are by definition prefixes of live leaves and only
    become evictable once their children are gone;
  * insertion is best-effort: when the pool cannot yield pages even after
    eviction, the tail of the prompt simply isn't cached (admission never
    fails on a cold cache).

The tree never touches device memory itself: it returns page ids, and the
engine's jitted executables move the bytes (insert scatter / ctx gather).
"""
from __future__ import annotations

from typing import Dict, List, NamedTuple, Optional, Tuple

import numpy as np

from ..core.paging import PagePool


class _Node:
    __slots__ = ("chunk", "ids", "children", "parent", "pins", "last_use")

    def __init__(self, chunk: Tuple[int, ...], ids: np.ndarray,
                 parent: "Optional[_Node]"):
        self.chunk = chunk
        self.ids = ids                    # [n_layers] int32 page ids
        self.children: Dict[Tuple[int, ...], _Node] = {}
        self.parent = parent
        self.pins = 0
        self.last_use = 0


class PrefixMatch(NamedTuple):
    """Result of a pinned lookup. `matched` counts TOKENS (a multiple of
    `page_size`); `ids` is [n_layers, matched // page_size] page ids in
    prefix order; `nodes` is the pinned path (release via
    `PrefixCache.release`)."""
    matched: int
    ids: np.ndarray
    nodes: Tuple


class PrefixCache:
    """Radix tree mapping page-aligned prompt prefixes to resident pages.

    **Thread safety**: the tree ADOPTS its pool's re-entrant lock — one
    lock covers both structures, so the cross-calls in both directions
    (``insert → pool.try_alloc`` and ``pool.alloc → evict_hook →
    pool.decref``) re-enter instead of deadlocking, and a stat poll from
    another thread (`reclaimable_pages`, `page_ids`) never sees a
    half-mutated tree."""

    def __init__(self, pool: PagePool, page_size: int, n_layers: int):
        assert page_size > 0 and n_layers > 0
        self.pool = pool
        self.page_size = int(page_size)
        self.n_layers = int(n_layers)
        self._root: Dict[Tuple[int, ...], _Node] = {}
        self._clock = 0                   # monotonic LRU clock
        self.evictions = 0
        self.n_nodes = 0
        self._lock = pool.lock
        pool.evict_hook = self._evict_one

    # ------------------------------------------------------------------ LRU
    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def _evict_one(self) -> bool:
        """Drop the least-recently-used unpinned leaf; True if one fell."""
        with self._lock:
            victim: Optional[_Node] = None
            stack = list(self._root.values())
            while stack:
                n = stack.pop()
                if n.children:
                    stack.extend(n.children.values())
                elif n.pins == 0 and (victim is None
                                      or n.last_use < victim.last_use):
                    victim = n
            if victim is None:
                return False
            siblings = (victim.parent.children if victim.parent is not None
                        else self._root)
            del siblings[victim.chunk]
            self.pool.decref(victim.ids)
            self.n_nodes -= 1
            self.evictions += 1
            return True

    # --------------------------------------------------------------- lookup
    def _chunks(self, tokens) -> List[Tuple[int, ...]]:
        toks = [int(t) for t in tokens]
        p = self.page_size
        return [tuple(toks[i * p:(i + 1) * p])
                for i in range(len(toks) // p)]

    def lookup(self, tokens) -> PrefixMatch:
        """Longest page-aligned cached prefix of `tokens`, pinned.

        Capped at ``(len(tokens) - 1) // page_size`` chunks so at least one
        prompt token always remains for the suffix prefill (the sampling
        path needs real last-token logits).  Always `release` the returned
        match once its pages have been gathered (or ignored)."""
        cap = (len(tokens) - 1) // self.page_size
        with self._lock:
            path: List[_Node] = []
            level = self._root
            for chunk in self._chunks(tokens)[:cap]:
                node = level.get(chunk)
                if node is None:
                    break
                path.append(node)
                level = node.children
            now = self._tick()
            for n in path:
                n.pins += 1
                n.last_use = now
            ids = (np.stack([n.ids for n in path], axis=1)
                   if path else np.zeros((self.n_layers, 0), np.int32))
            return PrefixMatch(matched=len(path) * self.page_size, ids=ids,
                               nodes=tuple(path))

    def release(self, match: PrefixMatch) -> None:
        with self._lock:
            for n in match.nodes:
                assert n.pins > 0
                n.pins -= 1

    # --------------------------------------------------------------- insert
    def insert(self, tokens, max_chunks: Optional[int] = None
               ) -> List[Tuple[int, np.ndarray]]:
        """Extend the tree along `tokens`; returns [(chunk_index, ids)] for
        NEWLY created nodes — the engine must scatter those chunks' KV into
        `ids` ([n_layers] each).  Existing nodes are skipped (same tokens =>
        same KV, already resident), which also dedupes identical prompts
        admitted in one burst.  Best-effort under pool pressure."""
        chunks = self._chunks(tokens)
        if max_chunks is not None:
            chunks = chunks[:max_chunks]
        with self._lock:
            created: List[Tuple[int, np.ndarray]] = []
            fresh: List[_Node] = []
            level, parent = self._root, None
            now = self._tick()
            for ci, chunk in enumerate(chunks):
                node = level.get(chunk)
                if node is None:
                    ids = self.pool.try_alloc(self.n_layers)
                    if ids is None:
                        break                      # pool full: cache a prefix
                    node = _Node(chunk, ids, parent)
                    node.pins = 1  # shield the fresh path from same-call LRU
                    level[chunk] = node
                    self.n_nodes += 1
                    created.append((ci, ids))
                    fresh.append(node)
                node.last_use = now
                level, parent = node.children, node
            for node in fresh:
                node.pins -= 1
            return created

    # ---------------------------------------------------------------- stats
    @property
    def resident_pages(self) -> int:
        return self.n_nodes * self.n_layers

    def _walk(self) -> List[_Node]:
        out: List[_Node] = []
        stack = list(self._root.values())
        while stack:
            n = stack.pop()
            out.append(n)
            stack.extend(n.children.values())
        return out

    @property
    def reclaimable_pages(self) -> int:
        """Pages LRU eviction could free right now (unpinned-subtree
        residency).  The engine's watermark headroom counts these as
        effectively free: backpressure should not stall on memory the
        ladder's first rung can reclaim."""
        return sum(self.n_layers for n in self._walk() if self._droppable(n))

    def _droppable(self, node: _Node) -> bool:
        """A node is reclaimable iff nothing at or below it is pinned
        (eviction frees leaves first, but a fully unpinned subtree falls
        one leaf per eviction call)."""
        stack = [node]
        while stack:
            n = stack.pop()
            if n.pins:
                return False
            stack.extend(n.children.values())
        return True

    def page_ids(self) -> List[np.ndarray]:
        """Every resident page-id array ([n_layers] per node) — the prefix
        cache's entry in the pool-accounting audit."""
        return [n.ids for n in self._walk()]
