"""Multimodal request intake: typed requests -> embeds-native admission.

SqueezeAttention's layer-wise budgets are modality-agnostic — Algorithm 1
measures layer importance on the *hidden states*, not on token ids — so the
continuous engine admits whatever the decoder stack can embed.  This module
is the subsystem that turns a frontend-carrying request (image patch grids,
audio frames, interleaved text) into the ``[len, d]`` embedding sequence the
engine's embeds admission paths consume (DESIGN.md §5):

  * **Typed segments** (`TextSegment` / `ImageSegment` / `AudioSegment`)
    compose a `MultimodalRequest` in interleaving order.  Text-only
    requests stay token prompts — the intake only materializes embeddings
    where a frontend exists.
  * **Batched frontend encoding** (`IntakeEncoder`): a burst's segments are
    bucketed by (kind, length) and each bucket runs ONE encoder dispatch —
    the stub vision/audio encoders (`models/frontend.py`, per-request
    keys, vmapped) and the text embedding table
    (`models/transformer.py:embed_tokens`) respectively.  Because every
    row of a keyed stub encode depends only on its own key, bucketing is
    *batch-invariant*: a request's embeddings are identical whether it is
    encoded alone (`encode_request`, the solo-reference path the identity
    tests use) or inside a burst (`encode_burst`).
  * **Positions** are the mixed sequential scheme
    (`models/frontend.py:mixed_positions`): one index over
    [frontend | text], which M-RoPE models see as the degenerate t=h=w
    triple — exactly what the decode step's scalar position extends, so
    the 3-D patch-grid ids remain a one-shot `Engine.generate` flavor
    while serving stays position-scheme-consistent end to end.

The encoded requests flow into `ContinuousEngine.admit_many` as 2-D
``[len, d]`` prompts (token prompts stay 1-D int arrays); the engine
prefills them through the same bucketed / packed layouts and the SAME fused
admit executables as token bursts — `PrefillOut` is layout- and
modality-agnostic, so admission never forked.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple, Union

import jax
import numpy as np

from repro.models.frontend import (STUB_FRONTENDS, audio_stub_embeds_keyed,
                                   mixed_positions, vision_stub_embeds_keyed)
from repro.models.transformer import embed_tokens


@dataclasses.dataclass(frozen=True)
class TextSegment:
    """A run of ordinary token ids (embedded through the model's table —
    bit-identical to submitting the same ids as a token prompt)."""
    tokens: np.ndarray            # [n] int32

    @property
    def kind(self) -> str:
        return "text"

    def __len__(self) -> int:
        return len(self.tokens)


@dataclasses.dataclass(frozen=True)
class ImageSegment:
    """One image as a patch grid: `n_patches` precomputed patch embeddings
    (the vision stub per assignment; a real ViT/SigLIP+projector would
    produce the same `[n_patches, d]` interface).  ``grid_hw`` is carried
    for the M-RoPE one-shot flavor; the intake's serving path uses mixed
    sequential positions (module docstring)."""
    n_patches: int
    grid_hw: Optional[Tuple[int, int]] = None

    @property
    def kind(self) -> str:
        return "image"

    def __len__(self) -> int:
        return self.n_patches


@dataclasses.dataclass(frozen=True)
class AudioSegment:
    """One audio clip as `n_frames` codec-frame embeddings (EnCodec-style
    stub per assignment)."""
    n_frames: int

    @property
    def kind(self) -> str:
        return "audio"

    def __len__(self) -> int:
        return self.n_frames


Segment = Union[TextSegment, ImageSegment, AudioSegment]

#: segment kind -> the ModelConfig.frontend that encodes it
_KIND_FRONTEND = {v: k for k, v in STUB_FRONTENDS.items()}


@dataclasses.dataclass(frozen=True)
class MultimodalRequest:
    """An ordered tuple of typed segments + decode budget.

    ``seed`` keys the stub frontend encoders (segment ``j`` uses
    ``fold_in(PRNGKey(seed), j)``), standing in for the image/audio bytes a
    real frontend would hash — two requests with the same seed and segments
    encode identically, which is what lets tests replay the exact embeds
    into solo `Engine.generate`.
    """
    segments: Tuple[Segment, ...]
    max_new: int
    seed: int = 0

    def __post_init__(self):
        assert self.segments, "a request needs at least one segment"
        assert self.total_len >= 1

    @property
    def n_frontend(self) -> int:
        return sum(len(s) for s in self.segments if s.kind != "text")

    @property
    def n_text(self) -> int:
        return sum(len(s) for s in self.segments if s.kind == "text")

    @property
    def total_len(self) -> int:
        return self.n_frontend + self.n_text

    @property
    def is_text_only(self) -> bool:
        return self.n_frontend == 0

    def text_tokens(self) -> np.ndarray:
        """The concatenated text content (token-prompt form of a text-only
        request)."""
        toks = [np.asarray(s.tokens, np.int32) for s in self.segments
                if s.kind == "text"]
        return np.concatenate(toks) if toks else np.zeros((0,), np.int32)


def _pow2(n: int) -> int:
    return 1 << (n - 1).bit_length()


class IntakeEncoder:
    """Batched frontend encoding for admission bursts.

    Buckets a burst's segments by ``(kind, length)`` and runs ONE memoized
    encoder dispatch per bucket (batch padded to a power of two so burst
    compositions reuse executables): stub vision/audio encoders for
    frontend segments, the model's embedding table for text segments.  The
    per-request pieces are then concatenated in segment order into the
    ``[total_len, d]`` float32 sequence `ContinuousEngine.admit_many`
    accepts as an embeds-carrying prompt.

    Counters (`encode_dispatches`, `encoded_segments`,
    `frontend_tokens_encoded`) mirror the engine's admission accounting so
    the serving bench can see the frontend amortization.
    """

    def __init__(self, params, cfg):
        if cfg.frontend is not None and cfg.frontend not in STUB_FRONTENDS:
            raise ValueError(f"unknown frontend {cfg.frontend!r}; known: "
                             f"{', '.join(STUB_FRONTENDS)}")
        self.params = params
        self.cfg = cfg
        self._fns = {}                 # (kind, NB, n) -> jitted encoder
        self.encode_dispatches = 0     # one per (kind, length) bucket
        self.encoded_segments = 0
        self.frontend_tokens_encoded = 0

    # ------------------------------------------------------------- encoders
    def _fn(self, kind: str, NB: int, n: int):
        key = (kind, NB, n)
        if key not in self._fns:
            cfg = self.cfg
            if kind == "image":
                fn = jax.jit(lambda keys: vision_stub_embeds_keyed(
                    keys, n, cfg)[0])
            elif kind == "audio":
                fn = jax.jit(lambda keys: audio_stub_embeds_keyed(
                    keys, n, cfg))
            else:                      # text: table lookup, float32 pieces
                fn = jax.jit(lambda p, toks: embed_tokens(
                    p, cfg, toks).astype(jax.numpy.float32))
            self._fns[key] = fn
        return self._fns[key]

    def _check(self, seg: Segment):
        if seg.kind == "text":
            return
        front = _KIND_FRONTEND[seg.kind]
        if self.cfg.frontend != front:
            raise ValueError(
                f"{self.cfg.name!r} has frontend "
                f"{self.cfg.frontend or 'none'!r}, which cannot encode a "
                f"{seg.kind} segment (needs {front!r})")

    def check_request(self, req: MultimodalRequest,
                      max_len: Optional[int] = None):
        """Submit-time validation: every segment kind must be encodable by
        this config's frontend, and the encoded length must fit `max_len`
        (the admission cap) — raising HERE keeps an invalid request from
        poisoning a whole admission burst at poll time."""
        for seg in req.segments:
            self._check(seg)
        if max_len is not None and req.total_len > max_len:
            raise ValueError(f"request length {req.total_len} "
                             f"(frontend {req.n_frontend} + text "
                             f"{req.n_text}) exceeds max_prompt_len "
                             f"{max_len}")

    # -------------------------------------------------------------- encoding
    def encode_burst(self, reqs: Sequence[MultimodalRequest]
                     ) -> List[np.ndarray]:
        """Encode a whole burst: one dispatch per (kind, length) bucket,
        pieces reassembled per request in segment order.  Returns one
        ``[total_len, d]`` float32 array per request, in order."""
        buckets = {}                   # (kind, n) -> [(req i, seg j, payload)]
        for i, req in enumerate(reqs):
            for j, seg in enumerate(req.segments):
                self._check(seg)
                if seg.kind == "text":
                    payload = np.asarray(seg.tokens, np.int32)
                else:
                    payload = np.asarray(jax.random.fold_in(
                        jax.random.PRNGKey(req.seed), j))
                buckets.setdefault((seg.kind, len(seg)), []).append(
                    (i, j, payload))

        pieces = {}                    # (req i, seg j) -> np [n, d]
        for (kind, n), items in sorted(buckets.items()):
            NB = _pow2(len(items))
            pay = [p for _, _, p in items]
            pay += [pay[0]] * (NB - len(items))   # pad rows replicate item 0
            stacked = np.stack(pay)
            if kind == "text":
                out = self._fn(kind, NB, n)(self.params, stacked)
            else:
                out = self._fn(kind, NB, n)(stacked)
                self.frontend_tokens_encoded += n * len(items)
            out = np.asarray(out, np.float32)
            for (i, j, _), row in zip(items, out):
                pieces[(i, j)] = row
            self.encode_dispatches += 1
            self.encoded_segments += len(items)

        return [np.concatenate([pieces[(i, j)]
                                for j in range(len(req.segments))], axis=0)
                for i, req in enumerate(reqs)]

    def encode_request(self, req: MultimodalRequest) -> np.ndarray:
        """Solo encode (the reference path): identical values to the same
        request inside any `encode_burst` — keyed stub encoders make each
        row a pure function of its own key."""
        return self.encode_burst([req])[0]

    def positions_for(self, req: MultimodalRequest) -> np.ndarray:
        """The mixed sequential positions `[1, total_len]` of the encoded
        sequence — what prefill derives implicitly; exposed for driving
        the one-shot `Engine.generate` reference explicitly."""
        return np.asarray(mixed_positions(1, req.n_frontend, req.n_text))
