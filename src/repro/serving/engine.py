"""SqueezeAttention serving engine: the paper's two-phase flow, XLA-ified.

    prompt --jit prefill--> logits, KV, per-layer cosine sims
           --host--------> KMeans(k=3) -> Algorithm-1 budgets -> bucketize
           --jit compact--> two budget-tier arenas
           --jit serve_step loop--> tokens

Modes:
  * "full"     — no eviction (arena = prompt + max_new slots)     [paper: Full Cache]
  * "uniform"  — sequence-wise policy, same budget per layer      [paper: baselines]
  * "squeeze"  — + layer-wise reallocation                        [paper: the method]

Compiled executables are memoized on the static shape key (batch, prompt len,
tier sizes), so repeated traffic with the same bucketed allocation reuses
them — the KMeans/allocation overhead is the one-time host-side cost the
paper measures in Table 5.

Two serving clients share this core (DESIGN.md §5): the one-shot
`generate` below (which the wave scheduler batches), and the
continuous-batching `ContinuousEngine` (continuous.py), which reuses
`prefill_jit` / `plan_budgets` / `build_state` per request and owns its own
persistent decode loop.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.allocation import (BudgetPlan, allocate, allocate_zigzag,
                                   recurrent_tier, total_state_bytes,
                                   uniform_plan)
from repro.core.cache import SlotCache, compact, pad_cache, sort_slots
from repro.core.policies import PolicyConfig, key_norms, uses_key_norms
from repro.models.config import ModelConfig
from repro.models.transformer import n_attn_layers
from repro.serving.decode import (DecodeState, make_tier_indices,
                                  sampled_step, serve_step)
from repro.serving.prefill import packed_prefill, prefill, prefill_ctx
from repro.serving.sampler import SamplerConfig, sample


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Budget-policy knobs shared by the one-shot `Engine` and the
    continuous engine (field reference in `docs/API.md`)."""
    #: "full" (no eviction) | "uniform" (same budget per layer) |
    #: "squeeze" (the paper: Algorithm-1 2-tier reallocation) |
    #: "zigzag" (N-tier sensitivity-proportional budgets, allocate_zigzag)
    mode: str = "squeeze"
    #: sequence-wise eviction policy (sliding_window / streaming_llm /
    #: h2o / sink_h2o / l2_norm — `repro.core.policies.POLICIES`)
    policy: PolicyConfig = PolicyConfig()
    budget_frac: float = 0.4           # b_init as a fraction of prompt length
    budget_abs: int = 0                # or absolute tokens (overrides frac if >0)
    p: float = 0.35                    # Algorithm-1 squeeze factor
    bucket: int = 16                   # budget quantization (static shapes)
    min_budget: int = 16               # floor per layer (keep sinks + recents)
    n_tiers: int = 4                   # "zigzag": requested budget levels
    #: default decode length for `Engine.generate`
    max_new_tokens: int = 64
    #: temperature 0 = greedy; one engine-level PRNG stream otherwise
    sampler: SamplerConfig = SamplerConfig()
    eos_token: int = -1                # >=0: stop rows at EOS (masked to eos)
    eos_check_every: int = 8           # fused decode-block length / early exit
    use_flash_decode: bool = False     # Pallas flash-decode for arena reads

    def b_init(self, prompt_len: int, max_new: int) -> int:
        if self.mode == "full":
            return prompt_len + max_new
        b = self.budget_abs or int(self.budget_frac * prompt_len)
        return max(self.bucket, (b // self.bucket) * self.bucket)


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray                 # [B, max_new]
    plan: BudgetPlan
    cos_sims: np.ndarray               # [n_attn_layers]
    prefill_seconds: float
    decode_seconds: float
    allocate_seconds: float
    cache_slots: int                   # total physical KV slots across layers
    state_bytes: int = 0               # KV arenas + fixed recurrent tier

    @property
    def tokens_per_second(self) -> float:
        n = self.tokens.shape[0] * self.tokens.shape[1]
        return n / max(self.decode_seconds, 1e-9)


class Engine:
    def __init__(self, params, cfg: ModelConfig, ecfg: EngineConfig):
        self.params = params
        self.cfg = cfg
        self.ecfg = ecfg
        self._prefill_cache = {}
        self._step_cache = {}
        self._block_cache = {}
        self.decode_dispatches = 0    # fused-block executable launches

    # ------------------------------------------------------------------ jit
    def prefill_jit(self, batch: int, prompt_len: int, embeds: bool = False):
        """The memoized prefill executable for one (batch, prompt) bucket.
        Called per request by continuous-batching admission.  ``embeds``
        selects the embeds-carrying layout (vlm/audio intake: the request
        arrives as `[B, P, d]` precomputed embeddings instead of token
        ids) — a distinct executable, same output structure."""
        return self._prefill_fn((batch, prompt_len, "emb") if embeds
                                else (batch, prompt_len))

    def _prefill_fn(self, key):
        if key not in self._prefill_cache:
            self._prefill_cache[key] = jax.jit(
                lambda p, tok, emb, pos, val: prefill(
                    p, self.cfg, tokens=tok, embeds=emb, positions=pos, valid=val))
        return self._prefill_cache[key]

    def packed_prefill_jit(self, rows: int, pack_len: int, max_segs: int,
                           embeds: bool = False):
        """The memoized PACKED prefill executable for one (rows, pack_len,
        segments-per-row) shape: one dispatch prefills a whole admission
        burst of concatenated prompts under the block-diagonal mask
        (`serving/prefill.py:packed_prefill`, DESIGN.md §5).  ``embeds``
        selects the packed-embeds layout (`pack_embeds` rows [R, P, d]
        instead of token ids)."""
        key = ("packed", rows, pack_len, max_segs) + (("emb",) if embeds
                                                     else ())
        if key not in self._prefill_cache:
            self._prefill_cache[key] = jax.jit(
                lambda p, tok, emb, pos, val, seg, tl, ts: packed_prefill(
                    p, self.cfg, tok, pos, val, seg, tl, ts, embeds=emb))
        return self._prefill_cache[key]

    def prefill_ctx_jit(self, batch: int, suffix_len: int):
        """The memoized PREFIX-HIT prefill executable (prefix reuse,
        `serving/prefill.py:prefill_ctx`): transformer FLOPs for the
        unmatched suffix only, cached-prefix pages attended as read-only
        context.  Keyed on (batch, suffix bucket) alone — match lengths and
        page ids are traced data, so every hit depth and page placement
        reuses one executable."""
        key = ("ctx", batch, suffix_len)
        if key not in self._prefill_cache:
            self._prefill_cache[key] = jax.jit(
                lambda p, tok, val, matched, kp, vp, ids: prefill_ctx(
                    p, self.cfg, tok, val, matched, kp, vp, ids))
        return self._prefill_cache[key]

    def _step_fn(self, key):
        """Single decode step (one dispatch per token).  The generate loop
        runs on `_block_fn` instead; this stays as the per-step reference
        the fused path is pinned against (tests/test_fused_decode.py)."""
        if key not in self._step_cache:
            cfg, pol = self.cfg, self.ecfg.policy
            use_flash = self.ecfg.use_flash_decode

            def step(params, state, token, rngkey):
                logits, state = serve_step(params, cfg, pol, state, token,
                                           use_flash=use_flash)
                nxt = sample(logits, rngkey, self.ecfg.sampler)
                return nxt, logits, state

            self._step_cache[key] = jax.jit(step)
        return self._step_cache[key]

    def _block_fn(self, shape_key, n_steps: int):
        """Fused decode block: `n_steps` serve_step+sample iterations in one
        `lax.scan` executable, emitting the block's tokens [n_steps, B] and
        carrying a running per-row `done` mask — the host checks EOS once
        per block on the mask instead of re-scanning emitted tokens."""
        key = (shape_key, n_steps)
        if key not in self._block_cache:
            cfg, pol, sc = self.cfg, self.ecfg.policy, self.ecfg.sampler
            eos = self.ecfg.eos_token
            use_flash = self.ecfg.use_flash_decode

            def block(params, state, token, rngkey, done):
                def body(carry, _):
                    state, token, rngkey, done = carry
                    if eos >= 0:
                        done = done | (token == eos)
                    nxt, state, rngkey = sampled_step(
                        params, cfg, pol, sc, state, token, rngkey,
                        use_flash=use_flash)
                    return (state, nxt, rngkey, done), token

                (state, token, rngkey, done), toks = jax.lax.scan(
                    body, (state, token, rngkey, done), None, length=n_steps)
                return toks, state, token, rngkey, done

            self._block_cache[key] = jax.jit(block)
        return self._block_cache[key]

    # ----------------------------------------------------------- allocation
    def plan_budgets(self, cos_sims: np.ndarray, prompt_len: int,
                     max_new: int) -> BudgetPlan:
        """Algorithm-1 budget plan over the *attention* layers only.

        Recurrent (SSM) layers are a fixed-cost tier — their state is O(1)
        in sequence length, so there is nothing to squeeze or boost — and
        are excluded from the split entirely: a hybrid model divides
        ``n_attn * b_init`` across its attention invocations, an ssm-only
        model degenerates to a placeholder uniform plan
        (`core.allocation.recurrent_tier` carries the fixed cost)."""
        n_attn = n_attn_layers(self.cfg)
        b_init = self.ecfg.b_init(prompt_len, max_new)
        if self.cfg.is_ssm_only or n_attn == 0:
            return uniform_plan(max(n_attn, 1), b_init)
        if self.ecfg.mode in ("full", "uniform"):
            return uniform_plan(n_attn, b_init)
        if self.ecfg.mode == "zigzag":
            return allocate_zigzag(cos_sims, b_init,
                                   n_tiers=self.ecfg.n_tiers,
                                   bucket=self.ecfg.bucket,
                                   min_budget=self.ecfg.min_budget)
        return allocate(cos_sims, b_init, p=self.ecfg.p, bucket=self.ecfg.bucket,
                        min_budget=self.ecfg.min_budget)

    # ------------------------------------------------------------ state init
    def build_state(self, pre, plan: BudgetPlan, batch: int,
                    canonical: bool = False) -> DecodeState:
        """Compact a prefill into budget-tier arenas (Algorithm 1 line 12).

        With ``batch=1`` this doubles as continuous-batching admission: the
        returned row-shaped arenas are what `insert_request` writes into a
        free row of the persistent state.

        ``canonical`` re-sorts each compacted arena into position order with
        empties trailing (`core.cache.sort_slots`) — required for the
        context-prefill layout, whose valid slots are not a contiguous
        prefix (the plain layout already IS canonical, so the flag is off
        by default to keep the hot path gather-free).
        """
        cfg, pol = self.cfg, self.ecfg.policy
        if cfg.is_ssm_only:
            st, cv = pre.ssm_state
            return DecodeState((), (), (), st, cv, pre.t)

        tier_of, tier_index = make_tier_indices(plan.tier_of)
        # l2_norm: the score channel carries static key norms — computed
        # here from the prefill K, never from attention statistics, so every
        # admission layout (plain / packed / ctx) sources identical scores
        scores = key_norms(pre.k) if uses_key_norms(pol) else pre.scores

        def build_tier(idx, budget):
            assert idx, "plans never produce empty tiers"
            sel = jnp.asarray(idx, jnp.int32)
            k = jnp.take(pre.k, sel, axis=0)
            v = jnp.take(pre.v, sel, axis=0)
            pos = jnp.take(pre.cache_pos, sel, axis=0)
            score = jnp.take(scores, sel, axis=0)
            P = pos.shape[-1]
            if budget <= P:
                tier = compact(pol, k, v, pos, score, budget, pre.t)
            else:
                tier = pad_cache(SlotCache(k, v, pos, score), budget)
            return sort_slots(tier) if canonical else tier

        tiers = tuple(build_tier(idx, budget)
                      for budget, idx in plan.layer_tiers())

        if cfg.is_hybrid:
            st, cv = pre.ssm_state
            return DecodeState(tiers, tier_of, tier_index, st, cv, pre.t)
        return DecodeState(tiers, tier_of, tier_index, (), (), pre.t)

    # --------------------------------------------------------------- generate
    def generate(
        self,
        tokens: Optional[np.ndarray] = None,     # [B, P] int32
        embeds: Optional[np.ndarray] = None,     # [B, P, d] (vlm/audio stubs)
        positions=None,
        valid=None,
        max_new_tokens: Optional[int] = None,
        seed: int = 0,
    ) -> GenerationResult:
        max_new = max_new_tokens or self.ecfg.max_new_tokens
        B, P = (tokens.shape if tokens is not None else embeds.shape[:2])

        t0 = time.perf_counter()
        pre = self._prefill_fn((B, P))(self.params,
                                       tokens, embeds, positions, valid)
        pre.last_logits.block_until_ready()
        t1 = time.perf_counter()

        cos = np.asarray(pre.cos_sims).mean(axis=-1) if pre.cos_sims.size \
            else np.zeros(0)
        plan = self.plan_budgets(cos, P, max_new)
        state = self.build_state(pre, plan, B)
        t2 = time.perf_counter()

        shape_key = (B, P) + tuple(plan.tier_budgets) + tuple(plan.tier_counts)
        token = sample(pre.last_logits, jax.random.PRNGKey(seed),
                       self.ecfg.sampler)
        key = jax.random.PRNGKey(seed + 1)
        eos = self.ecfg.eos_token
        done = jnp.zeros((B,), bool)
        # block schedule: with no EOS there is nothing to check between
        # steps, so the WHOLE generation fuses into one dispatch; with EOS,
        # blocks of `eos_check_every` steps and one host look at the running
        # `done` mask per block (the old loop re-stacked every emitted token
        # per check — O(steps^2) host work)
        if eos >= 0:
            every = max(1, self.ecfg.eos_check_every)
            sizes = [every] * (max_new // every)
            if max_new % every:
                sizes.append(max_new % every)
        else:
            sizes = [max_new]
        blocks = []
        emitted = 0
        for nblk in sizes:
            btoks, state, token, key, done = self._block_fn(
                shape_key, nblk)(self.params, state, token, key, done)
            self.decode_dispatches += 1
            blocks.append(btoks)
            emitted += nblk
            if eos >= 0 and emitted < max_new \
                    and bool(np.asarray(done).all()):
                break
        jax.block_until_ready(token)
        t3 = time.perf_counter()

        slots = 0 if self.cfg.is_ssm_only else plan.total
        state_bytes = total_state_bytes(
            plan if self.cfg.has_attention else None,
            recurrent_tier(self.cfg), B, self.cfg.n_kv_heads, self.cfg.hd,
            jnp.dtype(self.cfg.dtype).itemsize)
        toks = np.concatenate([np.asarray(b) for b in blocks], axis=0).T
        if eos >= 0:   # mask everything after the first EOS per row
            hit = np.cumsum(toks == eos, axis=1) > 0
            mask = np.concatenate(
                [np.zeros((toks.shape[0], 1), bool), hit[:, :-1]], axis=1)
            toks = np.where(mask, eos, toks)
        return GenerationResult(
            tokens=toks,
            plan=plan, cos_sims=cos,
            prefill_seconds=t1 - t0, decode_seconds=t3 - t2,
            allocate_seconds=t2 - t1, cache_slots=slots,
            state_bytes=state_bytes)
