"""Async serving front end: background scheduler loop + streaming handles.

Everything below this module is synchronous: `ContinuousScheduler.poll`
runs one rung-ladder iteration and returns, and the benches drive it in a
loop on the calling thread.  `ServingService` turns that into a service
(DESIGN.md §5): ONE background thread owns the scheduler (and therefore
every JAX dispatch — the engine is single-threaded by construction), a
thread-safe intake queue carries submissions and cancellations in, and
per-request `RequestHandle`s carry tokens out as they are emitted.

  * **Ownership** — client threads never touch the scheduler.  `submit`
    validates and enqueues; the loop thread binds the handle to a request
    id, admits it through the normal poll ladder, and pushes each emitted
    token into the handle's queue.  Cancellation is an intake op too, so
    it lands between polls, never mid-dispatch.
  * **Overlapped drain** — the service flips `ContinuousEngine.async_drain`
    on: each poll's fused block is dispatched and the PREVIOUS block's
    emission-ring bank is drained while it computes (the double-buffered
    ring in `ContinuousState`), so the loop thread spends its per-block
    device→host wait doing useful work.  `drain_stall_s` on the engine is
    the residual blocked time — the `emission_overlap` bench pins it near
    zero against the sync discipline.
  * **SLO observability** — every emission carries the host timestamp the
    token became visible (the scheduler's `emit_hook` journal).  Each
    finished request folds into an `SLORecord` (TTFT, ITL p50/p95, queue
    wait, preemption count) and into the service-wide `ServiceMetrics`
    aggregate that `/metrics` (launch/http_api.py) serves.

Streaming identity contract (pinned by tests/test_service.py): the token
stream a handle yields — including tokens emitted before a preemption and
the EOS tail padding — is exactly `Request.tokens` from the synchronous
`run_to_completion` drive of the same trace.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Callable, Dict, Iterator, List, Optional

import numpy as np

from repro.serving.scheduler import ContinuousScheduler, Request

_DONE = object()          # stream terminator (normal, cancelled or failed)


def _pctl(values: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(values), q)) if values else 0.0


@dataclasses.dataclass
class SLORecord:
    """Per-request service-level trace, all host-clock seconds.

    `ttft_s` spans submit → first token visible; `queue_wait_s` spans
    submit → first slot grant (the admission the request waited for, kept
    across preempt-and-resume); `itl_s` are the gaps between consecutive
    token visibility times (block-granular: tokens of one fused block
    share a drain timestamp, so a `sync_every`-token block contributes
    one real gap and `sync_every - 1` zeros — the client-visible truth)."""
    rid: int
    n_tokens: int
    ttft_s: float
    queue_wait_s: float
    e2e_s: float
    itl_s: List[float]
    preemptions: int
    cancelled: bool

    @property
    def itl_p50_ms(self) -> float:
        return _pctl(self.itl_s, 50) * 1e3

    @property
    def itl_p95_ms(self) -> float:
        return _pctl(self.itl_s, 95) * 1e3


class ServiceMetrics:
    """Service-wide SLO aggregate: every finished request's `SLORecord`
    folds in here.  Thread-safe — the loop thread records, any thread
    snapshots (the HTTP `/metrics` endpoint's reader)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._ttft: List[float] = []
        self._queue_wait: List[float] = []
        self._itl: List[float] = []
        self.completed = 0
        self.cancelled = 0
        self.preemptions = 0
        self.tokens_streamed = 0

    def record(self, rec: SLORecord) -> None:
        with self._lock:
            if rec.cancelled:
                self.cancelled += 1
            else:
                self.completed += 1
                self._ttft.append(rec.ttft_s)
                self._queue_wait.append(rec.queue_wait_s)
                self._itl.extend(rec.itl_s)
            self.preemptions += rec.preemptions
            self.tokens_streamed += rec.n_tokens

    def snapshot(self) -> Dict[str, float]:
        """Point-in-time SLO summary (milliseconds for the latency rows —
        the BENCH_serving.json / `/metrics` schema)."""
        with self._lock:
            return {
                "completed": self.completed,
                "cancelled": self.cancelled,
                "preemptions": self.preemptions,
                "tokens_streamed": self.tokens_streamed,
                "ttft_p50_ms": _pctl(self._ttft, 50) * 1e3,
                "ttft_p95_ms": _pctl(self._ttft, 95) * 1e3,
                "itl_p50_ms": _pctl(self._itl, 50) * 1e3,
                "itl_p95_ms": _pctl(self._itl, 95) * 1e3,
                "queue_wait_p50_ms": _pctl(self._queue_wait, 50) * 1e3,
                "queue_wait_p95_ms": _pctl(self._queue_wait, 95) * 1e3,
            }


class RequestHandle:
    """Client-side view of one submitted request.

    Tokens arrive on the loop thread and are re-published through a
    thread-safe queue: consume them incrementally with `stream()` (or a
    constructor `on_token` callback — called ON the loop thread, keep it
    cheap), or block for the finished output with `result()`.  `cancel()`
    is safe from any thread at any point in the request's life; the
    stream simply ends early and `cancelled` flips."""

    def __init__(self, service: "ServingService", max_new: int,
                 on_token: Optional[Callable[[int, float], None]] = None):
        self._service = service
        self.rid: Optional[int] = None       # bound by the loop thread
        self.max_new = max_new
        self.submitted_at = time.perf_counter()
        self._on_token = on_token
        self._q: "queue.SimpleQueue" = queue.SimpleQueue()
        self._done = threading.Event()
        self._streamed: List[int] = []
        self._token_times: List[float] = []
        self.tokens: Optional[np.ndarray] = None
        self.cancelled = False
        self.error: Optional[BaseException] = None
        self.slo: Optional[SLORecord] = None

    # ---- loop-thread side -------------------------------------------------
    def _push(self, tok: int, t: float) -> None:
        self._streamed.append(tok)
        self._token_times.append(t)
        if self._on_token is not None:
            self._on_token(tok, t)
        self._q.put(tok)

    def _push_tail(self, tok: int) -> None:
        # EOS tail padding: part of the canonical output (parity with the
        # synchronous path), but never a timed emission — excluded from
        # the SLO gaps
        self._streamed.append(tok)
        self._q.put(tok)

    def _finish(self, req: Optional[Request], cancelled: bool = False,
                error: Optional[BaseException] = None) -> None:
        now = time.perf_counter()
        self.tokens = np.asarray(
            req.tokens if req is not None and req.tokens is not None
            else self._streamed, np.int32)
        times = self._token_times
        self.slo = SLORecord(
            rid=self.rid if self.rid is not None else -1,
            n_tokens=len(self._streamed),
            ttft_s=times[0] - self.submitted_at if times else 0.0,
            queue_wait_s=(req.admitted_at - req.submitted_at
                          if req is not None and req.admitted_at > 0.0
                          else 0.0),
            e2e_s=now - self.submitted_at,
            itl_s=list(np.diff(times)) if len(times) > 1 else [],
            preemptions=req.preemptions if req is not None else 0,
            cancelled=cancelled)
        self.cancelled = cancelled
        self.error = error
        self._done.set()
        self._q.put(_DONE)

    # ---- client side ------------------------------------------------------
    @property
    def done(self) -> bool:
        return self._done.is_set()

    def stream(self, timeout: Optional[float] = None) -> Iterator[int]:
        """Yield tokens as they are emitted; returns when the request
        finishes (or is cancelled — the stream just ends).  `timeout`
        bounds the wait for EACH token; raises TimeoutError past it."""
        while True:
            try:
                item = self._q.get(timeout=timeout)
            except queue.Empty:
                raise TimeoutError(
                    f"no token within {timeout}s (rid={self.rid})") from None
            if item is _DONE:
                if self.error is not None:
                    raise self.error
                return
            yield item

    def result(self, timeout: Optional[float] = None) -> np.ndarray:
        """Block until the request finishes; returns the full token output
        (the partial stream, if it was cancelled — check `cancelled`)."""
        if not self._done.wait(timeout):
            raise TimeoutError(f"request not done within {timeout}s "
                               f"(rid={self.rid})")
        if self.error is not None:
            raise self.error
        return self.tokens

    def cancel(self) -> None:
        """Abandon the request from any thread: queued → dropped, live →
        its row is released and recycled (`ContinuousEngine.cancel`),
        mid-chunked-prefill → `cancel_pending`.  A no-op once finished."""
        if not self._done.is_set():
            self._service._enqueue_cancel(self)


class ServingService:
    """Background serving loop over a `ContinuousScheduler`.

    The constructor takes ownership of the scheduler (no other thread may
    drive it afterwards), flips the engine to the overlapped async-drain
    discipline, installs the per-token emission tap, and starts the loop
    thread.  `submit` returns a `RequestHandle` immediately; `close`
    stops the loop — ``drain=True`` finishes every in-flight and queued
    request first, ``drain=False`` cancels them all (pages released, pool
    audit-clean).  Usable as a context manager (drains on exit)."""

    def __init__(self, scheduler: ContinuousScheduler,
                 poll_idle_s: float = 0.02, async_drain: bool = True):
        self.sched = scheduler
        self.metrics = ServiceMetrics()
        scheduler.core.async_drain = async_drain
        scheduler.emit_hook = self._on_emit
        self._intake: "queue.SimpleQueue" = queue.SimpleQueue()
        self._handles: Dict[int, RequestHandle] = {}   # loop thread only
        self._wake = threading.Event()
        self._poll_idle_s = poll_idle_s
        self._closed = False
        self._stopping = False
        self._drain_mode = True
        self._close_lock = threading.Lock()
        self.error: Optional[BaseException] = None
        self._thread = threading.Thread(
            target=self._loop, name="serving-loop", daemon=True)
        self._thread.start()

    # ---- client side ------------------------------------------------------
    @property
    def engine(self):
        return self.sched.core

    def submit(self, prompt, max_new: int = 32,
               on_token: Optional[Callable[[int, float], None]] = None
               ) -> RequestHandle:
        """Enqueue a token prompt; returns its handle immediately.
        Validation happens HERE, on the caller's thread — a bad request
        fails fast and never occupies the loop."""
        if self._closed:
            raise RuntimeError("service is closed")
        prompt = np.asarray(prompt, np.int32)
        if prompt.ndim != 1:
            raise ValueError(f"prompt must be 1-D token ids, got shape "
                             f"{prompt.shape}")
        cap = self.sched.core.ccfg.max_prompt_len
        if len(prompt) > cap:
            raise ValueError(f"prompt length {len(prompt)} exceeds "
                             f"max_prompt_len {cap}")
        if max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {max_new}")
        h = RequestHandle(self, int(max_new), on_token)
        self._intake.put(("submit", h, prompt, int(max_new)))
        self._wake.set()
        return h

    def _enqueue_cancel(self, h: RequestHandle) -> None:
        self._intake.put(("cancel", h))
        self._wake.set()

    def counters(self) -> Dict[str, float]:
        """Engine-side observability to pair with `metrics.snapshot()`:
        drain/dispatch/pool counters (plain attribute reads — safe from
        any thread)."""
        core = self.sched.core
        return {
            "decode_dispatches": core.decode_dispatches,
            "decode_steps": core.decode_steps,
            "drained_blocks": core.drained_blocks,
            "drain_stall_s": core.drain_stall_s,
            "tokens_emitted": core.tokens_emitted,
            "admitted": core.admitted,
            "preemptions": core.preemptions,
            "cancellations": core.cancellations,
            "stall_polls": core.stall_polls,
            "pool_pages": core.pool_pages,
            "pool_pages_resident": core.pool_pages_resident,
        }

    def close(self, drain: bool = True, timeout: float = 120.0) -> None:
        """Stop the loop.  ``drain=True`` serves everything already
        submitted to completion first; ``drain=False`` cancels queued,
        live and mid-prefill requests (their handles end `cancelled`,
        pages return to the pool).  Idempotent."""
        with self._close_lock:
            self._closed = True
            self._drain_mode = drain
            self._stopping = True
        self._wake.set()
        self._thread.join(timeout)
        if self._thread.is_alive():
            raise RuntimeError("serving loop did not stop in time")
        # a submit racing `close` may have slipped into the intake after
        # the loop exited — fail those handles instead of stranding them
        while True:
            try:
                op = self._intake.get_nowait()
            except queue.Empty:
                break
            if op[0] == "submit":
                op[1]._finish(None, cancelled=True)

    def __enter__(self) -> "ServingService":
        return self

    def __exit__(self, *exc) -> None:
        self.close(drain=True)

    # ---- loop thread ------------------------------------------------------
    def _on_emit(self, req: Request, tok: int, t: float) -> None:
        h = self._handles.get(req.rid)
        if h is not None:
            h._push(tok, t)

    def _pump_intake(self) -> None:
        while True:
            try:
                op = self._intake.get_nowait()
            except queue.Empty:
                return
            if op[0] == "submit":
                _, h, prompt, max_new = op
                if self._stopping and not self._drain_mode:
                    h._finish(None, cancelled=True)
                    self.metrics.record(h.slo)
                    continue
                h.rid = self.sched.submit(prompt, max_new)
                self._handles[h.rid] = h
            else:                                      # ("cancel", handle)
                _, h = op
                if h.rid is None or h.rid not in self._handles:
                    continue                           # already finished
                if self.sched.cancel_request(h.rid):
                    hh = self._handles.pop(h.rid)
                    hh._finish(None, cancelled=True)
                    self.metrics.record(hh.slo)

    def _finish_request(self, r: Request) -> None:
        h = self._handles.pop(r.rid, None)
        if h is None:
            return
        # publish the EOS tail padding (canonical-output parity with the
        # synchronous path) — untimed, so it never skews the SLO gaps
        for tok in r.tokens[len(h._streamed):]:
            h._push_tail(int(tok))
        h._finish(r)
        self.metrics.record(h.slo)

    def _cancel_all(self) -> None:
        sched = self.sched
        # a lagging async drain may hold rows that already FINISHED:
        # flush and resolve those as completed first — only work that is
        # genuinely unfinished gets cancelled
        sched.core.drain_pending()
        for r in sched._harvest():
            self._finish_request(r)
        for r in list(sched.queue) + sched.live_requests():
            sched.cancel_request(r.rid)
        for h in list(self._handles.values()):
            h._finish(None, cancelled=True)
            self.metrics.record(h.slo)
        self._handles.clear()

    def _loop(self) -> None:
        sched = self.sched
        try:
            while True:
                self._pump_intake()
                if self._stopping and not self._drain_mode:
                    self._cancel_all()
                    return
                busy = bool(sched.queue) or sched.core.n_occupied \
                    or sched.core.n_pending
                # poll even when idle: it flushes a parked async-drain
                # record and harvests whatever that retires
                for r in sched.poll():
                    self._finish_request(r)
                if busy:
                    continue
                if self._stopping and not self._handles:
                    return
                self._wake.wait(self._poll_idle_s)
                self._wake.clear()
        except BaseException as e:                     # loop died: fail fast
            self.error = e
            for h in list(self._handles.values()):
                h._finish(None, error=e)
            self._handles.clear()
            while True:
                try:
                    op = self._intake.get_nowait()
                except queue.Empty:
                    break
                if op[0] == "submit":
                    op[1]._finish(None, error=e)
