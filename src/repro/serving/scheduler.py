"""Request schedulers: lock-step waves and token-level continuous batching.

Production serving has two batching regimes over the same SqueezeAttention
engine core (DESIGN.md §5):

  * `WaveScheduler` — groups requests into fixed-shape waves (prompt lengths
    padded to buckets, batch padded to the wave size) so each wave hits an
    already-compiled (batch, prompt-bucket, budget-tier) executable.  Simple
    and wholly synchronous, but every wave member pays ``max(max_new)``
    decode steps and pad rows burn compute — the paper's Table 3 batching
    model.
  * `ContinuousScheduler` — interleaves batched admission (packed /
    length-sorted / pad-to-longest, per `ContinuousConfig`) with fused
    decode blocks over the persistent arenas of
    `ContinuousEngine` (continuous.py).  Finished rows retire on-device and
    their slots recycle immediately, so heterogeneous ``max_new`` traffic
    no longer quantizes to the slowest wave member.  Family-agnostic: SSM
    and hybrid configs carry per-row recurrent-state arenas through the
    same admit → decode → retire path (`ContinuousScheduler.capability`
    reports what the config maps onto).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.paging import PoolFaultInjector
from repro.serving.continuous import ContinuousConfig, ContinuousEngine
from repro.serving.engine import Engine, EngineConfig
from repro.serving.intake import IntakeEncoder, MultimodalRequest
from repro.serving.prefill import pad_prompts


@dataclasses.dataclass
class Request:
    """One queued request.  Exactly one of `prompt` / `embeds` / `mm` is
    the payload: token prompts carry `prompt`, pre-encoded embedding
    sequences carry `embeds` ([len, d] float32), and typed multimodal
    requests carry `mm` until the admission poll encodes them (batched,
    one frontend dispatch per bucket — `IntakeEncoder`).

    `generated` is the preempt-and-resume carry (DESIGN.md §5): tokens the
    request had produced before a preemption released its row.  A resumed
    request re-queues with ``prompt = original prompt + generated`` (it
    re-prefills its own history) and its remaining token budget shrinks by
    ``len(generated)``; harvest prepends `generated` so `tokens` is always
    the full `max_new`-length output, preemptions invisible.

    `admitted_at` / `preemptions` are the per-request SLO trace
    (serving/service.py): the host time the request FIRST won a slot
    (queue wait = ``admitted_at - submitted_at``; preserved across
    preempt-and-resume) and how many times it was preempted."""
    rid: int
    prompt: Optional[np.ndarray]        # [P] int32 (token requests)
    max_new: int
    submitted_at: float = 0.0
    tokens: Optional[np.ndarray] = None
    latency_s: float = 0.0
    embeds: Optional[np.ndarray] = None       # [P, d] float32
    mm: Optional[MultimodalRequest] = None    # encoded at poll time
    generated: Optional[np.ndarray] = None    # tokens emitted pre-preemption
    admitted_at: float = 0.0                  # first slot grant (0 = never)
    preemptions: int = 0                      # times this request was evicted


def select_victim(candidates: Sequence[Tuple[int, int]]) -> Optional[int]:
    """Preemption victim policy over ``(slot, tokens_generated)`` pairs:
    fewest generated tokens first — the resumed prefill re-pays exactly
    those tokens, so the cheapest victim is the youngest — with the slot
    index as a deterministic tie-break.  None when nothing is eligible."""
    if not candidates:
        return None
    return min(candidates, key=lambda c: (int(c[1]), int(c[0])))[0]


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    wave_size: int = 8                  # requests per wave (compiled batch)
    prompt_bucket: int = 32             # prompts right-pad to multiples
    max_wave_new: int = 64              # decode steps per wave


class _RequestQueue:
    """Shared request intake for both schedulers."""

    def __init__(self):
        self.queue: List[Request] = []
        self._next_id = 0

    def submit(self, prompt: np.ndarray, max_new: int = 32) -> int:
        rid = self._next_id
        self._next_id += 1
        self.queue.append(Request(rid, np.asarray(prompt, np.int32),
                                  max_new, time.perf_counter()))
        return rid


class WaveScheduler(_RequestQueue):
    def __init__(self, params, cfg, ecfg: EngineConfig,
                 scfg: SchedulerConfig = SchedulerConfig()):
        super().__init__()
        self.engine = Engine(params, cfg, ecfg)
        self.cfg = cfg
        self.scfg = scfg
        # decode-lane accounting: every wave burns wave_size rows for
        # n_new steps; useful = steps a real request actually wanted
        self.row_steps = 0
        self.useful_row_steps = 0

    def _pad_wave(self, wave: List[Request]):
        toks, valid = pad_prompts([r.prompt for r in wave],
                                  self.scfg.prompt_bucket,
                                  batch=self.scfg.wave_size)
        for i in range(len(wave), self.scfg.wave_size):
            toks[i] = toks[0]           # pad rows replicate request 0
            valid[i] = valid[0]
        return toks, valid

    def run_wave(self) -> List[Request]:
        """Serve the next wave; returns the completed requests."""
        if not self.queue:
            return []
        wave = self.queue[:self.scfg.wave_size]
        self.queue = self.queue[self.scfg.wave_size:]
        toks, valid = self._pad_wave(wave)
        n_new = min(max(r.max_new for r in wave), self.scfg.max_wave_new)
        t0 = time.perf_counter()
        res = self.engine.generate(tokens=toks, valid=valid,
                                   max_new_tokens=n_new)
        t1 = time.perf_counter()
        self.row_steps += self.scfg.wave_size * n_new
        self.useful_row_steps += sum(min(r.max_new, n_new) for r in wave)
        for i, r in enumerate(wave):
            r.tokens = res.tokens[i, :r.max_new]
            r.latency_s = t1 - r.submitted_at
        return wave

    def run_until_empty(self) -> List[Request]:
        done: List[Request] = []
        while self.queue:
            done.extend(self.run_wave())
        return done


class ContinuousScheduler(_RequestQueue):
    """Interleaved admit/decode loop over the persistent-arena core.

    Same submit/run_until_empty surface as `WaveScheduler`; each `poll`
    fills every free row from the queue with ONE batched admission
    (bucketed multi-request prefill → fused admit scatter), then decodes
    one fused block, streaming out whatever finished.  Under greedy
    sampling per-request outputs are token-identical to solo
    `Engine.generate` runs *when budgets are request-independent* — mode
    "full", or `budget_abs` set (with `budget_frac` the continuous plan
    derives from `max_prompt_len` while solo derives from each prompt, so
    budgets and therefore outputs differ).  Stochastic sampling draws from
    one engine-level key stream instead of per-request streams.
    """

    def __init__(self, params, cfg, ecfg: EngineConfig,
                 ccfg: ContinuousConfig = ContinuousConfig(), seed: int = 0,
                 injector: Optional[PoolFaultInjector] = None):
        super().__init__()
        self.core = ContinuousEngine(params, cfg, ecfg, ccfg, seed=seed)
        self.intake = IntakeEncoder(params, cfg)
        self._slot_req: Dict[int, Request] = {}
        self.injector = injector       # scripted pool pressure (tests/bench)
        self._stall_streak = 0         # consecutive pressure-held polls
        self._emit_hook = None         # per-token streaming tap (see below)

    @property
    def emit_hook(self):
        """Per-token streaming tap: a callable ``(request, token, t_host)``
        invoked for every live emission, in order, with the host timestamp
        the token became visible (admission sample time for first tokens,
        ring-drain time for block emissions).  Setting it enables the
        engine's emission journal; the scheduler flushes the journal to the
        hook at every point a slot→request mapping is about to resolve, so
        events always reach the request that OWNED the slot when they were
        emitted.  Set to None to disable journaling entirely."""
        return self._emit_hook

    @emit_hook.setter
    def emit_hook(self, fn):
        self._emit_hook = fn
        self.core.emit_journal = [] if fn is not None else None

    def _flush_emissions(self):
        journal = self.core.emit_journal
        if not journal:
            return
        self.core.emit_journal = []
        hook = self._emit_hook
        for slot, tok, t in journal:
            r = self._slot_req.get(slot)
            if r is not None and hook is not None:
                hook(r, tok, t)

    @property
    def capability(self):
        """Config-driven report: budget-tiered vs fixed-cost layers."""
        return self.core.cap

    def submit(self, prompt: np.ndarray, max_new: int = 32) -> int:
        """Enqueue a token prompt.  Length is validated at SUBMIT time
        against `max_prompt_len`: the ENGINE's admission cap is relaxed to
        admit resumed (prompt + generated) payloads, so the user-facing
        bound has to be enforced here."""
        prompt = np.asarray(prompt, np.int32)
        if len(prompt) > self.core.ccfg.max_prompt_len:
            raise ValueError(
                f"prompt length {len(prompt)} exceeds max_prompt_len "
                f"{self.core.ccfg.max_prompt_len}")
        return super().submit(prompt, max_new)

    def submit_embeds(self, embeds: np.ndarray, max_new: int = 32) -> int:
        """Enqueue a pre-encoded embedding sequence ([len, d] float32) —
        the raw form of an embeds-carrying request.  Shape is validated
        HERE: a rejection at poll time would drop the whole admission
        burst the bad request rode in on."""
        embeds = np.asarray(embeds, np.float32)
        if embeds.ndim != 2 or embeds.shape[-1] != self.core.cfg.d_model:
            raise ValueError(f"embeds must be [len, d_model="
                             f"{self.core.cfg.d_model}], got "
                             f"{embeds.shape}")
        if len(embeds) > self.core.ccfg.max_prompt_len:
            raise ValueError(f"embeds length {len(embeds)} exceeds "
                             f"max_prompt_len "
                             f"{self.core.ccfg.max_prompt_len}")
        rid = self._next_id
        self._next_id += 1
        self.queue.append(Request(rid, None, max_new, time.perf_counter(),
                                  embeds=embeds))
        return rid

    def submit_multimodal(self, request: MultimodalRequest) -> int:
        """Enqueue a typed multimodal request (`serving/intake.py`).

        Segment kinds and the admission length cap are validated at
        SUBMIT time (`IntakeEncoder.check_request`); encoding is DEFERRED
        to the admission poll so a burst of queued requests shares
        bucketed frontend dispatches (`IntakeEncoder.encode_burst`);
        text-only requests degrade to token prompts and skip the embeds
        path entirely."""
        self.intake.check_request(request, self.core.ccfg.max_prompt_len)
        rid = self._next_id
        self._next_id += 1
        if request.is_text_only:
            self.queue.append(Request(rid, request.text_tokens(),
                                      request.max_new, time.perf_counter()))
        else:
            self.queue.append(Request(rid, None, request.max_new,
                                      time.perf_counter(), mm=request))
        return rid

    def _admit_payloads(self, reqs: List[Request]):
        """Resolve each burst member to its admit_many payload, encoding
        the typed multimodal members in one batched intake pass.  Encoded
        members drop their `mm` handle so a burst held back by pool
        backpressure is not re-encoded on the retry poll.  A resumed
        member's budget shrinks by what it already generated."""
        mm = [r for r in reqs if r.mm is not None]
        if mm:
            encoded = self.intake.encode_burst([r.mm for r in mm])
            for r, e in zip(mm, encoded):
                r.embeds = e
                r.mm = None
        return [(r.prompt if r.prompt is not None else r.embeds,
                 r.max_new - (len(r.generated) if r.generated is not None
                              else 0))
                for r in reqs]

    @property
    def row_steps(self) -> int:
        return self.core.row_steps

    @property
    def useful_row_steps(self) -> int:
        return self.core.useful_row_steps

    def _harvest(self) -> List[Request]:
        """Resolve finished slots to their requests.  Must run before a
        freed slot can be re-admitted, or the slot→request map would be
        clobbered — hence the harvest after every admission below.
        Emissions flush FIRST: journal entries for a slot must reach its
        request before the mapping is popped."""
        self._flush_emissions()
        done = []
        for c in self.core.pop_completed():
            r = self._slot_req.pop(c.slot)
            toks = c.tokens if r.generated is None \
                else np.concatenate([r.generated, c.tokens])
            r.tokens = toks[:r.max_new]
            r.latency_s = time.perf_counter() - r.submitted_at
            done.append(r)
        return done

    def preempt_slot(self, slot: int) -> Request:
        """Preempt the row in `slot` (the ladder's last rung — also the
        test hook for forcing a preempt→resume): release its pages, bank
        the tokens it generated, and re-queue it at the HEAD of the queue
        as ``prompt + generated`` so re-admission resumes it
        token-identically (greedy, position-based policies).  Only
        token-prompt requests are eligible (`select_victim` candidates);
        embeds/multimodal rows cannot re-prefill appended token ids."""
        r = self._slot_req[slot]
        if r.prompt is None:
            raise ValueError(f"slot {slot} holds an embeds request — not "
                             f"resumable, pick a token-prompt victim")
        toks = self.core.preempt(slot)
        # the preempt drained any lagging async record into the row's
        # buffer; flush while the slot→request mapping still stands, so
        # streamed-so-far == `generated` == what the resume re-prefills
        self._flush_emissions()
        del self._slot_req[slot]
        prev = r.generated if r.generated is not None \
            else np.zeros(0, np.int32)
        r.generated = np.concatenate([prev, toks]).astype(np.int32)
        r.prompt = np.concatenate([r.prompt, toks]).astype(np.int32)
        r.preemptions += 1
        self.core.requeues += 1
        self.queue.insert(0, r)
        return r

    def live_requests(self) -> List[Request]:
        """Requests currently holding a slot (live or mid-chunked-prefill)
        — a snapshot copy, admission order not guaranteed."""
        return list(self._slot_req.values())

    def cancel_request(self, rid: int) -> bool:
        """Abandon a request wherever it currently lives: still queued
        (dropped from the queue), mid-chunked-prefill (`cancel_pending` —
        its up-front page tables are released), or live in a slot
        (`ContinuousEngine.cancel` — pages freed, slot recycled for the
        next admission).  Returns False when `rid` is unknown — already
        harvested or never submitted; completed output stands."""
        for i, r in enumerate(self.queue):
            if r.rid == rid:
                self.queue.pop(i)
                return True
        for slot, r in list(self._slot_req.items()):
            if r.rid != rid:
                continue
            if self.core.pending_slot == slot:
                self.core.cancel_pending()
            else:
                self.core.cancel(slot)
                self._flush_emissions()   # mapping intact: drained tokens
            del self._slot_req[slot]      # still reach the cancelled owner
            return True
        return False

    def _victim_slot(self) -> Optional[int]:
        """Fewest-generated-tokens-first victim among resumable rows."""
        cands = [(s, self.core.decoded_tokens(s))
                 for s in self.core.occupied_slots
                 if self._slot_req[s].prompt is not None]
        return select_victim(cands)

    def _chunk_eligible(self, r: Request) -> bool:
        """True when `r` should stream in through the chunked-prefill path
        instead of a monolithic admission: chunked mode ready (plan
        calibrated), a token prompt (embeds/multimodal payloads have no
        chunk planner), and longer than one chunk — a prompt that fits in
        a single chunk gains nothing over the bucketed monolithic
        dispatch."""
        return (self.core.chunk_ready
                and r.prompt is not None
                and len(r.prompt) > self.core.ccfg.resolved_chunk_len())

    def _try_begin_chunked(self) -> bool:
        """Route the first chunk-eligible queued request into the pending
        chunk stream (at most ONE new chunked row per poll; the staging
        buffers hold one pending row).  Returns True when pool headroom
        refused the admission — the caller folds that into the stall
        ladder like a refused monolithic burst."""
        if not (self.core.chunk_ready and self.core.n_pending == 0
                and self.core.has_free):
            return False
        idx = next((i for i, r in enumerate(self.queue)
                    if self._chunk_eligible(r)), None)
        if idx is None:
            return False
        r = self.queue[idx]
        mn = r.max_new - (len(r.generated) if r.generated is not None
                          else 0)
        if self.core.admissible_prefix([(r.prompt, mn)]) == 0:
            return True                       # held: pool pressure
        self.queue.pop(idx)
        slot = self.core.begin_chunked(r.prompt, mn)
        self._slot_req[slot] = r
        if r.admitted_at == 0.0:
            r.admitted_at = time.perf_counter()
        return False

    def poll(self) -> List[Request]:
        """One scheduler iteration.  The fixed rung ladder (docs/API.md):

        1. **Harvest** — resolve rows the last block retired to their
           requests (must precede admission: a freed slot re-admitted
           before harvest would clobber the slot→request map).
        2. **Reclaim** — tick the configured `PoolFaultInjector` (scripted
           page-pool steal/return pressure), so admission sees the pool's
           true headroom.
        3. **Chunk-admit** — with `chunked_prefill` ready, route the first
           chunk-eligible queued request (token prompt longer than one
           chunk) into the pending chunk stream via `begin_chunked`: it
           takes a slot NOW but prefills one chunk per decode block, so
           resident rows never stall behind its prompt.  At most one
           pending row exists; further eligible requests HOLD in the
           queue (shorter requests admit past them — out-of-order
           admission is the point) until the pending row goes live.
        4. **Admit** — fill the remaining free rows from the queue with
           ONE batched monolithic admission per burst (typed multimodal
           members frontend-encoded first, batched across the burst; the
           engine picks the packed / length-sorted / padded layout per
           modality), gated by `ContinuousEngine.admissible_prefix`
           against free rows AND page-pool headroom.
        5. **Hold** — when headroom refuses the burst head (or the
           chunk-admit candidate), admission is HELD: the queue is the
           backpressure buffer, `stall_polls` counts the held polls.
        6. **Preempt** — after `preempt_after` consecutive held polls the
           ladder escalates: ONE victim row per poll (fewest generated
           tokens, `select_victim`) is preempted and re-queued at the
           head as ``prompt + generated`` so its pages host the stalled
           arrival; harvest makes the preemption invisible in the output.
        7. **Decode** — one fused block: up to `sync_every` decode steps,
           plus the pending row's next chunk co-scheduled in the same
           dispatch (the final chunk flips it live and samples its first
           token inside the block).
        8. **Harvest** again and return completions; `ccfg.audit_pool`
           runs the pool-accounting audit (device tables included) last.
        """
        done = self._harvest()
        if self.injector is not None and self.core._pool is not None:
            self.injector.tick(self.core._pool)
        chunk_held = self._try_begin_chunked()
        held = chunk_held
        preempted = False
        while self.core.has_free:
            burst = [r for r in self.queue if not self._chunk_eligible(r)]
            if not burst:
                break
            burst = burst[:min(len(burst), self.core.n_free)]
            payloads = self._admit_payloads(burst)
            n_ok = self.core.admissible_prefix(payloads)
            if n_ok == 0:
                if not preempted and \
                        self._stall_streak + 1 >= self.core.ccfg.preempt_after:
                    victim = self._victim_slot()
                    if victim is not None:
                        self.preempt_slot(victim)
                        preempted = True
                        continue
                held = True
                break
            reqs = burst[:n_ok]
            admitted = set(map(id, reqs))
            self.queue = [r for r in self.queue if id(r) not in admitted]
            slots = self.core.admit_many(payloads[:n_ok])
            now = time.perf_counter()
            for r, s in zip(reqs, slots):
                self._slot_req[s] = r
                if r.admitted_at == 0.0:
                    r.admitted_at = now
            done.extend(self._harvest())   # instant EOS / max_new == 1
            if n_ok < len(burst):         # partial fit: pressure remains
                held = True
                break
        if chunk_held and not preempted and \
                self._stall_streak + 1 >= self.core.ccfg.preempt_after:
            # the hold came from a refused CHUNK candidate (the burst loop
            # escalates its own refusals inline) — same ladder, one victim
            victim = self._victim_slot()
            if victim is not None:
                self.preempt_slot(victim)
        if held:
            self._stall_streak += 1
            self.core.stall_polls += 1
        else:
            self._stall_streak = 0
        self.core.decode_block()
        done.extend(self._harvest())
        if self.core.ccfg.audit_pool:
            extra = (self.injector.stolen_pages,) \
                if self.injector is not None else ()
            self.core.audit_pool(extra_owned=extra, deep=True)
        return done

    def run_until_empty(self) -> List[Request]:
        done: List[Request] = []
        while self.queue or self.core.n_occupied or self.core.n_pending:
            done.extend(self.poll())
        # async drain discipline parks the final block's record; flush it
        # (no-op in the default sync mode) so nothing strands on device
        self.core.drain_pending()
        done.extend(self._harvest())
        return done

    # the name the service layer (and the ISSUE checklists) know the
    # synchronous drive by
    run_to_completion = run_until_empty
