"""Wave scheduler: request-queue batched serving on top of the Engine.

Production serving groups incoming requests into fixed-shape waves (prompt
lengths padded to buckets, batch padded to the wave size) so each wave hits
an already-compiled (batch, prompt-bucket, budget-tier) executable.  This is
the batching model behind the paper's Table 3 throughput runs; true
token-level continuous batching would additionally interleave prefills into
the decode loop — noted as future work in DESIGN.md.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import numpy as np

from repro.serving.engine import Engine, EngineConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # [P] int32
    max_new: int
    submitted_at: float = 0.0
    tokens: Optional[np.ndarray] = None
    latency_s: float = 0.0


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    wave_size: int = 8                  # requests per wave (compiled batch)
    prompt_bucket: int = 32             # prompts right-pad to multiples
    max_wave_new: int = 64              # decode steps per wave


class WaveScheduler:
    def __init__(self, params, cfg, ecfg: EngineConfig,
                 scfg: SchedulerConfig = SchedulerConfig()):
        self.engine = Engine(params, cfg, ecfg)
        self.cfg = cfg
        self.scfg = scfg
        self.queue: List[Request] = []
        self._next_id = 0

    def submit(self, prompt: np.ndarray, max_new: int = 32) -> int:
        rid = self._next_id
        self._next_id += 1
        self.queue.append(Request(rid, np.asarray(prompt, np.int32),
                                  max_new, time.perf_counter()))
        return rid

    def _pad_wave(self, wave: List[Request]):
        B = self.scfg.wave_size
        bucket = self.scfg.prompt_bucket
        plen = max(len(r.prompt) for r in wave)
        plen = ((plen + bucket - 1) // bucket) * bucket
        toks = np.zeros((B, plen), np.int32)
        valid = np.zeros((B, plen), bool)
        for i, r in enumerate(wave):
            toks[i, :len(r.prompt)] = r.prompt
            valid[i, :len(r.prompt)] = True
        for i in range(len(wave), B):    # pad rows replicate request 0
            toks[i] = toks[0]
            valid[i] = valid[0]
        return toks, valid

    def run_wave(self) -> List[Request]:
        """Serve the next wave; returns the completed requests."""
        if not self.queue:
            return []
        wave = self.queue[:self.scfg.wave_size]
        self.queue = self.queue[self.scfg.wave_size:]
        toks, valid = self._pad_wave(wave)
        n_new = min(max(r.max_new for r in wave), self.scfg.max_wave_new)
        t0 = time.perf_counter()
        res = self.engine.generate(tokens=toks, valid=valid,
                                   max_new_tokens=n_new)
        t1 = time.perf_counter()
        for i, r in enumerate(wave):
            r.tokens = res.tokens[i, :r.max_new]
            r.latency_s = t1 - r.submitted_at
        return wave

    def run_until_empty(self) -> List[Request]:
        done: List[Request] = []
        while self.queue:
            done.extend(self.run_wave())
        return done
