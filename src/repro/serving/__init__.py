from repro.serving.continuous import (Capability, Completed, ContinuousConfig,
                                      ContinuousEngine, ContinuousState,
                                      continuous_capability)
from repro.serving.decode import DecodeState, make_tier_indices, serve_step
from repro.serving.engine import Engine, EngineConfig, GenerationResult
from repro.serving.intake import (AudioSegment, ImageSegment, IntakeEncoder,
                                  MultimodalRequest, TextSegment)
from repro.serving.prefill import (PackedPrefillOut, PackPlan, PrefillOut,
                                   pack_embeds, packed_prefill, pad_embeds,
                                   pad_prompt, pad_prompts, plan_pack,
                                   plan_pack_lengths, prefill, prefill_ctx)
from repro.serving.prefix import PrefixCache, PrefixMatch
from repro.serving.sampler import SamplerConfig, sample
from repro.serving.scheduler import (ContinuousScheduler, Request,
                                     SchedulerConfig, WaveScheduler)
from repro.serving.service import (RequestHandle, ServiceMetrics,
                                   ServingService, SLORecord)

__all__ = [
    "DecodeState", "make_tier_indices", "serve_step",
    "Engine", "EngineConfig", "GenerationResult",
    "PrefillOut", "prefill", "pad_prompt", "pad_prompts", "pad_embeds",
    "PackPlan", "PackedPrefillOut", "packed_prefill", "plan_pack",
    "plan_pack_lengths", "pack_embeds", "prefill_ctx",
    "PrefixCache", "PrefixMatch",
    "SamplerConfig", "sample",
    "Capability", "continuous_capability",
    "Completed", "ContinuousConfig", "ContinuousEngine", "ContinuousState",
    "ContinuousScheduler", "Request", "SchedulerConfig", "WaveScheduler",
    "IntakeEncoder", "MultimodalRequest",
    "TextSegment", "ImageSegment", "AudioSegment",
    "RequestHandle", "ServiceMetrics", "ServingService", "SLORecord",
]
