from repro.serving.decode import DecodeState, make_tier_indices, serve_step
from repro.serving.engine import Engine, EngineConfig, GenerationResult
from repro.serving.prefill import PrefillOut, prefill
from repro.serving.scheduler import Request, SchedulerConfig, WaveScheduler
from repro.serving.sampler import SamplerConfig, sample

__all__ = [
    "DecodeState", "make_tier_indices", "serve_step",
    "Engine", "EngineConfig", "GenerationResult",
    "PrefillOut", "prefill", "SamplerConfig", "sample",
    "Request", "SchedulerConfig", "WaveScheduler",
]
