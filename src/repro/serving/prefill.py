"""Prefill: full-attention pass that doubles as the measurement phase.

Returns everything SqueezeAttention's host-side allocator needs: per-layer
cosine similarities (Eq. 5, token-averaged), the full KV to be compacted into
the budget arenas, and the H2O prefill column-sum statistics.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.transformer import forward


def pad_prompts(prompts: Sequence[np.ndarray], bucket: int,
                batch: Optional[int] = None,
                max_len: Optional[int] = None):
    """Host-side shape bucketing shared by every serving client.

    Right-pads 1-D prompts to the next multiple of `bucket` (over the longest
    prompt) and to `batch` rows, returning ``(tokens [B, P] int32,
    valid [B, P] bool)``.  Prefill executables are memoized on (B, P), so
    bucketing here is what makes repeated traffic hit compiled code.
    `max_len` raises on over-long prompts (the continuous-batching admission
    cap — arena sizes are fixed at plan time).
    """
    B = batch if batch is not None else len(prompts)
    assert len(prompts) <= B
    if max_len is not None:
        for p in prompts:
            if len(p) > max_len:
                raise ValueError(f"prompt length {len(p)} exceeds "
                                 f"max_prompt_len {max_len}")
    plen = max(len(p) for p in prompts)
    P = ((plen + bucket - 1) // bucket) * bucket
    toks = np.zeros((B, P), np.int32)
    valid = np.zeros((B, P), bool)
    for i, p in enumerate(prompts):
        toks[i, :len(p)] = p
        valid[i, :len(p)] = True
    return toks, valid


def group_by_bucket(lengths: Sequence[int], bucket: int):
    """Length-sorted admission grouping (DESIGN.md §5).

    Partitions request indices by their *bucketed* prompt length (next
    multiple of `bucket`) and returns the groups shortest-bucket-first:
    ``[(padded_len, [indices...]), ...]``.  Each group prefills at its own
    bucket instead of the burst-wide pad-to-longest, so a bimodal burst of
    mostly-short prompts stops paying the longest prompt's padded FLOPs —
    the win `benchmarks/serving_bench.py` measures as `prefill_pad_tokens`.
    """
    buckets = {}
    for i, n in enumerate(lengths):
        p = ((max(int(n), 1) + bucket - 1) // bucket) * bucket
        buckets.setdefault(p, []).append(i)
    return sorted(buckets.items())


def pad_prompt(prompt: np.ndarray, bucket: int,
               max_len: Optional[int] = None):
    """Single-request `pad_prompts`."""
    return pad_prompts([np.asarray(prompt, np.int32)], bucket,
                       max_len=max_len)


def pad_embeds(embeds: Sequence[np.ndarray], bucket: int,
               batch: Optional[int] = None,
               max_len: Optional[int] = None):
    """`pad_prompts` for embeds-carrying requests (vlm/audio intake).

    Each request is a precomputed ``[len, d]`` float sequence (frontend
    patch/frame embeddings + table-embedded text, `serving/intake.py`);
    right-pads to the bucketed length and to `batch` rows with zeros,
    returning ``(embeds [B, P, d] float32, valid [B, P] bool)``.  Pad
    positions are masked by `valid` exactly like pad tokens, so the same
    memoized prefill executables serve the embeds layout.
    """
    B = batch if batch is not None else len(embeds)
    assert len(embeds) <= B
    if max_len is not None:
        for e in embeds:
            if len(e) > max_len:
                raise ValueError(f"embeds length {len(e)} exceeds "
                                 f"max_prompt_len {max_len}")
    d = embeds[0].shape[-1]
    plen = max(len(e) for e in embeds)
    P = ((plen + bucket - 1) // bucket) * bucket
    out = np.zeros((B, P, d), np.float32)
    valid = np.zeros((B, P), bool)
    for i, e in enumerate(embeds):
        out[i, :len(e)] = e
        valid[i, :len(e)] = True
    return out, valid


class PackPlan(NamedTuple):
    """Host-side layout of one packed admission burst (DESIGN.md §5).

    ``n`` requests become segments of ``n_rows`` packed rows of length
    ``pack_len`` each (one prefill dispatch).  Per-token arrays describe the
    packed layout; per-request arrays say where each request landed.
    """
    tokens: np.ndarray       # [R, P] int32 packed prompt tokens
    valid: np.ndarray        # [R, P] bool: real prompt tokens
    positions: np.ndarray    # [R, P] int32, reset to 0 at every segment start
    segments: np.ndarray     # [R, P] int32, non-decreasing per row; tail pad
                             #          gets its own id so it matches nothing
    take_last: np.ndarray    # [R, K] int32 last VALID token per segment (-1 pad)
    take_state: np.ndarray   # [R, K] int32 last SLOT token per segment (-1 pad)
    row: np.ndarray          # [n] packed row of request i
    start: np.ndarray        # [n] segment start offset of request i
    seg: np.ndarray          # [n] segment index (into the K axis) of request i
    lengths: np.ndarray      # [n] true prompt lengths
    slot_len: np.ndarray     # [n] occupied slot lengths (quantum-padded)

    @property
    def n_rows(self) -> int:
        return self.tokens.shape[0]

    @property
    def pack_len(self) -> int:
        return self.tokens.shape[1]

    @property
    def max_segments(self) -> int:
        return self.take_last.shape[1]

    @property
    def packed_tokens(self) -> int:
        """Tokens the packed prefill actually processes (rows x pack_len)."""
        return self.tokens.size


def plan_pack(prompts: Sequence[np.ndarray], bucket: int, pack_len: int,
              quantum: int = 1, max_len: Optional[int] = None) -> PackPlan:
    """Greedy packing of a TOKEN admission burst: `plan_pack_lengths` on the
    prompt lengths, with the prompt tokens written into the packed rows."""
    plan = plan_pack_lengths([len(p) for p in prompts], bucket, pack_len,
                             quantum=quantum, max_len=max_len)
    tokens = plan.tokens.copy()
    for i, p in enumerate(prompts):
        r, s = plan.row[i], plan.start[i]
        tokens[r, s:s + len(p)] = np.asarray(p, np.int32)
    return plan._replace(tokens=tokens)


def pack_embeds(plan: PackPlan, embeds: Sequence[np.ndarray]) -> np.ndarray:
    """Scatter embeds-carrying requests into a packed layout's rows.

    ``embeds[i]`` is request ``i``'s ``[len, d]`` sequence (the lengths the
    plan was built from); returns the packed ``[R, P, d]`` float32 array
    the embeds variant of `packed_prefill` consumes — the layout twin of
    `PackPlan.tokens`, with pad positions left at zero (masked by
    ``plan.valid``).
    """
    d = embeds[0].shape[-1]
    out = np.zeros((plan.n_rows, plan.pack_len, d), np.float32)
    for i, e in enumerate(embeds):
        r, s = plan.row[i], plan.start[i]
        assert len(e) == plan.lengths[i], (len(e), int(plan.lengths[i]))
        out[r, s:s + len(e)] = e
    return out


def plan_pack_lengths(lengths: Sequence[int], bucket: int, pack_len: int,
                      quantum: int = 1,
                      max_len: Optional[int] = None) -> PackPlan:
    """Greedy packing of an admission burst into few equal-length rows.

    Planning is payload-agnostic — only the per-request LENGTHS matter —
    so one planner serves both token prompts (`plan_pack` fills
    ``tokens``) and embeds-carrying requests (`pack_embeds` fills the
    ``[R, P, d]`` twin).  Each request occupies a *slot* of
    ``ceil(len/quantum) * quantum`` tokens (``quantum=1``: the raw
    length; ``quantum=bucket``: the same padded shape the bucketed path
    prefills, which keeps recurrent-state integration bit-identical — pad
    tokens update the SSD state in both).  Slots are placed longest-first
    onto the currently lightest row (LPT), opening rows beyond the
    ``ceil(total/pack_len)`` target only when a slot genuinely does not
    fit, and the realized row length is re-quantized to a ``bucket``
    multiple so executables keyed on (rows, pack_len) stay few.  Within a
    row every segment restarts positions at 0 and carries a distinct,
    monotone segment id — the block-diagonal mask's key.
    """
    n = len(lengths)
    assert n >= 1
    lengths = np.asarray(lengths, np.int64)
    if max_len is not None and (lengths > max_len).any():
        bad = int(lengths.max())
        raise ValueError(f"prompt length {bad} exceeds max_prompt_len "
                         f"{max_len}")
    slot = ((np.maximum(lengths, 1) + quantum - 1) // quantum) * quantum
    cap = max(pack_len, int(slot.max()))
    target_rows = max(1, int(-(-slot.sum() // cap)))

    order = np.argsort(-slot, kind="stable")
    loads = [0] * target_rows
    rows_of = np.zeros(n, np.int64)
    starts = np.zeros(n, np.int64)
    for i in order:
        fits = [r for r in range(len(loads)) if loads[r] + slot[i] <= cap]
        r = min(fits, key=lambda r: loads[r]) if fits else len(loads)
        if not fits:
            loads.append(0)
        rows_of[i], starts[i] = r, loads[r]
        loads[r] += int(slot[i])

    R = len(loads)
    P = int(-(-max(loads) // bucket)) * bucket
    seg_of = np.zeros(n, np.int64)
    counts = np.zeros(R, np.int64)
    tokens = np.zeros((R, P), np.int32)
    valid = np.zeros((R, P), bool)
    positions = np.zeros((R, P), np.int32)
    segments = np.zeros((R, P), np.int32)
    # order segments within a row by start offset so ids are non-decreasing
    for i in sorted(range(n), key=lambda i: (rows_of[i], starts[i])):
        r, s, L, Ls = rows_of[i], starts[i], int(lengths[i]), int(slot[i])
        seg_of[i] = counts[r]
        counts[r] += 1
        valid[r, s:s + L] = True
        positions[r, s:s + Ls] = np.arange(Ls)
        segments[r, s:s + Ls] = seg_of[i]
    for r in range(R):      # tail padding: its own id, positions reset
        t0 = int(loads[r])
        segments[r, t0:] = counts[r]
        positions[r, t0:] = np.arange(P - t0)

    K = int(counts.max())
    take_last = np.full((R, K), -1, np.int32)
    take_state = np.full((R, K), -1, np.int32)
    for i in range(n):
        r, j = rows_of[i], seg_of[i]
        take_last[r, j] = starts[i] + lengths[i] - 1
        take_state[r, j] = starts[i] + slot[i] - 1
    return PackPlan(tokens, valid, positions, segments, take_last, take_state,
                    rows_of.astype(np.int32), starts.astype(np.int32),
                    seg_of.astype(np.int32), lengths.astype(np.int32),
                    slot.astype(np.int32))


class ChunkPlan(NamedTuple):
    """Host-side plan for one CHUNKED prefill (DESIGN.md §5).

    The prompt is bucket-padded first (``P = ceil(t / bucket) * bucket`` —
    the exact token stream the bucketed monolithic path prefills, so
    recurrent pad-token integration matches it), then cut at ``chunk_len``
    multiples.  Every boundary is a bucket multiple, and the planner
    requires ``chunk_len % bucket == 0``, so chunk lengths come from the
    tiny set {chunk_len} ∪ {bucket multiples < chunk_len} and the chunk
    executables stay memoizable.  With ``ssm_chunk`` set the same
    alignment puts every boundary on the SSD chunk grid
    (``bucket % ssm_chunk == 0`` is validated), which is what makes the
    carried recurrent state bit-identical to one monolithic scan
    (`ssm.ssd_chunked`'s ``initial_state``).

    Because ``P < t + bucket <= t + chunk_len``, the last VALID token
    always lands in the final chunk — the only chunk that may carry
    right-padding — so ``last_logits`` and the first sampled token come
    out of the finalizing dispatch, never an interior one.
    """
    tokens: np.ndarray    # [P] int32 bucket-padded prompt
    valid: np.ndarray     # [P] bool (prefix mask; False on padding)
    starts: tuple         # chunk start offsets, multiples of chunk_len
    lens: tuple           # chunk lengths (all == chunk_len but maybe the last)
    t: int                # true prompt length
    total: int            # P, the bucket-padded length

    @property
    def n_chunks(self) -> int:
        return len(self.starts)


def plan_chunks(prompt: np.ndarray, chunk_len: int, bucket: int,
                ssm_chunk: int = 0,
                max_len: Optional[int] = None) -> ChunkPlan:
    """Cut one prompt into fixed-size prefill chunks (see `ChunkPlan`)."""
    if chunk_len <= 0 or chunk_len % bucket != 0:
        raise ValueError(
            f"chunk_len ({chunk_len}) must be a positive multiple of "
            f"prompt_bucket ({bucket})")
    if ssm_chunk and bucket % ssm_chunk != 0:
        raise ValueError(
            f"chunked prefill with recurrent layers requires prompt_bucket "
            f"({bucket}) to be a multiple of ssm_chunk ({ssm_chunk}) so "
            f"chunk boundaries align with the SSD chunk grid")
    p = np.asarray(prompt, np.int32)
    t = len(p)
    assert t >= 1, "empty prompt"
    if max_len is not None and t > max_len:
        raise ValueError(f"prompt length {t} exceeds "
                         f"max_prompt_len {max_len}")
    P = ((t + bucket - 1) // bucket) * bucket
    toks = np.zeros((P,), np.int32)
    toks[:t] = p
    valid = np.zeros((P,), bool)
    valid[:t] = True
    starts = tuple(range(0, P, chunk_len))
    lens = tuple(min(chunk_len, P - s) for s in starts)
    return ChunkPlan(toks, valid, starts, lens, t, P)


class ChunkOut(NamedTuple):
    last_logits: jnp.ndarray          # [B, V] at the chunk's last valid token
    k: Optional[jnp.ndarray]          # [n_attn, B, C, Hkv, hd] chunk KV
    v: Optional[jnp.ndarray]
    pos_row: jnp.ndarray              # [B, C] absolute positions (-1 on pad)
    colsums: Optional[jnp.ndarray]    # [n_attn, B, Cctx+C] RAW kv-head-mean mass
    ssm_state: Optional[tuple]        # (state, conv) carries after this chunk


def chunk_prefill(
    params,
    cfg: ModelConfig,
    tokens: jnp.ndarray,       # [B, C] one chunk of the bucket-padded prompt
    valid: jnp.ndarray,        # [B, C] prefix mask within the chunk
    start,                     # traced scalar: the chunk's absolute offset
    ctx=None,                  # previous chunks' staged KV (k, v, pos)
    state_in=None,             # previous chunk's recurrent carries
) -> ChunkOut:
    """One prefill chunk: forward over [start, start+C) with carry-in.

    Attention sees the previously-staged KV as read-only context
    (`models.attention.full_attention`'s ``ctx`` — the prefix-reuse hook,
    re-used here with staging buffers instead of cached pages), recurrent
    layers resume from ``state_in``.  Colsums come back RAW (un-normalized)
    over the concatenated [Cctx + C] key axis so the caller can accumulate
    them across chunks and divide by the prompt length once at finalize —
    the same per-query normalization monolithic `prefill` applies.
    """
    B, C = tokens.shape
    positions = start + jnp.broadcast_to(
        jnp.arange(C, dtype=jnp.int32)[None], (B, C))
    out = forward(params, cfg, tokens=tokens, positions=positions,
                  valid=valid, collect_kv=cfg.has_attention, ctx=ctx,
                  state_in=state_in)
    nv = valid.sum(-1).astype(jnp.int32)                    # [B] >= 1
    last = jnp.take_along_axis(
        out.logits, (jnp.maximum(nv, 1) - 1)[:, None, None], axis=1)[:, 0]
    pos_row = jnp.where(valid, positions, -1)
    if out.kv is not None:
        k, v = out.kv
        colsums = out.attn_scores.mean(axis=2)              # kv-head mean
    else:
        k = v = colsums = None
    return ChunkOut(last, k, v, pos_row, colsums, out.ssm_state)


class PrefillOut(NamedTuple):
    last_logits: jnp.ndarray          # [B, V] logits at each row's last valid token
    cos_sims: jnp.ndarray             # [n_attn_layers, B]
    k: Optional[jnp.ndarray]          # [n_attn, B, P, Hkv, hd]
    v: Optional[jnp.ndarray]
    cache_pos: Optional[jnp.ndarray]  # [n_attn, B, P] (-1 on padding)
    scores: Optional[jnp.ndarray]     # [n_attn, B, P] H2O col-sums (kv-head mean)
    ssm_state: Optional[tuple]        # (state, conv) stacked [n_ssm, ...]
    t: jnp.ndarray                    # [B] prompt lengths (next position)


def prefill(
    params,
    cfg: ModelConfig,
    tokens: Optional[jnp.ndarray] = None,      # [B, P]
    embeds: Optional[jnp.ndarray] = None,      # [B, P, d]
    positions: Optional[jnp.ndarray] = None,
    valid: Optional[jnp.ndarray] = None,       # [B, P] right-padding mask
) -> PrefillOut:
    B, P = (tokens.shape if tokens is not None else embeds.shape[:2])
    out = forward(params, cfg, tokens=tokens, embeds=embeds,
                  positions=positions, valid=valid, collect_kv=cfg.has_attention)
    if valid is None:
        t = jnp.full((B,), P, jnp.int32)
        last = out.logits[:, -1]
        pos_row = jnp.broadcast_to(jnp.arange(P, dtype=jnp.int32), (B, P))
    else:
        t = valid.sum(-1).astype(jnp.int32)
        last = jnp.take_along_axis(
            out.logits, (t - 1)[:, None, None].astype(jnp.int32), axis=1)[:, 0]
        pos_row = jnp.where(valid, jnp.arange(P, dtype=jnp.int32)[None], -1)

    if out.kv is not None:
        k, v = out.kv
        n_attn = k.shape[0]
        cache_pos = jnp.broadcast_to(pos_row[None], (n_attn, B, P))
        scores = out.attn_scores.mean(axis=2) / jnp.clip(
            t.astype(jnp.float32)[None, :, None], 1.0)  # kv-head mean, per-query norm
    else:
        k = v = cache_pos = scores = None
    return PrefillOut(last, out.cos_sims, k, v, cache_pos, scores,
                      out.ssm_state, t)


def prefill_ctx(
    params,
    cfg: ModelConfig,
    tokens: jnp.ndarray,       # [B, Psuf] suffix tokens (right-padded)
    valid: jnp.ndarray,        # [B, Psuf]
    matched: jnp.ndarray,      # [B] cached-prefix lengths (page multiples)
    pool_k: jnp.ndarray,       # [N_pages, psize, Hkv, hd] global page pool
    pool_v: jnp.ndarray,
    ctx_ids: jnp.ndarray,      # [n_attn, B, Cmax] page ids (0 = null page)
) -> PrefillOut:
    """Prefix-hit prefill: run the transformer over ONLY the unmatched
    suffix, attending the cached prefix pages as read-only context.

    The prefix-reuse payoff (DESIGN.md §5): a request whose first
    ``matched`` tokens are resident in the prefix cache pays transformer
    FLOPs for ``Psuf`` tokens instead of ``matched + Psuf``.  Suffix
    positions are absolute (``matched + i``), so RoPE matches the cold
    path exactly.  ``ctx_ids`` is traced data — one executable per
    (B, Psuf) serves every match length and page placement.

    Returns a regular `PrefillOut` over the CONCATENATED layout
    ``P_total = Cmax * psize + Psuf`` (gathered ctx region first, computed
    suffix second) so the downstream `Engine.build_state` -> compact ->
    admit machinery is reused unchanged.  Note the layout's valid slots are
    no longer a contiguous prefix — the ctx region's tail (beyond
    ``matched``) is empty — which is why the paged admit path re-sorts
    slots canonically after compaction (`core.cache.sort_slots`).
    """
    B, Psuf = tokens.shape
    n_attn, _, Cmax = ctx_ids.shape
    psize = pool_k.shape[1]
    C = Cmax * psize
    matched = matched.astype(jnp.int32)

    def g(a):   # [n_attn, B, Cmax] pages -> [n_attn, B, C, Hkv, hd]
        return a[ctx_ids].reshape(n_attn, B, C, *a.shape[2:])

    ck, cv = g(pool_k), g(pool_v)
    cpos = jnp.broadcast_to(jnp.arange(C, dtype=jnp.int32)[None], (B, C))
    cpos = jnp.where(cpos < matched[:, None], cpos, -1)          # [B, C]
    positions = matched[:, None] + jnp.arange(Psuf, dtype=jnp.int32)[None]

    out = forward(params, cfg, tokens=tokens, positions=positions,
                  valid=valid, collect_kv=True, ctx=(ck, cv, cpos))

    nsuf = valid.sum(-1).astype(jnp.int32)
    t = matched + nsuf
    last = jnp.take_along_axis(out.logits, (nsuf - 1)[:, None, None],
                               axis=1)[:, 0]
    k_suf, v_suf = out.kv
    pos_suf = jnp.where(valid, positions, -1)
    cache_pos = jnp.concatenate(
        [jnp.broadcast_to(cpos[None], (n_attn, B, C)),
         jnp.broadcast_to(pos_suf[None], (n_attn, B, Psuf))], axis=2)
    # H2O column sums cover the concatenated key axis but count only the
    # SUFFIX queries' mass (the prefix's own prefill mass is gone — this is
    # why the engine gates prefix caching to position-based policies)
    scores = out.attn_scores.mean(axis=2) / jnp.clip(
        t.astype(jnp.float32)[None, :, None], 1.0)
    return PrefillOut(last, out.cos_sims,
                      jnp.concatenate([ck.astype(k_suf.dtype), k_suf], axis=2),
                      jnp.concatenate([cv.astype(v_suf.dtype), v_suf], axis=2),
                      cache_pos, scores, None, t)


class PackedPrefillOut(NamedTuple):
    """Per-PACKED-ROW prefill outputs; request-shaped views are gathered by
    the fused unpack+admit executable (`ContinuousEngine._padmit_jit`)."""
    seg_logits: jnp.ndarray           # [R, K, V] logits at each segment's
                                      #           last valid token
    cos_sims: jnp.ndarray             # [n_layers, R] (token-avg over the ROW)
    k: Optional[jnp.ndarray]          # [n_attn, R, P, Hkv, hd]
    v: Optional[jnp.ndarray]
    cache_pos: Optional[jnp.ndarray]  # [n_attn, R, P] segment-reset positions
                                      #              (-1 on padding)
    colsums: Optional[jnp.ndarray]    # [n_attn, R, P] RAW H2O column sums
                                      #   (kv-head mean; per-request /t
                                      #    normalization happens at unpack)
    ssm_state: Optional[tuple]        # (ssm [n_ssm,R,K,H,P,N],
                                      #  conv [n_ssm,R,K,W-1,C]) snapshots


def packed_prefill(
    params,
    cfg: ModelConfig,
    tokens: Optional[jnp.ndarray],  # [R, P] packed rows (PackPlan.tokens)
    positions: jnp.ndarray,     # [R, P] segment-reset positions
    valid: jnp.ndarray,         # [R, P]
    segments: jnp.ndarray,      # [R, P] segment ids
    take_last: jnp.ndarray,     # [R, K] last valid token per segment
    take_state: jnp.ndarray,    # [R, K] last slot token per segment
    embeds: Optional[jnp.ndarray] = None,  # [R, P, d] packed rows
                                           # (`pack_embeds`, vlm/audio)
) -> PackedPrefillOut:
    """Prefill a whole admission burst as ONE packed dispatch.

    The block-diagonal mask (`segments` through `forward`) keeps every
    request's attention, recurrence and logits exactly what a solo prefill
    would compute; this function additionally snapshots, per segment, the
    last-valid-token logits and (for recurrent layers) the end-of-slot
    SSD/conv states, so the admit executable only gathers — it never
    recomputes.  The packed rows arrive either as token ids or as
    precomputed embeddings (`embeds`, the intake's vlm/audio layout) —
    everything downstream of the embedding lookup is identical.
    """
    R, P = (tokens.shape if tokens is not None else embeds.shape[:2])
    need_state = cfg.is_ssm_only or cfg.is_hybrid
    # slot boundaries are chunk-aligned by construction (the continuous
    # engine enforces prompt_bucket % ssm_chunk == 0 for recurrent packs),
    # so the snapshots are the cheap bit-exact post-chunk gathers
    out = forward(params, cfg, tokens=tokens, embeds=embeds,
                  positions=positions,
                  valid=valid, collect_kv=cfg.has_attention,
                  segments=segments,
                  state_take=take_state if need_state else None,
                  state_take_aligned=True)
    seg_logits = jnp.take_along_axis(
        out.logits, jnp.maximum(take_last, 0)[..., None], axis=1)  # [R,K,V]
    if out.kv is not None:
        k, v = out.kv
        n_attn = k.shape[0]
        pos_row = jnp.where(valid, positions, -1)
        cache_pos = jnp.broadcast_to(pos_row[None], (n_attn, R, P))
        colsums = out.attn_scores.mean(axis=2)        # kv-head mean, raw
    else:
        k = v = cache_pos = colsums = None
    return PackedPrefillOut(seg_logits, out.cos_sims, k, v, cache_pos,
                            colsums, out.ssm_state)
