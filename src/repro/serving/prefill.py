"""Prefill: full-attention pass that doubles as the measurement phase.

Returns everything SqueezeAttention's host-side allocator needs: per-layer
cosine similarities (Eq. 5, token-averaged), the full KV to be compacted into
the budget arenas, and the H2O prefill column-sum statistics.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.transformer import forward


def pad_prompts(prompts: Sequence[np.ndarray], bucket: int,
                batch: Optional[int] = None,
                max_len: Optional[int] = None):
    """Host-side shape bucketing shared by every serving client.

    Right-pads 1-D prompts to the next multiple of `bucket` (over the longest
    prompt) and to `batch` rows, returning ``(tokens [B, P] int32,
    valid [B, P] bool)``.  Prefill executables are memoized on (B, P), so
    bucketing here is what makes repeated traffic hit compiled code.
    `max_len` raises on over-long prompts (the continuous-batching admission
    cap — arena sizes are fixed at plan time).
    """
    B = batch if batch is not None else len(prompts)
    assert len(prompts) <= B
    if max_len is not None:
        for p in prompts:
            if len(p) > max_len:
                raise ValueError(f"prompt length {len(p)} exceeds "
                                 f"max_prompt_len {max_len}")
    plen = max(len(p) for p in prompts)
    P = ((plen + bucket - 1) // bucket) * bucket
    toks = np.zeros((B, P), np.int32)
    valid = np.zeros((B, P), bool)
    for i, p in enumerate(prompts):
        toks[i, :len(p)] = p
        valid[i, :len(p)] = True
    return toks, valid


def group_by_bucket(lengths: Sequence[int], bucket: int):
    """Length-sorted admission grouping (DESIGN.md §5).

    Partitions request indices by their *bucketed* prompt length (next
    multiple of `bucket`) and returns the groups shortest-bucket-first:
    ``[(padded_len, [indices...]), ...]``.  Each group prefills at its own
    bucket instead of the burst-wide pad-to-longest, so a bimodal burst of
    mostly-short prompts stops paying the longest prompt's padded FLOPs —
    the win `benchmarks/serving_bench.py` measures as `prefill_pad_tokens`.
    """
    buckets = {}
    for i, n in enumerate(lengths):
        p = ((max(int(n), 1) + bucket - 1) // bucket) * bucket
        buckets.setdefault(p, []).append(i)
    return sorted(buckets.items())


def pad_prompt(prompt: np.ndarray, bucket: int,
               max_len: Optional[int] = None):
    """Single-request `pad_prompts`."""
    return pad_prompts([np.asarray(prompt, np.int32)], bucket,
                       max_len=max_len)


class PrefillOut(NamedTuple):
    last_logits: jnp.ndarray          # [B, V] logits at each row's last valid token
    cos_sims: jnp.ndarray             # [n_attn_layers, B]
    k: Optional[jnp.ndarray]          # [n_attn, B, P, Hkv, hd]
    v: Optional[jnp.ndarray]
    cache_pos: Optional[jnp.ndarray]  # [n_attn, B, P] (-1 on padding)
    scores: Optional[jnp.ndarray]     # [n_attn, B, P] H2O col-sums (kv-head mean)
    ssm_state: Optional[tuple]        # (state, conv) stacked [n_ssm, ...]
    t: jnp.ndarray                    # [B] prompt lengths (next position)


def prefill(
    params,
    cfg: ModelConfig,
    tokens: Optional[jnp.ndarray] = None,      # [B, P]
    embeds: Optional[jnp.ndarray] = None,      # [B, P, d]
    positions: Optional[jnp.ndarray] = None,
    valid: Optional[jnp.ndarray] = None,       # [B, P] right-padding mask
) -> PrefillOut:
    B, P = (tokens.shape if tokens is not None else embeds.shape[:2])
    out = forward(params, cfg, tokens=tokens, embeds=embeds,
                  positions=positions, valid=valid, collect_kv=cfg.has_attention)
    if valid is None:
        t = jnp.full((B,), P, jnp.int32)
        last = out.logits[:, -1]
        pos_row = jnp.broadcast_to(jnp.arange(P, dtype=jnp.int32), (B, P))
    else:
        t = valid.sum(-1).astype(jnp.int32)
        last = jnp.take_along_axis(
            out.logits, (t - 1)[:, None, None].astype(jnp.int32), axis=1)[:, 0]
        pos_row = jnp.where(valid, jnp.arange(P, dtype=jnp.int32)[None], -1)

    if out.kv is not None:
        k, v = out.kv
        n_attn = k.shape[0]
        cache_pos = jnp.broadcast_to(pos_row[None], (n_attn, B, P))
        scores = out.attn_scores.mean(axis=2) / jnp.clip(
            t.astype(jnp.float32)[None, :, None], 1.0)  # kv-head mean, per-query norm
    else:
        k = v = cache_pos = scores = None
    return PrefillOut(last, out.cos_sims, k, v, cache_pos, scores,
                      out.ssm_state, t)
