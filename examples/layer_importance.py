"""Reproduce the paper's Figure 2: layer-importance heatmap (ASCII).

    PYTHONPATH=src python examples/layer_importance.py [--arch mistral-7b]

Feeds prompts through a reduced-family model and prints the cosine
similarity between the residual stream before/after each attention block
(Eq. 5), per layer — the signal SqueezeAttention clusters.  Darker block =
lower similarity = more important layer.
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ALL_ARCHS, get_reduced
from repro.models import forward, init_params

SHADES = " .:-=+*#%@"


def heat(v, lo, hi):
    i = int((v - lo) / max(hi - lo, 1e-9) * (len(SHADES) - 1))
    return SHADES[len(SHADES) - 1 - max(0, min(i, len(SHADES) - 1))]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="default: 4 representative archs")
    ap.add_argument("--layers", type=int, default=12)
    ap.add_argument("--prompts", type=int, default=8)
    args = ap.parse_args()
    archs = [args.arch] if args.arch else \
        ["mistral-7b", "llama2-7b", "gemma2-27b", "mamba2-1.3b"]

    for arch in archs:
        cfg = get_reduced(arch)
        if not cfg.is_hybrid:
            cfg = dataclasses.replace(cfg, n_layers=args.layers)
        params = init_params(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(1)
        toks = rng.integers(0, cfg.vocab_size,
                            (args.prompts, 64)).astype(np.int32)
        toks[:, 32:] = toks[:, :32]        # structured prompt
        out = forward(params, cfg, tokens=jnp.asarray(toks))
        cs = np.asarray(out.cos_sims).mean(-1)
        lo, hi = cs.min(), cs.max()
        bar = "".join(heat(v, lo, hi) for v in cs)
        note = " (mixer blocks; no KV cache — measurement only)" \
            if cfg.is_ssm_only else ""
        print(f"\n{arch:22s}{note}")
        print(f"  layer importance |{bar}|  (dark=important)")
        print("  cos sims:", np.array2string(cs, precision=3))
        if cs.size >= 4:
            print(f"  first half mean {cs[:len(cs)//2].mean():.3f}   "
                  f"second half mean {cs[len(cs)//2:].mean():.3f}")


if __name__ == "__main__":
    main()
