"""End-to-end training driver: ~100M-param model for a few hundred steps.

    PYTHONPATH=src python examples/train_100m.py [--steps 300] [--arch olmo-1b]

Uses the real launcher (repro.launch.train) with the '100m' preset — the
same train_step the multi-pod dry-run lowers, running data-parallel on this
host.  Checkpoints land in experiments/train_100m/.
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    argv = sys.argv[1:]
    # 125M params x seq 256 x batch 4: a few hundred steps is ~1 h on this
    # CPU container; on the production mesh the same step lowers via
    # launch/dryrun.py.  Pass --steps to go longer.
    defaults = ["--preset", "100m", "--steps", "200", "--seq", "256",
                "--batch", "4", "--ckpt-dir", "experiments/train_100m",
                "--log-every", "10"]
    if "--arch" not in " ".join(argv):
        defaults += ["--arch", "olmo-1b"]
    sys.argv = [sys.argv[0]] + defaults + argv
    main()
