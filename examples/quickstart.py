"""Quickstart: SqueezeAttention end to end on a small model.

    PYTHONPATH=src python examples/quickstart.py

Shows the full paper flow: prefill measures per-layer cosine similarity,
KMeans groups the layers, Algorithm 1 reallocates the KV budget, and the
decode loop runs with per-layer-tier arenas — then compares the three modes
(full cache / uniform sequence-wise budget / squeeze).
"""
import dataclasses

import jax
import numpy as np

from repro.configs import get_reduced
from repro.core import PolicyConfig
from repro.models import init_params
from repro.serving import Engine, EngineConfig


def main():
    cfg = dataclasses.replace(get_reduced("mistral-7b"), n_layers=6,
                              sliding_window=None)
    print(f"model: {cfg.name}  layers={cfg.n_layers}  d={cfg.d_model}")
    params = init_params(jax.random.PRNGKey(0), cfg)

    prompt = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, 48)).astype(np.int32)
    # make it structured (repeat) so eviction is observable
    prompt[:, 24:] = prompt[:, :24]

    results = {}
    for mode, frac in (("full", 1.0), ("uniform", 0.5), ("squeeze", 0.5)):
        eng = Engine(params, cfg, EngineConfig(
            mode=mode, policy=PolicyConfig("sliding_window"),
            budget_frac=frac, p=0.35, max_new_tokens=16,
            bucket=4, min_budget=4))
        r = eng.generate(tokens=prompt)
        results[mode] = r
        print(f"\n== {mode} ==")
        print(f" budgets: {sorted(set(r.plan.budgets.tolist()))} "
              f"(total slots {r.cache_slots})")
        if mode == "squeeze":
            print(f" cosine sims per layer: {np.round(r.cos_sims, 3)}")
            print(f" squeezed layers (G3):  "
                  f"{[i for i, s in enumerate(r.plan.is_small) if s]}")
        print(f" tokens[0]: {r.tokens[0][:10]}...")
        print(f" prefill {r.prefill_seconds*1e3:.1f}ms  "
              f"allocate {r.allocate_seconds*1e3:.1f}ms  "
              f"decode {r.decode_seconds*1e3:.1f}ms")

    full, sq = results["full"], results["squeeze"]
    agree = (full.tokens == sq.tokens).mean()
    print(f"\nsqueeze vs full-cache: {sq.cache_slots}/{full.cache_slots} "
          f"slots ({100*(1-sq.cache_slots/full.cache_slots):.0f}% memory "
          f"saved), token agreement {agree:.2f}")


if __name__ == "__main__":
    main()
