"""Batched serving comparison: Full Cache vs best-baseline vs SqueezeAttention.

    PYTHONPATH=src python examples/serve_batch.py [--batches 1 4 8]

The paper's Table 3 experiment shape: fixed prompt/gen length, growing batch
size, measuring tokens/s and KV memory.  A second section serves the same
requests with *heterogeneous* generation lengths through both schedulers —
the regime where token-level continuous batching (slot recycling) beats
lock-step waves.  Runs a reduced model on CPU; on a TPU mesh the same Engine
code runs under the production sharding (launch/dryrun.py proves the
lowering).
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_reduced
from repro.core import POLICIES, PolicyConfig, plan_cache_bytes
from repro.models import init_params
from repro.serving import (ContinuousConfig, ContinuousScheduler, Engine,
                           EngineConfig, SchedulerConfig, WaveScheduler)


def table3_section(params, cfg, args):
    rng = np.random.default_rng(0)
    print(f"{'batch':>5} {'mode':>8} {'tok/s':>9} {'KV slots':>9} {'KV MB':>8}")
    for bs in args.batches:
        prompt = rng.integers(0, cfg.vocab_size,
                              (bs, args.prompt_len)).astype(np.int32)
        for mode, frac in (("full", 1.0), ("uniform", 0.3), ("squeeze", 0.3)):
            eng = Engine(params, cfg, EngineConfig(
                mode=mode, policy=PolicyConfig(args.policy),
                budget_frac=frac, max_new_tokens=args.gen_len,
                bucket=4, min_budget=4))
            r = eng.generate(tokens=prompt)
            mb = plan_cache_bytes(r.plan, bs, cfg.n_kv_heads, cfg.hd) / 1e6
            print(f"{bs:>5} {mode:>8} {r.tokens_per_second:>9.1f} "
                  f"{r.cache_slots:>9} {mb:>8.2f}")


def continuous_section(params, cfg, args):
    """Same requests, heterogeneous max_new: waves pay max(max_new) per
    member, continuous retires rows early and recycles their slots."""
    n_req = max(args.batches) * 4
    rng = np.random.default_rng(1)
    reqs = [(rng.integers(0, cfg.vocab_size, (args.prompt_len,)),
             int(rng.integers(2, args.gen_len + 1))) for _ in range(n_req)]
    ecfg = EngineConfig(mode="uniform", policy=PolicyConfig(args.policy),
                        budget_abs=args.prompt_len // 2, bucket=4,
                        min_budget=4)

    def drain(sched):
        for p, mn in reqs:
            sched.submit(p, max_new=mn)
        sched.run_until_empty()          # warm the executables
        for p, mn in reqs:
            sched.submit(p, max_new=mn)
        t0 = time.perf_counter()
        done = sched.run_until_empty()
        wall = time.perf_counter() - t0
        toks = sum(r.tokens.size for r in done)
        return wall, toks

    wave = WaveScheduler(params, cfg, ecfg, SchedulerConfig(
        wave_size=4, prompt_bucket=args.prompt_len,
        max_wave_new=args.gen_len))
    cont = ContinuousScheduler(params, cfg, ecfg, ContinuousConfig(
        max_concurrency=4, prompt_bucket=args.prompt_len,
        max_prompt_len=args.prompt_len, max_new_cap=args.gen_len))
    print(f"\nheterogeneous max_new (2..{args.gen_len}), {n_req} requests:")
    print(f"{'scheduler':>11} {'wall ms':>9} {'tok/s':>9}")
    for name, sched in (("wave", wave), ("continuous", cont)):
        wall, toks = drain(sched)
        print(f"{name:>11} {wall*1e3:>9.1f} {toks/max(wall,1e-9):>9.1f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batches", type=int, nargs="+", default=[1, 4, 8])
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--policy", default="sliding_window",
                    choices=list(POLICIES))
    args = ap.parse_args()

    cfg = dataclasses.replace(get_reduced("mistral-7b"), n_layers=4)
    params = init_params(jax.random.PRNGKey(0), cfg)
    table3_section(params, cfg, args)
    continuous_section(params, cfg, args)


if __name__ == "__main__":
    main()
