"""Batched serving comparison: Full Cache vs best-baseline vs SqueezeAttention.

    PYTHONPATH=src python examples/serve_batch.py [--batches 1 4 8]

The paper's Table 3 experiment shape: fixed prompt/gen length, growing batch
size, measuring tokens/s and KV memory.  Runs a reduced model on CPU; on a
TPU mesh the same Engine code runs under the production sharding
(launch/dryrun.py proves the lowering).
"""
import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import get_reduced
from repro.core import PolicyConfig, plan_cache_bytes
from repro.models import init_params
from repro.serving import Engine, EngineConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batches", type=int, nargs="+", default=[1, 4, 8])
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--policy", default="sliding_window")
    args = ap.parse_args()

    cfg = dataclasses.replace(get_reduced("mistral-7b"), n_layers=4)
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)

    print(f"{'batch':>5} {'mode':>8} {'tok/s':>9} {'KV slots':>9} {'KV MB':>8}")
    for bs in args.batches:
        prompt = rng.integers(0, cfg.vocab_size,
                              (bs, args.prompt_len)).astype(np.int32)
        for mode, frac in (("full", 1.0), ("uniform", 0.3), ("squeeze", 0.3)):
            eng = Engine(params, cfg, EngineConfig(
                mode=mode, policy=PolicyConfig(args.policy),
                budget_frac=frac, max_new_tokens=args.gen_len,
                bucket=4, min_budget=4))
            r = eng.generate(tokens=prompt)
            mb = plan_cache_bytes(r.plan, bs, cfg.n_kv_heads, cfg.hd) / 1e6
            print(f"{bs:>5} {mode:>8} {r.tokens_per_second:>9.1f} "
                  f"{r.cache_slots:>9} {mb:>8.2f}")


if __name__ == "__main__":
    main()
