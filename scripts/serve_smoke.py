"""CI serve-smoke: boot the async serving stack end to end and prove the
HTTP story in one shot — a real `ServingService` (background loop +
double-buffered emission drain) behind the OpenAI-compatible endpoint on
an ephemeral port, one streamed SSE completion, one non-streamed one, and
`/metrics` reporting TTFT/ITL SLO rows for both.

    PYTHONPATH=src python scripts/serve_smoke.py

Runs on a tiny dense config so the fast CI lane affords it; everything
here is asserted, so a silent wedge in the loop thread, the SSE framing,
or the SLO plumbing fails the lane instead of hanging it (every wait is
bounded).
"""
import json
import sys
import threading
import urllib.request

import jax
import numpy as np

sys.path.insert(0, "src")

from repro.core import PolicyConfig                              # noqa: E402
from repro.launch.http_api import make_server                    # noqa: E402
from repro.models import ModelConfig, init_params                # noqa: E402
from repro.serving import (ContinuousConfig, ContinuousScheduler,  # noqa: E402
                           EngineConfig, ServingService)

CFG = ModelConfig(name="smoke", arch_type="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97,
                  dtype="float32", param_dtype="float32")
ECFG = EngineConfig(mode="uniform", policy=PolicyConfig("sliding_window"),
                    budget_abs=12, bucket=4, min_budget=4)
CCFG = ContinuousConfig(max_concurrency=3, prompt_bucket=8, max_prompt_len=24,
                        max_new_cap=8, sync_every=2)


def main():
    params = init_params(jax.random.PRNGKey(0), CFG)
    sched = ContinuousScheduler(params, CFG, ECFG, CCFG, seed=0)
    svc = ServingService(sched)
    httpd = make_server(svc, port=0)
    port = httpd.server_address[1]
    threading.Thread(target=httpd.serve_forever, daemon=True).start()
    base = f"http://127.0.0.1:{port}"
    try:
        # streamed completion (the curl -N demo from the README)
        req = urllib.request.Request(
            base + "/v1/completions",
            data=json.dumps({"prompt": "count with me", "max_tokens": 6,
                             "stream": True}).encode(),
            headers={"Content-Type": "application/json"})
        toks, done = [], False
        with urllib.request.urlopen(req, timeout=120) as r:
            assert r.status == 200, r.status
            assert r.headers["Content-Type"].startswith("text/event-stream")
            for line in r:
                line = line.decode().strip()
                if not line.startswith("data: "):
                    continue
                if line[6:] == "[DONE]":
                    done = True
                    break
                c = json.loads(line[6:])["choices"][0]
                if "token" in c:
                    toks.append(c["token"])
        assert done, "stream never terminated with [DONE]"
        assert len(toks) == 6, f"expected 6 streamed tokens, got {toks}"
        print(f"streamed completion OK: {toks}")

        # non-streamed completion with explicit ids + per-request SLO
        req = urllib.request.Request(
            base + "/v1/completions",
            data=json.dumps({"prompt": [5, 9, 11, 2],
                             "max_tokens": 4}).encode(),
            headers={"Content-Type": "application/json"})
        with urllib.request.urlopen(req, timeout=120) as r:
            obj = json.load(r)
        assert len(obj["choices"][0]["tokens"]) == 4, obj
        assert obj["slo"]["ttft_ms"] > 0.0, obj["slo"]
        print(f"completion OK: {obj['choices'][0]['tokens']} "
              f"ttft={obj['slo']['ttft_ms']:.1f}ms")

        # /metrics carries the service-wide TTFT/ITL SLO aggregate
        with urllib.request.urlopen(base + "/metrics", timeout=30) as r:
            rows = dict(line.split(" ", 1)
                        for line in r.read().decode().splitlines())
        for key in ("serving_completed", "serving_ttft_p50_ms",
                    "serving_ttft_p95_ms", "serving_itl_p50_ms",
                    "serving_itl_p95_ms", "serving_queue_wait_p50_ms",
                    "serving_drain_stall_s", "serving_drained_blocks"):
            assert key in rows, f"/metrics missing {key}"
        assert float(rows["serving_completed"]) == 2, rows
        assert float(rows["serving_ttft_p50_ms"]) > 0.0, rows
        print(f"metrics OK: completed={rows['serving_completed']} "
              f"ttft_p50={float(rows['serving_ttft_p50_ms']):.1f}ms "
              f"itl_p95={float(rows['serving_itl_p95_ms']):.1f}ms")
    finally:
        httpd.shutdown()
        httpd.server_close()
        svc.close(drain=True)
    assert svc.engine.drained_blocks > 0
    print("serve smoke OK")


if __name__ == "__main__":
    main()
