#!/usr/bin/env python
"""Docs lane (scripts/ci.sh --docs): keep the documentation honest.

Two checks, both cheap enough to run on every push:

  1. **Internal links resolve** — every relative markdown link in the
     checked docs must point at a file (or file#anchor whose heading
     exists) inside the repo.  External http(s) links are not fetched.
  2. **The API snippet runs** — every ```python block in docs/API.md is
     executed (in order, one shared namespace) under JAX_PLATFORMS=cpu,
     so the documented quickstart can never rot silently.

    PYTHONPATH=src python scripts/check_docs.py [--no-snippets]
"""
from __future__ import annotations

import argparse
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _discover_docs() -> list[str]:
    """Every checked page: the root docs plus EVERYTHING under docs/ —
    new pages get link/anchor coverage without editing this list."""
    docs = [d for d in ("README.md", "DESIGN.md", "ROADMAP.md", "PAPER.md")
            if os.path.exists(os.path.join(ROOT, d))]
    ddir = os.path.join(ROOT, "docs")
    if os.path.isdir(ddir):
        docs += sorted("docs/" + f for f in os.listdir(ddir)
                       if f.endswith(".md"))
    return docs


DOCS = _discover_docs()
SNIPPET_DOC = "docs/API.md"

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#+\s+(.*)$", re.MULTILINE)
FENCE_RE = re.compile(r"```python\n(.*?)```", re.DOTALL)


def _anchor(heading: str) -> str:
    """GitHub-style heading -> anchor slug."""
    h = heading.strip().lower()
    h = re.sub(r"[^\w\s-]", "", h)
    return re.sub(r"\s+", "-", h)


def check_links() -> list[str]:
    errors = []
    for doc in DOCS:
        path = os.path.join(ROOT, doc)
        if not os.path.exists(path):
            errors.append(f"{doc}: file missing")
            continue
        text = open(path).read()
        base = os.path.dirname(path)
        for target in LINK_RE.findall(text):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            ref, _, frag = target.partition("#")
            dest = os.path.normpath(os.path.join(base, ref)) if ref else path
            if not os.path.exists(dest):
                errors.append(f"{doc}: broken link -> {target}")
                continue
            if frag and dest.endswith(".md"):
                anchors = {_anchor(h) for h in
                           HEADING_RE.findall(open(dest).read())}
                if frag not in anchors:
                    errors.append(f"{doc}: broken anchor -> {target}")
    return errors


def run_snippets() -> list[str]:
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    path = os.path.join(ROOT, SNIPPET_DOC)
    blocks = FENCE_RE.findall(open(path).read())
    if not blocks:
        return [f"{SNIPPET_DOC}: no ```python blocks found"]
    ns: dict = {}
    for i, code in enumerate(blocks):
        try:
            exec(compile(code, f"{SNIPPET_DOC}[snippet {i}]", "exec"), ns)
        except Exception as e:  # noqa: BLE001 — report, don't crash the lane
            return [f"{SNIPPET_DOC} snippet {i} failed: {type(e).__name__}: {e}"]
    print(f"docs: {len(blocks)} snippet(s) from {SNIPPET_DOC} ran OK")
    return []


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--no-snippets", action="store_true",
                    help="link check only (no JAX import)")
    args = ap.parse_args()
    errors = check_links()
    print(f"docs: checked links in {', '.join(DOCS)}")
    if not args.no_snippets and not errors:
        errors += run_snippets()
    for e in errors:
        print(f"docs ERROR: {e}", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
