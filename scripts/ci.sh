#!/usr/bin/env bash
# CPU CI, tiered (DESIGN.md §5):
#
#     scripts/ci.sh --fast                 # unit lane: pytest -m fast, <2 min
#     scripts/ci.sh --full                 # system + kernel lane + smoke gate
#     scripts/ci.sh --docs                 # docs lane: link check + API snippet
#     scripts/ci.sh --coverage             # full suite under pytest-cov + floor
#     scripts/ci.sh                        # everything (tier-1 verify exact)
#     scripts/ci.sh --with-benchmarks      # ... plus the quick benchmark suite
#
# The fast lane runs the unit-level tests only (marker `fast`, registered in
# pyproject.toml; --strict-markers makes unknown marks collection errors),
# then the serve-smoke: the async serving service behind the OpenAI HTTP
# endpoint on a tiny model, asserting SSE streaming and /metrics SLO rows.
# The full lane runs the complement (system + interpret-mode kernel tests),
# the quickstart example, and the serving-bench smoke, which doubles as the
# bench-regression gate: it compares dispatches-per-decode-step and the
# fused/per-step wall-clock ratio against the last BENCH_serving.json entry
# and fails on >20% regression.  The coverage lane reruns the full suite
# under pytest-cov with a line-coverage floor (COV_FLOOR, default 70) over
# src/repro; it degrades to a no-op with a message when pytest-cov is not
# installed, so local runs without the optional dep never fail — the CI
# `coverage` job installs it explicitly.  The default (no flag) mirrors the
# tier-1 verify command from ROADMAP.md exactly, then runs example + smoke.
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

run_pytest() {
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
}

lane="${1:-}"

case "$lane" in
    --fast)
        echo "== fast lane: unit tests (-m fast) =="
        run_pytest -m fast
        echo "== fast lane: HTTP serve smoke =="
        PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python scripts/serve_smoke.py
        echo "CI OK (fast lane)"
        exit 0
        ;;
    --docs)
        echo "== docs lane: internal links + docs/API.md snippet =="
        PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python scripts/check_docs.py
        echo "CI OK (docs lane)"
        exit 0
        ;;
    --coverage)
        echo "== coverage lane: full suite under pytest-cov =="
        if ! python -c "import pytest_cov" >/dev/null 2>&1; then
            echo "pytest-cov not installed; skipping coverage lane"
            echo "(the CI coverage job installs it: pip install pytest-cov)"
            exit 0
        fi
        run_pytest --cov=repro --cov-report=term \
            --cov-fail-under="${COV_FLOOR:-70}"
        echo "CI OK (coverage lane)"
        exit 0
        ;;
    --full)
        echo "== full lane: system + kernel tests (-m 'not fast') =="
        run_pytest -m "not fast"
        ;;
    *)
        echo "== tier-1: pytest =="
        run_pytest
        ;;
esac

echo "== quickstart example =="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python examples/quickstart.py

echo "== paged serving launcher (page tables + prefix cache) =="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m repro.launch.serve \
    --arch mistral-7b --reduced --batching continuous --mode uniform \
    --batch 4 --max-concurrency 2 --prompt-len 32 --max-new 8 \
    --page-size 8 --prefix-cache

echo "== serving bench smoke + regression gate =="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.serving_bench --smoke

if [[ "$lane" == "--with-benchmarks" ]]; then
    echo "== quick benchmarks =="
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.run --quick
fi

echo "CI OK"
