#!/usr/bin/env bash
# CPU CI: tier-1 tests + the quickstart example.
#
#     scripts/ci.sh [--with-benchmarks]
#
# Mirrors the tier-1 verify command from ROADMAP.md exactly, then proves the
# end-to-end serving flow (prefill -> KMeans/Algorithm-1 -> tiered decode)
# still runs.  `--with-benchmarks` additionally drains the quick benchmark
# suite (several minutes on CPU).
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

echo "== tier-1: pytest =="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q

echo "== quickstart example =="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python examples/quickstart.py

echo "== serving bench smoke (fused decode blocks) =="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.serving_bench --smoke

if [[ "${1:-}" == "--with-benchmarks" ]]; then
    echo "== quick benchmarks =="
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.run --quick
fi

echo "CI OK"
