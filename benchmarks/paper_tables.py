"""One benchmark per paper table/figure (SqueezeAttention, ICLR 2025).

fig2  — layer-importance observation (cosine sims across depth)
fig3  — accuracy-vs-budget: squeeze beats uniform at equal total budget
table2 — min budget to reach iso-fidelity
fig4  — per-token decode memory
table3 — generation throughput vs batch size
table4/5 — overhead of cosine tracking + kmeans/allocation
a2    — sensitivity to the hyperparameter p
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import (decode_fidelity, eval_prompts, row,
                               trained_model)
from repro.core import allocate, kmeans_1d, plan_cache_bytes
from repro.models import forward, init_params


def fig2_layer_importance(quick=False):
    """Cosine-similarity-by-depth on reduced variants of 4 archs (Fig 2)."""
    import jax.numpy as jnp
    from repro.configs import get_reduced
    import dataclasses
    out = []
    for arch in ("mistral-7b", "llama2-7b", "gemma2-27b", "olmo-1b"):
        cfg = dataclasses.replace(get_reduced(arch), n_layers=8)
        params = init_params(jax.random.PRNGKey(0), cfg)
        toks = eval_prompts(4, 64, cfg.vocab_size)
        t0 = time.perf_counter()
        o = forward(params, cfg, tokens=jnp.asarray(toks))
        cs = np.asarray(o.cos_sims).mean(-1)
        dt = (time.perf_counter() - t0) * 1e6
        first, second = cs[:4].mean(), cs[4:].mean()
        out.append(row(f"fig2_cos_sim_{arch}", dt,
                       f"first_half={first:.3f};second_half={second:.3f};"
                       f"second_higher={second > first}"))
    return out


def fig3_accuracy_vs_budget(quick=False, policy="sliding_window"):
    params, cfg = trained_model()
    prompts = eval_prompts(4 if quick else 8)
    fracs = (0.3, 0.5) if quick else (0.2, 0.3, 0.5, 0.7)
    out = []
    for frac in fracs:
        u = decode_fidelity(params, cfg, prompts, "uniform", policy=policy,
                            budget_frac=frac)
        s = decode_fidelity(params, cfg, prompts, "squeeze", policy=policy,
                            budget_frac=frac)
        out.append(row(
            f"fig3_budget_{int(frac*100)}pct",
            u["wall"] * 1e6,
            f"uniform_agree={u['agreement']:.3f};"
            f"squeeze_agree={s['agreement']:.3f};"
            f"squeeze_slots={s['cache_slots']};uniform_slots={u['cache_slots']}"))
    return out


def fig3b_allocation_frontier(quick=False):
    """Memory-vs-quality frontier column (beyond the paper): token
    agreement for uniform / 2-tier squeeze / N-tier zigzag x {h2o,
    l2_norm} at the same conserved total budget.  The delta-vs-uniform
    column is the quality the layer-wise shaping buys at equal memory."""
    params, cfg = trained_model()
    prompts = eval_prompts(4 if quick else 8)
    fracs = (0.5,) if quick else (0.3, 0.5)
    out = []
    for frac in fracs:
        for pol in ("h2o", "l2_norm"):
            u = decode_fidelity(params, cfg, prompts, "uniform", policy=pol,
                                budget_frac=frac)
            s = decode_fidelity(params, cfg, prompts, "squeeze", policy=pol,
                                budget_frac=frac)
            z = decode_fidelity(params, cfg, prompts, "zigzag", policy=pol,
                                budget_frac=frac, n_tiers=3)
            for r in (u, s, z):      # conservation, asserted here too
                p = r["plan"]
                assert p.total + p.slack == p.n_layers * p.b_init, p
            out.append(row(
                f"fig3b_frontier_{pol}_{int(frac*100)}pct", u["wall"] * 1e6,
                f"uniform={u['agreement']:.3f};"
                f"twotier={s['agreement']:.3f};"
                f"zigzag={z['agreement']:.3f};"
                f"twotier_vs_uniform={s['agreement']-u['agreement']:+.3f};"
                f"zigzag_vs_uniform={z['agreement']-u['agreement']:+.3f};"
                f"slots={u['cache_slots']}|{s['cache_slots']}|"
                f"{z['cache_slots']};zigzag_tiers={z['plan'].describe()}"))
    return out


def table2_iso_accuracy(quick=False, policy="sliding_window"):
    """Smallest budget reaching >= 90% agreement with full cache."""
    params, cfg = trained_model()
    prompts = eval_prompts(4)
    out = []
    for mode in ("uniform", "squeeze"):
        best = None
        for frac in (0.2, 0.3, 0.4, 0.5, 0.7, 0.9):
            r = decode_fidelity(params, cfg, prompts, mode, policy=policy,
                                budget_frac=frac)
            if r["agreement"] >= 0.9:
                best = (frac, r)
                break
        frac, r = best if best else (1.0, r)
        out.append(row(f"table2_min_budget_{mode}", r["wall"] * 1e6,
                       f"min_budget_frac={frac};agree={r['agreement']:.3f};"
                       f"slots={r['cache_slots']}"))
    return out


def fig4_memory_per_token(quick=False):
    """Decode-memory model per cached token across three real configs."""
    from repro.configs import get_config
    from repro.core import uniform_plan
    from repro.models.transformer import n_attn_layers
    out = []
    for arch, pol in (("mistral-7b", "sliding_window"),
                      ("llama2-7b", "streaming_llm"),
                      ("gemma2-27b", "h2o")):
        cfg = get_config(arch)
        P = 4096
        full = uniform_plan(n_attn_layers(cfg), P)
        base = uniform_plan(full.n_layers, int(0.4 * P))
        cos = np.concatenate([np.linspace(.2, .5, full.n_layers // 2),
                              np.full(full.n_layers - full.n_layers // 2, .95)])
        sq = allocate(cos, int(0.4 * P), p=0.35)
        b = {k: plan_cache_bytes(p, 1, cfg.n_kv_heads, cfg.hd)
             for k, p in (("full", full), ("seqwise", base), ("squeeze", sq))}
        out.append(row(
            f"fig4_mem_{arch}", 0.0,
            f"full={b['full']/1e6:.1f}MB;seqwise={b['seqwise']/1e6:.1f}MB;"
            f"squeeze={b['squeeze']/1e6:.1f}MB;"
            f"saving_vs_full={(1-b['squeeze']/b['full'])*100:.0f}%"))
    return out


def table3_throughput(quick=False, policy="sliding_window"):
    params, cfg = trained_model()
    out = []
    sizes = (1, 4) if quick else (1, 4, 8, 16)
    for bs in sizes:
        prompts = eval_prompts(bs, 96, cfg.vocab_size)
        f = decode_fidelity(params, cfg, prompts, "full", policy=policy)
        s = decode_fidelity(params, cfg, prompts, "squeeze", policy=policy,
                            budget_frac=0.2)
        out.append(row(
            f"table3_throughput_b{bs}",
            f["decode_seconds"] * 1e6,
            f"full_tok_s={f['tokens_per_s']:.1f};"
            f"squeeze_tok_s={s['tokens_per_s']:.1f};"
            f"speedup={s['tokens_per_s']/max(f['tokens_per_s'],1e-9):.2f}x"))
    return out


def table45_overhead(quick=False):
    """Cosine-sim tracking + KMeans/allocation cost (one-time, prefill)."""
    import jax.numpy as jnp
    params, cfg = trained_model()
    toks = jnp.asarray(eval_prompts(4, 96, cfg.vocab_size))
    f_with = jax.jit(lambda p, t: forward(p, cfg, tokens=t, collect_kv=True))
    f_wo = jax.jit(lambda p, t: forward(p, cfg, tokens=t, collect_kv=False))
    f_with(params, toks).logits.block_until_ready()
    f_wo(params, toks).logits.block_until_ready()

    def best_of(fn, trials=3, reps=3):
        """min-of-trials timing: robust to background contention."""
        if quick:
            trials, reps = 2, 2
        best = float("inf")
        for _ in range(trials):
            t0 = time.perf_counter()
            for _ in range(reps):
                fn(params, toks).logits.block_until_ready()
            best = min(best, (time.perf_counter() - t0) / reps)
        return best

    t_wo = best_of(f_wo)
    t_with = best_of(f_with)

    cos = np.random.RandomState(0).rand(94)
    t0 = time.perf_counter()
    for _ in range(100):
        kmeans_1d(cos)
    t_km = (time.perf_counter() - t0) / 100
    t0 = time.perf_counter()
    for _ in range(100):
        allocate(cos, 4096, p=0.35)
    t_alloc = (time.perf_counter() - t0) / 100
    return [
        row("table4_prefill_overhead", t_with * 1e6,
            f"with={t_with*1e3:.2f}ms;without={t_wo*1e3:.2f}ms;"
            f"overhead_ratio={(t_with-t_wo)/t_wo*100:.1f}%"),
        row("table5_kmeans", t_km * 1e6, f"kmeans_94layers={t_km*1e3:.3f}ms"),
        row("table5_allocate", t_alloc * 1e6,
            f"allocate_94layers={t_alloc*1e3:.3f}ms"),
    ]


def a2_p_sweep(quick=False, policy="sliding_window"):
    params, cfg = trained_model()
    prompts = eval_prompts(4)
    ps = (0.2, 0.5, 0.9) if quick else (0.1, 0.2, 0.35, 0.5, 0.7, 0.9)
    out = []
    for p in ps:
        r = decode_fidelity(params, cfg, prompts, "squeeze", policy=policy,
                            budget_frac=0.3, p=p)
        out.append(row(f"a2_p_{p}", r["wall"] * 1e6,
                       f"agree={r['agreement']:.3f};"
                       f"tiers={r['plan'].describe()}"))
    return out


ALL = [fig2_layer_importance, fig3_accuracy_vs_budget,
       fig3b_allocation_frontier, table2_iso_accuracy,
       fig4_memory_per_token, table3_throughput, table45_overhead, a2_p_sweep]
