"""Shared benchmark substrate: a small trained model + fidelity metrics.

The paper measures ROUGE/F1 on pretrained LLMs; offline we train a small
model on structured synthetic tasks (induction/copy) and measure decode
*fidelity against the full-cache reference* — token agreement and logit KL —
which preserves the paper's comparisons (uniform-budget baseline vs
layer-wise squeeze at equal total budget) without pretrained weights.
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.core import PolicyConfig
from repro.data import DataConfig, batches
from repro.models import ModelConfig, init_params
from repro.serving import Engine, EngineConfig
from repro.training import AdamWConfig, init_opt_state, train_step

CACHE_DIR = os.environ.get("BENCH_MODEL_DIR", "experiments/bench_model")

BENCH_CFG = ModelConfig(
    name="bench-8l", arch_type="dense", n_layers=8, d_model=128,
    n_heads=8, n_kv_heads=4, d_ff=512, vocab_size=256,
    dtype="float32", param_dtype="float32")


def trained_model(steps: int = 200, seq: int = 128, batch: int = 16):
    """Train (or restore) the benchmark model; returns (params, cfg)."""
    cfg = BENCH_CFG
    params = init_params(jax.random.PRNGKey(0), cfg)
    if (s := ckpt.latest_step(CACHE_DIR)) is not None:
        return ckpt.restore(CACHE_DIR, s, params), cfg
    opt = init_opt_state(params)
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=steps)
    dcfg = DataConfig(seq_len=seq, global_batch=batch, vocab_size=cfg.vocab_size)
    step = jax.jit(lambda p, o, b: train_step(p, o, b, cfg, ocfg))
    for i, b in zip(range(steps), batches(dcfg)):
        params, opt, m = step(params, opt, b)
    ckpt.save(CACHE_DIR, steps, params)
    return params, cfg


def eval_prompts(n: int = 8, seq: int = 96, vocab: int = 256, seed: int = 123):
    """Induction-structured prompts (cache eviction visibly matters)."""
    rng = np.random.default_rng(seed)
    toks = rng.integers(2, vocab, size=(n, seq))
    half = seq // 2
    toks[:, half:half * 2] = toks[:, :half]
    return toks.astype(np.int32)


def decode_fidelity(params, cfg, prompts, mode, policy="sliding_window",
                    budget_frac=0.4, p=0.35, n_new=24, **ekw):
    """Returns dict with agreement vs full cache, mean logit KL, tokens/s."""
    ref_eng = Engine(params, cfg, EngineConfig(
        mode="full", max_new_tokens=n_new))
    ref = ref_eng.generate(tokens=prompts)

    eng = Engine(params, cfg, EngineConfig(
        mode=mode, policy=PolicyConfig(policy), budget_frac=budget_frac,
        p=p, max_new_tokens=n_new, bucket=4, min_budget=4, **ekw))
    t0 = time.perf_counter()
    r = eng.generate(tokens=prompts)
    dt = time.perf_counter() - t0
    agree = float((r.tokens == ref.tokens).mean())
    return {
        "agreement": agree,
        "cache_slots": r.cache_slots,
        "ref_slots": ref.cache_slots,
        "tokens_per_s": r.tokens.size / max(r.decode_seconds, 1e-9),
        "plan": r.plan,
        "decode_seconds": r.decode_seconds,
        "wall": dt,
    }


def row(name: str, us_per_call: float, derived) -> dict:
    return {"name": name, "us_per_call": us_per_call, "derived": derived}
