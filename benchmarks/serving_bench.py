"""Serving-loop benchmark: wave vs continuous batching under a Poisson trace.

Beyond the paper's Table 3 (fixed-shape batches): requests arrive with
exponential inter-arrival gaps and *heterogeneous* generation lengths, the
regime where lock-step waves waste decode steps — every wave member pays
``max(max_new)`` steps and pad rows replicate request 0 — while the
continuous engine retires rows on-device and recycles their slots.

Three schedulers are driven over the SAME trace:

  * ``wave``          — lock-step waves (paper Table 3 batching model)
  * ``continuous_step`` — persistent arenas, ``sync_every=1``: one decode
    dispatch per token, the PR-1 host-interaction regime (the "before")
  * ``continuous``    — fused decode blocks (``sync_every=4``): one dispatch
    and one device→host drain per block (the "after")

Reported per scheduler: total wall-clock to drain the trace, mean/p95
request latency (arrival -> completion), emitted tokens/s, and the host
dispatch counters (decode dispatches per decoded token / per decode step).
Both are warmed on the same shapes first so compile time is excluded.

Results are appended to ``BENCH_serving.json`` at the repo root so the perf
trajectory is machine-readable across PRs; the fused run ASSERTS that its
dispatch rate beats the per-step regime.

    PYTHONPATH=src python -m benchmarks.serving_bench [--quick|--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from benchmarks.common import row
from repro.core import PolicyConfig
from repro.models import ModelConfig, init_params
from repro.serving import (ContinuousConfig, ContinuousScheduler,
                           EngineConfig, ImageSegment, MultimodalRequest,
                           SchedulerConfig, TextSegment, WaveScheduler)

TRACE_CFG = ModelConfig(
    name="trace-4l", arch_type="dense", n_layers=4, d_model=128,
    n_heads=8, n_kv_heads=4, d_ff=256, vocab_size=256,
    dtype="float32", param_dtype="float32")

PROMPT_BUCKET = 32
MAX_NEW_CAP = 48
SHORT_NEW, LONG_NEW, P_LONG = 4, MAX_NEW_CAP, 0.25
SYNC_EVERY = 4

BENCH_JSON = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          os.pardir, "BENCH_serving.json")


def _trace(n_req: int, seed: int = 7):
    """(prompt, max_new, arrival_s) triples; Poisson arrivals, one prompt
    bucket, bimodal max_new (chat-style: mostly short replies, a quarter
    long generations).  With wave_size=4, ~68% of waves contain a long
    request, so the whole wave pays ~LONG_NEW steps for a ~15-step mean —
    the quantization continuous batching removes."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(scale=0.01, size=n_req)     # ~100 req/s offered
    arrivals = np.cumsum(gaps)
    out = []
    for i in range(n_req):
        plen = int(rng.integers(PROMPT_BUCKET // 2, PROMPT_BUCKET + 1))
        max_new = LONG_NEW if rng.random() < P_LONG else SHORT_NEW
        out.append((rng.integers(0, TRACE_CFG.vocab_size, (plen,)).astype(
            np.int32), max_new, float(arrivals[i])))
    return out


def _drive(sched, trace, step_fn):
    """Release requests at their arrival times, drain with `step_fn`."""
    t0 = time.perf_counter()
    pending = list(trace)
    done = []
    while pending or sched.queue or _n_inflight(sched):
        now = time.perf_counter() - t0
        while pending and pending[0][2] <= now:
            prompt, max_new, _ = pending.pop(0)
            sched.submit(prompt, max_new)
        if sched.queue or _n_inflight(sched):
            done.extend(step_fn(sched))
        elif pending:
            time.sleep(min(pending[0][2] - now, 1e-3))
    wall = time.perf_counter() - t0
    # latency_s is completion - submit, and submission happens at the
    # simulated arrival instant, so this is arrival -> completion latency
    lats = np.asarray([r.latency_s for r in done])
    toks = sum(r.tokens.size for r in done)
    return wall, lats, toks, done


def _n_inflight(sched):
    return sched.core.n_occupied if hasattr(sched, "core") else 0


def _counters(sched):
    """Host-interaction counter snapshot for either scheduler kind."""
    if hasattr(sched, "core"):
        c = sched.core
        return (c.decode_dispatches, c.decode_steps, c.tokens_emitted,
                c.admit_dispatches, c.admitted, c.prefill_pad_tokens,
                c.prompt_tokens)
    e = sched.engine
    return (e.decode_dispatches, 0, 0, 0, 0, 0, 0)


def _warm(sched, n=6):
    """Warm the compiled shapes: prompt buckets, admit-batch buckets, and —
    via a spread of max_new — the bound-clamped fused block lengths."""
    rng = np.random.default_rng(0)
    news = [1, 2, 3, SYNC_EVERY, MAX_NEW_CAP, MAX_NEW_CAP]
    for i in range(n):
        sched.submit(rng.integers(0, TRACE_CFG.vocab_size,
                                  (PROMPT_BUCKET,)).astype(np.int32),
                     news[i % len(news)])
    sched.run_until_empty()


def _best_of(sched, trace, step_fn, n_req, trials):
    """Repeat the drain (same warmed scheduler, queue empties every trial)
    and keep the fastest — real-time arrival release makes single passes
    noisy on a shared CPU.  Lane utilization and the dispatch counters are
    snapshotted per trial (the scheduler counters accumulate across warm-up
    and trials) and reported for the kept trial."""
    best = None
    for _ in range(trials):
        r0, u0 = sched.row_steps, sched.useful_row_steps
        c0 = _counters(sched)
        wall, lats, toks, done = _drive(sched, trace, step_fn)
        util = (sched.useful_row_steps - u0) / max(sched.row_steps - r0, 1)
        dd, ds, te, ad, na, pp, pt = (b - a
                                      for a, b in zip(c0, _counters(sched)))
        assert len(done) == n_req
        if best is None or wall < best["wall"]:
            best = {"wall": wall, "lats": lats, "toks": toks, "util": util,
                    "decode_dispatches": dd, "decode_steps": ds,
                    "tokens_emitted": te, "admit_dispatches": ad,
                    "admitted": na, "prefill_pad_tokens": pp,
                    "prompt_tokens": pt}
    return best


def _metrics(b):
    """JSON-ready metrics for one kept trial."""
    m = {
        "wall_s": round(b["wall"], 4),
        "tokens": int(b["toks"]),
        "tokens_per_s": round(b["toks"] / max(b["wall"], 1e-9), 1),
        "mean_latency_ms": round(float(b["lats"].mean()) * 1e3, 2),
        "p95_latency_ms": round(float(np.percentile(b["lats"], 95)) * 1e3, 2),
        "lane_util": round(b["util"], 3),
    }
    if b["decode_steps"]:
        m["decode_dispatches"] = int(b["decode_dispatches"])
        m["decode_steps"] = int(b["decode_steps"])
        m["dispatches_per_token"] = round(
            b["decode_dispatches"] / max(b["tokens_emitted"], 1), 4)
        m["dispatches_per_step"] = round(
            b["decode_dispatches"] / max(b["decode_steps"], 1), 4)
        m["admit_dispatches"] = int(b["admit_dispatches"])
        m["admitted"] = int(b["admitted"])
        m["prefill_pad_tokens"] = int(b["prefill_pad_tokens"])
        m["prompt_tokens"] = int(b["prompt_tokens"])
    return m


def _append_json(record, path=BENCH_JSON):
    """Append one run record to the cross-PR perf trajectory file."""
    data = {"runs": []}
    if os.path.exists(path):
        try:
            with open(path) as f:
                data = json.load(f)
        except (json.JSONDecodeError, OSError):
            data = {"runs": []}
    data.setdefault("runs", []).append(record)
    # atomic replace: an interrupted run must not truncate the trajectory
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(data, f, indent=2)
        f.write("\n")
    os.replace(tmp, path)


def _continuous(params, ecfg, sync_every, max_concurrency=4):
    return ContinuousScheduler(params, TRACE_CFG, ecfg, ContinuousConfig(
        max_concurrency=max_concurrency, prompt_bucket=PROMPT_BUCKET,
        max_prompt_len=PROMPT_BUCKET, max_new_cap=MAX_NEW_CAP,
        sync_every=sync_every))


def serving_trace(quick=False, policy="sliding_window", n_req=24,
                  write_json=True):
    rows_, _ = _serving_trace(quick=quick, policy=policy, n_req=n_req,
                              write_json=write_json)
    return rows_


def _serving_trace(quick=False, policy="sliding_window", n_req=24,
                   write_json=True):
    # the trace length stays fixed (smaller samples of the bimodal max_new
    # mix are unrepresentative); quick just takes fewer timing trials
    trials = 2 if quick else 3
    params = init_params(jax.random.PRNGKey(0), TRACE_CFG)
    ecfg = EngineConfig(mode="uniform", policy=PolicyConfig(policy),
                        budget_abs=PROMPT_BUCKET // 2, bucket=4, min_budget=4)
    trace = _trace(n_req)

    wave = WaveScheduler(params, TRACE_CFG, ecfg, SchedulerConfig(
        wave_size=4, prompt_bucket=PROMPT_BUCKET, max_wave_new=MAX_NEW_CAP))
    _warm(wave)
    w = _best_of(wave, trace, lambda s: s.run_wave(), n_req, trials)

    # "before": PR-1 host-interaction regime — one decode dispatch per token
    step = _continuous(params, ecfg, sync_every=1)
    _warm(step)
    s = _best_of(step, trace, lambda x: x.poll(), n_req, trials)

    # "after": fused decode blocks, one dispatch + one drain per block
    cont = _continuous(params, ecfg, sync_every=SYNC_EVERY)
    _warm(cont)
    c = _best_of(cont, trace, lambda x: x.poll(), n_req, trials)

    wm, sm, cm = _metrics(w), _metrics(s), _metrics(c)
    # the tentpole claim, asserted: fused blocks cut host dispatches per
    # decoded token from ~1/step to ~1/sync_every
    assert sm["dispatches_per_step"] == 1.0, sm
    assert cm["dispatches_per_step"] <= 0.5, cm
    assert cm["decode_dispatches"] < sm["decode_dispatches"]

    record = {
        "bench": "serving_trace_poisson",
        "ts": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "backend": jax.default_backend(),
        "policy": policy,
        "n_req": n_req,
        "max_new": {"short": SHORT_NEW, "long": LONG_NEW,
                    "p_long": P_LONG},
        "sync_every": SYNC_EVERY,
        "wave": wm,
        "continuous_per_step": sm,
        "continuous_fused": cm,
        "speedup_fused_vs_wave": round(w["wall"] / max(c["wall"], 1e-9),
                                       3),
        "speedup_fused_vs_per_step": round(
            s["wall"] / max(c["wall"], 1e-9), 3),
    }
    if write_json:
        _append_json(record)

    def _row(name, b, m):
        extra = ""
        if "dispatches_per_step" in m:
            extra = (f";disp_per_tok={m['dispatches_per_token']};"
                     f"disp_per_step={m['dispatches_per_step']};"
                     f"admits={m['admit_dispatches']}/{m['admitted']}")
        return row(name, b["wall"] * 1e6,
                   f"wall_ms={b['wall']*1e3:.1f};"
                   f"mean_lat_ms={m['mean_latency_ms']:.1f};"
                   f"p95_lat_ms={m['p95_latency_ms']:.1f};"
                   f"tok_s={m['tokens_per_s']:.1f};"
                   f"lane_util={m['lane_util']:.2f}" + extra)

    return [
        _row("serving_trace_wave", w, wm),
        _row("serving_trace_continuous_step", s, sm),
        _row("serving_trace_continuous_fused", c, cm),
        row("serving_trace_speedup", 0.0,
            f"fused_vs_wave={w['wall']/max(c['wall'], 1e-9):.2f}x;"
            f"fused_vs_per_step={s['wall']/max(c['wall'], 1e-9):.2f}x;"
            f"lane_util_gain={c['util']/max(w['util'], 1e-9):.2f}x;"
            f"n_req={n_req};max_new={SHORT_NEW}|{LONG_NEW}@p{P_LONG}"),
    ], record


# --------------------------------------------------------------------------- #
# length-sorted admission: bimodal prompt lengths
# --------------------------------------------------------------------------- #

SHORT_PLEN, LONG_PLEN, P_LONG_PROMPT = (16, 32), (97, 128), 0.25


def _bimodal_prompt_trace(n_req: int, seed: int = 11):
    """Poisson arrivals whose PROMPT lengths are bimodal (chat-style: short
    questions, occasional pasted-context prompts).  Arrival gaps are shorter
    than a decode block, so admissions batch into bursts — exactly where
    pad-to-longest admission pays `LONG_PLEN` prefill FLOPs for every short
    prompt that shares a burst with one long one."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(scale=0.004, size=n_req))
    out = []
    for i in range(n_req):
        lo, hi = LONG_PLEN if rng.random() < P_LONG_PROMPT else SHORT_PLEN
        plen = int(rng.integers(lo, hi + 1))
        max_new = int(rng.integers(3, 7))
        out.append((rng.integers(0, TRACE_CFG.vocab_size, (plen,)).astype(
            np.int32), max_new, float(arrivals[i])))
    return out


ADMISSION_LAYOUTS = {
    # pad-to-longest (PR-2 baseline), length-sorted buckets (PR-3), packed
    # block-diagonal rows (PR-4) — same engine, three admission layouts
    "padded": dict(length_sorted=False),
    "sorted": dict(length_sorted=True),
    "packed": dict(packed_prefill=True),
}


def _continuous_admission(params, ecfg, layout):
    return ContinuousScheduler(params, TRACE_CFG, ecfg, ContinuousConfig(
        max_concurrency=8, prompt_bucket=PROMPT_BUCKET,
        max_prompt_len=LONG_PLEN[1], max_new_cap=8, sync_every=SYNC_EVERY,
        **ADMISSION_LAYOUTS[layout]))


def _warm_bimodal(sched, n=8):
    rng = np.random.default_rng(1)
    for i in range(n):
        lo, hi = LONG_PLEN if i % 4 == 0 else SHORT_PLEN
        sched.submit(rng.integers(0, TRACE_CFG.vocab_size,
                                  (int(rng.integers(lo, hi + 1)),)).astype(
                                      np.int32), 3)
    sched.run_until_empty()


PACKED_SURPLUS_MAX = 0.25     # packed pure-padding budget vs naive, asserted


def admission_trace(quick=False, n_req=24, write_json=True):
    """Pad-to-longest vs length-sorted vs PACKED admission over the SAME
    bimodal Poisson trace.

    Asserted claims (the PR-3 and PR-4 satellite/tentpole wins):
      * sorted prefills strictly fewer padded tokens than padded;
      * packed prefills strictly fewer than sorted (it also removes the
        pow-2 admit-batch row padding and the per-bucket dispatches);
      * packed's PURE padding (prefilled - prompt tokens) is <= 25% of the
        naive pad-to-longest baseline's.  Total prefilled tokens cannot
        drop below the prompt content itself, so the surplus is the metric
        that can and must approach zero.
    """
    trials = 2 if quick else 3
    params = init_params(jax.random.PRNGKey(0), TRACE_CFG)
    ecfg = EngineConfig(mode="uniform",
                        policy=PolicyConfig("sliding_window"),
                        budget_abs=PROMPT_BUCKET // 2, bucket=4, min_budget=4)
    trace = _bimodal_prompt_trace(n_req)

    results, ms = {}, {}
    for name in ADMISSION_LAYOUTS:
        sched = _continuous_admission(params, ecfg, name)
        _warm_bimodal(sched)
        results[name] = _best_of(sched, trace, lambda x: x.poll(), n_req,
                                 trials)
        ms[name] = _metrics(results[name])
    pm, sm, km = ms["padded"], ms["sorted"], ms["packed"]
    # the claims, asserted (see docstring)
    assert sm["prefill_pad_tokens"] < pm["prefill_pad_tokens"], (sm, pm)
    assert km["prefill_pad_tokens"] < sm["prefill_pad_tokens"], (km, sm)
    assert sm["prompt_tokens"] == pm["prompt_tokens"] == km["prompt_tokens"]
    surplus = {n: m["prefill_pad_tokens"] - m["prompt_tokens"]
               for n, m in ms.items()}
    assert surplus["packed"] <= PACKED_SURPLUS_MAX * surplus["padded"], surplus

    record = {
        "bench": "admission_layouts",
        "ts": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "backend": jax.default_backend(),
        "n_req": n_req,
        "prompt_len": {"short": list(SHORT_PLEN), "long": list(LONG_PLEN),
                       "p_long": P_LONG_PROMPT},
        "padded": pm,
        "sorted": sm,
        "packed": km,
        # prefilled-token ratios vs the naive pad-to-longest baseline
        "pad_token_ratio": round(
            sm["prefill_pad_tokens"] / max(pm["prefill_pad_tokens"], 1), 3),
        "packed_token_ratio": round(
            km["prefill_pad_tokens"] / max(pm["prefill_pad_tokens"], 1), 3),
        # pure-padding (surplus) ratios vs the same baseline — the number
        # packing drives toward zero
        "sorted_pad_surplus_ratio": round(
            surplus["sorted"] / max(surplus["padded"], 1), 3),
        "packed_pad_surplus_ratio": round(
            surplus["packed"] / max(surplus["padded"], 1), 3),
    }
    if write_json:
        _append_json(record)

    def _arow(name, b, m):
        return row(f"admission_{name}", b["wall"] * 1e6,
                   f"wall_ms={b['wall']*1e3:.1f};"
                   f"prefill_pad_tokens={m['prefill_pad_tokens']};"
                   f"prompt_tokens={m['prompt_tokens']};"
                   f"admit_dispatches={m['admit_dispatches']};"
                   f"mean_lat_ms={m['mean_latency_ms']:.1f}")

    return [
        _arow(n, results[n], ms[n]) for n in ADMISSION_LAYOUTS
    ] + [
        row("admission_pad_savings", 0.0,
            f"pad_tokens={pm['prefill_pad_tokens']}->"
            f"{sm['prefill_pad_tokens']}(sorted)->"
            f"{km['prefill_pad_tokens']}(packed,"
            f"{record['packed_token_ratio']:.2f}x);"
            f"surplus={surplus['padded']}->{surplus['sorted']}->"
            f"{surplus['packed']}"
            f"({record['packed_pad_surplus_ratio']:.2f}x);"
            f"n_req={n_req};plen={SHORT_PLEN}|{LONG_PLEN}"
            f"@p{P_LONG_PROMPT}"),
    ]


# --------------------------------------------------------------------------- #
# multimodal admission: mixed text/vlm bursts through the embeds intake
# --------------------------------------------------------------------------- #

VLM_TRACE_CFG = ModelConfig(
    name="trace-vlm-4l", arch_type="vlm", n_layers=4, d_model=128,
    n_heads=8, n_kv_heads=4, d_ff=256, vocab_size=256,
    mrope_sections=(4, 2, 2), frontend="vision_stub", frontend_tokens=16,
    dtype="float32", param_dtype="float32")

MM_TEXT_LENS = (8, 16, 24)          # bucket-friendly text runs
MM_SHORT_PATCH, MM_LONG_PATCH = 8, 48
P_IMAGE, P_LONG_IMAGE = 0.5, 0.25
MM_BUCKET, MM_MAX_PROMPT = 16, 96


def _mm_trace(n_req: int, seed: int = 13):
    """Mixed text/vlm burst list: half the requests carry an image patch
    grid (bimodal size — occasional large images) ahead of their text, the
    rest are pure text.  The heterogeneous [frontend | text] lengths are
    exactly the traffic where padded admission pays the large image's
    prefill FLOPs for every short neighbour."""
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n_req):
        nt = int(rng.choice(MM_TEXT_LENS))
        text = TextSegment(rng.integers(
            0, VLM_TRACE_CFG.vocab_size, (nt,)).astype(np.int32))
        max_new = int(rng.integers(3, 7))
        if rng.random() < P_IMAGE:
            n_p = MM_LONG_PATCH if rng.random() < P_LONG_IMAGE \
                else MM_SHORT_PATCH
            segs = (ImageSegment(n_p), text)
        else:
            segs = (text,)
        out.append(MultimodalRequest(segs, max_new=max_new, seed=1000 + i))
    return out


def _mm_sched(params, ecfg, layout):
    return ContinuousScheduler(params, VLM_TRACE_CFG, ecfg, ContinuousConfig(
        max_concurrency=8, prompt_bucket=MM_BUCKET,
        max_prompt_len=MM_MAX_PROMPT, max_new_cap=8, sync_every=SYNC_EVERY,
        **ADMISSION_LAYOUTS[layout]))


def multimodal_trace(quick=False, n_req=24, write_json=True):
    """Mixed text/vlm bursts through the three admission layouts — the
    embeds-native intake end to end (DESIGN.md §5).

    Deterministic (counter-based): every request decodes the same tokens
    under every layout, so the asserted quantities are pure layout
    accounting:
      * sorted prefills strictly fewer padded tokens than padded, packed
        strictly fewer than sorted (the mixed burst is partitioned by
        modality, so packed pays at most one pack-row surplus per
        modality per poll);
      * packed's PURE padding surplus is <= 25% of the naive baseline's
        (same bound the token-only admission trace gates);
      * the packed unpack stays COPY-FREE: `admit_kv_copy_elems == 0`
        proves the direct packed->arena scatter never staged a
        request-shaped KV intermediate;
      * frontend encoding amortizes: fewer intake dispatches than encoded
        segments (bucketed batch encoding).
    """
    del quick     # one deterministic pass; nothing timing-sensitive here
    params = init_params(jax.random.PRNGKey(0), VLM_TRACE_CFG)
    ecfg = EngineConfig(mode="uniform",
                        policy=PolicyConfig("sliding_window"),
                        budget_abs=MM_BUCKET, bucket=4, min_budget=4)
    trace = _mm_trace(n_req)

    ms, outs = {}, {}
    for name in ADMISSION_LAYOUTS:
        sched = _mm_sched(params, ecfg, name)
        t0 = time.perf_counter()
        rids = [sched.submit_multimodal(r) for r in trace]
        done = {r.rid: r for r in sched.run_until_empty()}
        wall = time.perf_counter() - t0
        assert len(done) == n_req
        outs[name] = [done[rid].tokens.tolist() for rid in rids]
        core, enc = sched.core, sched.intake
        ms[name] = {
            "wall_s": round(wall, 4),
            "prefill_pad_tokens": core.prefill_pad_tokens,
            "prompt_tokens": core.prompt_tokens,
            "admit_dispatches": core.admit_dispatches,
            "admitted": core.admitted,
            "admit_kv_copy_elems": core.admit_kv_copy_elems,
            "encode_dispatches": enc.encode_dispatches,
            "encoded_segments": enc.encoded_segments,
            "frontend_tokens_encoded": enc.frontend_tokens_encoded,
        }
    # identical tokens under every layout: the intake's keyed encoding and
    # the layouts' identity scope make admission a pure scheduling choice
    assert outs["padded"] == outs["sorted"] == outs["packed"]
    pm, sm, km = ms["padded"], ms["sorted"], ms["packed"]
    assert sm["prefill_pad_tokens"] < pm["prefill_pad_tokens"], (sm, pm)
    assert km["prefill_pad_tokens"] < sm["prefill_pad_tokens"], (km, sm)
    assert sm["prompt_tokens"] == pm["prompt_tokens"] == km["prompt_tokens"]
    surplus = {n: m["prefill_pad_tokens"] - m["prompt_tokens"]
               for n, m in ms.items()}
    assert surplus["packed"] <= PACKED_SURPLUS_MAX * surplus["padded"], \
        surplus
    assert km["admit_kv_copy_elems"] == 0, km     # direct scatter, no copy
    for m in ms.values():                         # bucketed encoding pays off
        # strict amortization needs enough traffic for buckets to repeat;
        # the tiny smoke trace only proves dispatches never exceed segments
        if n_req >= 12:
            assert m["encode_dispatches"] < m["encoded_segments"], m
        assert m["encode_dispatches"] <= m["encoded_segments"], m

    record = {
        "bench": "admission_multimodal",
        "ts": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "backend": jax.default_backend(),
        "n_req": n_req,
        "text_lens": list(MM_TEXT_LENS),
        "patches": {"short": MM_SHORT_PATCH, "long": MM_LONG_PATCH,
                    "p_image": P_IMAGE, "p_long": P_LONG_IMAGE},
        "padded": pm, "sorted": sm, "packed": km,
        "packed_token_ratio": round(
            km["prefill_pad_tokens"] / max(pm["prefill_pad_tokens"], 1), 3),
        "packed_pad_surplus_ratio": round(
            surplus["packed"] / max(surplus["padded"], 1), 3),
    }
    if write_json:
        _append_json(record)

    return [
        row(f"admission_mm_{n}", ms[n]["wall_s"] * 1e6,
            f"prefill_pad_tokens={ms[n]['prefill_pad_tokens']};"
            f"prompt_tokens={ms[n]['prompt_tokens']};"
            f"encode_dispatches={ms[n]['encode_dispatches']}/"
            f"{ms[n]['encoded_segments']}seg;"
            f"kv_copy_elems={ms[n]['admit_kv_copy_elems']}")
        for n in ADMISSION_LAYOUTS
    ] + [
        row("admission_mm_savings", 0.0,
            f"pad_tokens={pm['prefill_pad_tokens']}->"
            f"{sm['prefill_pad_tokens']}(sorted)->"
            f"{km['prefill_pad_tokens']}(packed,"
            f"{record['packed_token_ratio']:.2f}x);"
            f"surplus={surplus['padded']}->{surplus['sorted']}->"
            f"{surplus['packed']}"
            f"({record['packed_pad_surplus_ratio']:.2f}x);"
            f"n_req={n_req};p_image={P_IMAGE}"),
    ]


# --------------------------------------------------------------------------- #
# prefix reuse: shared system prompts through the radix tree + paged pool
# --------------------------------------------------------------------------- #

PR_PAGE = 16                  # page size; SYS_LEN must NOT need to divide it
SYS_LEN, SYS_K = 64, 4        # shared system prompts: length, distinct count
PR_TAIL = (4, 16)             # unique user tail per request
PR_SHORT_NEW, PR_LONG_NEW, PR_P_LONG = 3, 8, 0.25
PREFIX_REF_MIN = 0.70         # gated: >=70% of shared tokens by reference


def _prefix_trace(n_req: int, n_sys: int, seed: int = 17):
    """Requests over `n_sys` shared system prompts: each is one system
    prompt plus a unique user tail, with bimodal decode lengths — the
    serving regime where the prompt KV of the shared prefix should be paid
    for once (DESIGN.md §5)."""
    rng = np.random.default_rng(seed)
    V = TRACE_CFG.vocab_size
    sys_prompts = [rng.integers(0, V, (SYS_LEN,)).astype(np.int32)
                   for _ in range(n_sys)]
    reqs = []
    for _ in range(n_req):
        s = sys_prompts[int(rng.integers(n_sys))]
        tail = rng.integers(0, V, (int(rng.integers(*PR_TAIL)),)).astype(
            np.int32)
        max_new = PR_LONG_NEW if rng.random() < PR_P_LONG else PR_SHORT_NEW
        reqs.append((np.concatenate([s, tail]), max_new))
    return sys_prompts, reqs


def _prefix_engine(params, ecfg, prefix: bool):
    from repro.serving import ContinuousEngine
    return ContinuousEngine(params, TRACE_CFG, ecfg, ContinuousConfig(
        max_concurrency=4, prompt_bucket=PROMPT_BUCKET,
        max_prompt_len=SYS_LEN + PROMPT_BUCKET, max_new_cap=PR_LONG_NEW,
        sync_every=SYNC_EVERY, page_size=PR_PAGE, prefix_cache=prefix))


def _prefix_drain(core):
    done = {}
    while core._occupied:
        core.decode_block()
        for c in core.pop_completed():
            done[c.slot] = c.tokens.tolist()
    return done


def _prefix_run(core, reqs, burst=4):
    """Admit in bursts, drain each; returns (wall_s, tokens-per-request)."""
    outs = []
    t0 = time.perf_counter()
    for i in range(0, len(reqs), burst):
        slots = core.admit_many(reqs[i:i + burst])
        done = _prefix_drain(core)
        outs.extend(done[s] for s in slots)
    return time.perf_counter() - t0, outs


def prefix_reuse_trace(quick=False, n_req=32, n_sys=SYS_K, write_json=True):
    """Shared-system-prompt trace through the paged pool, WITH and WITHOUT
    the radix-tree prefix cache (both engines paged — the no-reuse run
    isolates exactly the reuse win, not the paging change).

    Drive: a seed burst (one request per system prompt) cold-misses and
    populates the tree, one warm-up hit burst compiles the ctx-prefill
    executables, then the measured trace admits entirely by prefix hit.

    Asserted claims:
      * >= PREFIX_REF_MIN of all shared-prefix prompt tokens over the whole
        run (cold seeds included) were admitted by PAGE REFERENCE instead
        of prefill compute — the tentpole acceptance bar;
      * the measured phase referenced every one of its shared tokens and
        dispatched strictly fewer prefill tokens than the no-reuse run;
      * both engines emit token-identical streams per request (greedy) —
        reuse is a scheduling/storage change, not a model change;
      * page-pool accounting closes: every row page returns at retirement,
        so end-state residency is exactly the tree's resident pages.
    """
    del quick                 # deterministic counters; one pass either way
    params = init_params(jax.random.PRNGKey(0), TRACE_CFG)
    ecfg = EngineConfig(mode="uniform",
                        policy=PolicyConfig("sliding_window"),
                        budget_abs=PROMPT_BUCKET // 2, bucket=4, min_budget=4)
    sys_prompts, reqs = _prefix_trace(n_req, n_sys)
    rng = np.random.default_rng(23)
    V = TRACE_CFG.vocab_size
    seeds = [(np.concatenate([s, rng.integers(0, V, (5,)).astype(np.int32)]),
              PR_SHORT_NEW) for s in sys_prompts]
    warm = [(np.concatenate([sys_prompts[i % n_sys],
                             rng.integers(0, V, (9,)).astype(np.int32)]),
             PR_SHORT_NEW) for i in range(4)]

    ms, outs, occ = {}, {}, {}
    for name, use_prefix in (("reuse", True), ("no_reuse", False)):
        core = _prefix_engine(params, ecfg, use_prefix)
        _prefix_run(core, seeds)      # cold: populate tree, compile miss path
        _prefix_run(core, warm)       # compile the ctx-prefill hit path
        occ[name + "_peak"] = core.pool_occupancy
        c0 = (core.prompt_tokens, core.prefill_pad_tokens,
              core.prompt_tokens_referenced, core.prefix_hits)
        wall, toks = _prefix_run(core, reqs)
        d_prompt, d_pad, d_ref, d_hits = (
            b - a for a, b in zip(c0, (core.prompt_tokens,
                                       core.prefill_pad_tokens,
                                       core.prompt_tokens_referenced,
                                       core.prefix_hits)))
        outs[name] = toks
        shared_total = (n_req + len(seeds) + len(warm)) * SYS_LEN
        ms[name] = {
            "wall_s": round(wall, 4),
            "prompt_tokens": int(d_prompt),
            "prefill_pad_tokens": int(d_pad),
            "prompt_tokens_referenced": int(d_ref),
            "prefix_hits": int(d_hits),
            "referenced_frac_total": round(
                core.prompt_tokens_referenced / shared_total, 3),
            "pool_pages": core.pool_pages,
            "pool_occupancy_end": round(core.pool_occupancy, 3),
            "prefix_nodes": core._prefix.n_nodes if use_prefix else 0,
            "prefix_evictions": core._prefix.evictions if use_prefix else 0,
        }
        # accounting closes: all rows retired, so residency == tree pages
        resident = core.pool_pages_resident
        tree = core._prefix.resident_pages if use_prefix else 0
        assert resident == tree, (resident, tree)

    rm, nm = ms["reuse"], ms["no_reuse"]
    assert outs["reuse"] == outs["no_reuse"]       # scheduling, not model
    assert rm["prompt_tokens_referenced"] == n_req * SYS_LEN, rm
    assert rm["prefill_pad_tokens"] < nm["prefill_pad_tokens"], (rm, nm)
    assert rm["referenced_frac_total"] >= PREFIX_REF_MIN, rm
    assert nm["prompt_tokens_referenced"] == 0, nm

    record = {
        "bench": "prefix_reuse",
        "ts": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "backend": jax.default_backend(),
        "n_req": n_req,
        "n_sys_prompts": n_sys,
        "sys_len": SYS_LEN,
        "page_size": PR_PAGE,
        "max_new": {"short": PR_SHORT_NEW, "long": PR_LONG_NEW,
                    "p_long": PR_P_LONG},
        "reuse": rm,
        "no_reuse": nm,
        "prefill_token_ratio": round(
            rm["prefill_pad_tokens"] / max(nm["prefill_pad_tokens"], 1), 3),
        "speedup_reuse_vs_no_reuse": round(
            nm["wall_s"] / max(rm["wall_s"], 1e-9), 3),
    }
    if write_json:
        _append_json(record)

    return [
        row(f"prefix_{n}", ms[n]["wall_s"] * 1e6,
            f"wall_ms={ms[n]['wall_s']*1e3:.1f};"
            f"prefill_pad_tokens={ms[n]['prefill_pad_tokens']};"
            f"referenced={ms[n]['prompt_tokens_referenced']};"
            f"hits={ms[n]['prefix_hits']};"
            f"pool_occ={ms[n]['pool_occupancy_end']:.2f}")
        for n in ms
    ] + [
        row("prefix_reuse_savings", 0.0,
            f"referenced_frac={rm['referenced_frac_total']:.2f}"
            f"(gate>={PREFIX_REF_MIN});"
            f"prefill_tokens={nm['prefill_pad_tokens']}->"
            f"{rm['prefill_pad_tokens']}"
            f"({record['prefill_token_ratio']:.2f}x);"
            f"wall_ratio={record['speedup_reuse_vs_no_reuse']:.2f}x;"
            f"n_req={n_req};K={n_sys};sys_len={SYS_LEN}"),
    ]


# --------------------------------------------------------------------------- #
# pool pressure: overcommitted paged serving under the degradation ladder
# --------------------------------------------------------------------------- #

PP_PAGE, PP_BUCKET, PP_MAX_PROMPT, PP_MAX_NEW = 4, 8, 16, 4
PP_BUDGET = 16                     # >= plen + max_new: preempt-resume exact
PP_CONC = 8
PP_OVERCOMMIT = 0.5                # pool = half the worst-case row region
PP_WM_LOW, PP_WM_HIGH = 0.05, 0.25
PP_PREEMPT_AFTER = 2
RESIDENT_GAIN_MIN = 1.3            # gated: peak rows vs worst-case sizing


def _pressure_trace(n_req: int, seed: int = 29):
    """Short-window requests (plen 3..5, max_new 3..4): every row's live
    slots span ~half its worst-case page quota, which is exactly the slack
    overcommitted sizing converts into extra resident rows.  Lengths stay
    under PP_BUDGET so a preempted request's re-prefill window never
    overflows the cache budget — the scope where preempt-resume is
    token-exact (DESIGN.md §5)."""
    rng = np.random.default_rng(seed)
    return [(rng.integers(0, TRACE_CFG.vocab_size,
                          (int(rng.integers(3, 6)),)).astype(np.int32),
             int(rng.integers(3, 5))) for _ in range(n_req)]


def _pressure_sched(params, ecfg, overcommit, injector=None):
    from repro.core.paging import PoolFaultInjector   # noqa: F401 (doc aid)
    pressured = overcommit != 1.0
    return ContinuousScheduler(params, TRACE_CFG, ecfg, ContinuousConfig(
        max_concurrency=PP_CONC, prompt_bucket=PP_BUCKET,
        max_prompt_len=PP_MAX_PROMPT, max_new_cap=PP_MAX_NEW,
        sync_every=1,     # one decode step per poll: pressure persists
        page_size=PP_PAGE, overcommit=overcommit,
        watermark_low=PP_WM_LOW if pressured else 0.0,
        watermark_high=PP_WM_HIGH if pressured else 0.0,
        preempt_after=PP_PREEMPT_AFTER, audit_pool=pressured),
        injector=injector)


def _pressure_run(sched, trace):
    """Submit everything up front (constant pressure), poll until drained;
    returns (wall_s, tokens per request in submit order)."""
    t0 = time.perf_counter()
    rids = [sched.submit(p, max_new=mn) for p, mn in trace]
    done = []
    polls = 0
    while sched.queue or sched.core.n_occupied:
        done.extend(sched.poll())
        polls += 1
        assert polls < 100 * len(trace), "pressure trace failed to drain"
    wall = time.perf_counter() - t0
    d = {r.rid: r for r in done}
    assert len(d) == len(trace), (len(d), len(trace))
    return wall, [d[r].tokens.tolist() for r in rids]


def pool_pressure_trace(quick=False, n_req=20, write_json=True):
    """Overcommitted paged serving through the degradation ladder, vs the
    SAME trace on a worst-case-sized pool (ISSUE-7 tentpole).

    The overcommitted engine runs with half the worst-case row region,
    watermark backpressure, preemption after `PP_PREEMPT_AFTER` held polls,
    a scripted `PoolFaultInjector` (page steals + forced allocation
    failures mid-trace), and the full pool-accounting audit after EVERY
    poll.

    Asserted claims (the acceptance gates):
      * every request completes and is TOKEN-IDENTICAL to the uninterrupted
        worst-case-sized run — backpressure, preemption and fault injection
        are scheduling events, never model events;
      * the ladder actually fired: >=1 preemption (with its requeue), >=1
        stalled poll, >=1 watermark hit;
      * peak resident rows >= RESIDENT_GAIN_MIN x what worst-case sizing
        supports in the same pool — the capacity win overcommit buys;
      * the pool books balance after the drain (free list + refcounts +
        row/injector residency tile the pool; deep page-table check).
    """
    from repro.core.paging import PoolFaultInjector
    del quick                 # deterministic counters; one pass either way
    params = init_params(jax.random.PRNGKey(0), TRACE_CFG)
    ecfg = EngineConfig(mode="uniform",
                        policy=PolicyConfig("sliding_window"),
                        budget_abs=PP_BUDGET, bucket=4, min_budget=4)
    trace = _pressure_trace(n_req)

    base = _pressure_sched(params, ecfg, overcommit=1.0)
    wall_b, ref = _pressure_run(base, trace)

    inj = PoolFaultInjector({3: [("steal", 24), ("fail_alloc", 3)],
                             8: [("release", -1)]})
    over = _pressure_sched(params, ecfg, overcommit=PP_OVERCOMMIT,
                           injector=inj)
    wall_o, out = _pressure_run(over, trace)
    core = over.core
    inj.release_all(core._pool)
    core.audit_pool(deep=True)        # books balance after the drain

    # worst-case sizing supports floor(pool / quota) rows; the baseline
    # pool IS PP_CONC quotas, so quota falls out of its own sizing
    quota = base.core.pool_pages // PP_CONC
    worst_rows = core.pool_pages // quota
    gain = core.peak_resident_rows / max(worst_rows, 1)
    assert out == ref, "token divergence under pool pressure"
    assert core.preemptions >= 1 and core.requeues >= 1, \
        (core.preemptions, core.requeues)
    assert core.stall_polls >= 1 and core.watermark_hits >= 1, \
        (core.stall_polls, core.watermark_hits)
    assert gain >= RESIDENT_GAIN_MIN, \
        (core.peak_resident_rows, worst_rows, gain)

    bm = {"wall_s": round(wall_b, 4), "pool_pages": base.core.pool_pages,
          "peak_resident_rows": base.core.peak_resident_rows}
    om = {"wall_s": round(wall_o, 4), "pool_pages": core.pool_pages,
          "peak_resident_rows": core.peak_resident_rows,
          "preemptions": core.preemptions, "requeues": core.requeues,
          "stall_polls": core.stall_polls,
          "watermark_hits": core.watermark_hits}
    record = {
        "bench": "pool_pressure",
        "ts": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "backend": jax.default_backend(),
        "n_req": n_req,
        "page_size": PP_PAGE,
        "overcommit": PP_OVERCOMMIT,
        "watermarks": [PP_WM_LOW, PP_WM_HIGH],
        "preempt_after": PP_PREEMPT_AFTER,
        "worst_case": bm,
        "overcommitted": om,
        "worst_case_rows": worst_rows,
        "resident_gain": round(gain, 3),
        "token_identical": True,
    }
    if write_json:
        _append_json(record)

    return [
        row("pool_pressure_worst_case", bm["wall_s"] * 1e6,
            f"pool_pages={bm['pool_pages']};"
            f"peak_rows={bm['peak_resident_rows']}"),
        row("pool_pressure_overcommit", om["wall_s"] * 1e6,
            f"pool_pages={om['pool_pages']};"
            f"peak_rows={om['peak_resident_rows']};"
            f"preempt={om['preemptions']};requeues={om['requeues']};"
            f"stalls={om['stall_polls']};wm_hits={om['watermark_hits']}"),
        row("pool_pressure_gain", 0.0,
            f"resident_gain={gain:.2f}x(gate>={RESIDENT_GAIN_MIN});"
            f"worst_case_rows={worst_rows};"
            f"overcommit={PP_OVERCOMMIT};tokens_identical=True;"
            f"n_req={n_req}"),
    ]


# --------------------------------------------------------------------------- #
# latency_trace: long-prompt admissions mixed into steady decode
# --------------------------------------------------------------------------- #

LT_SHORT_MAX_NEW = 32     # steady decode traffic: fixed short generations
LT_CONC = 4
LT_P95_TARGET = 1.3       # acceptance bar: chunked p95 within 1.3x baseline
# full-run shape (quick/smoke shrinks it): "8k-class" long prompts scaled
# to the reduced CPU trace config — 32x the short-prompt bucket, split into
# bucket-multiple chunks.  Enough steady decode blocks per long that the
# chunk-carrying polls stay a <5% minority: p95 then measures the steady
# state, max measures the (bounded) chunk cost.
LT_FULL = dict(n_short=96, n_long=2, long_plen=1024, chunk_len=256,
               inject_every=60)
LT_QUICK = dict(n_short=64, n_long=1, long_plen=512, chunk_len=128,
                inject_every=40)


def _lt_trace(n_short, n_long, long_plen, seed=37):
    """Steady short-request decode traffic plus a few very long prompts.
    Shorts land in one prompt bucket with a FIXED token budget (the steady
    state whose per-block latency we protect); longs are exactly
    `long_plen` tokens — the admission spike generator."""
    rng = np.random.default_rng(seed)
    shorts = [(rng.integers(0, TRACE_CFG.vocab_size,
                            (int(rng.integers(PROMPT_BUCKET // 2,
                                              PROMPT_BUCKET + 1)),)).astype(
        np.int32), LT_SHORT_MAX_NEW) for _ in range(n_short)]
    longs = [(rng.integers(0, TRACE_CFG.vocab_size,
                           (long_plen,)).astype(np.int32), LT_SHORT_MAX_NEW)
             for _ in range(n_long)]
    return shorts, longs


def _lt_sched(params, ecfg, long_plen, chunk_len, chunked):
    return ContinuousScheduler(params, TRACE_CFG, ecfg, ContinuousConfig(
        max_concurrency=LT_CONC, prompt_bucket=PROMPT_BUCKET,
        max_prompt_len=long_plen, max_new_cap=LT_SHORT_MAX_NEW,
        sync_every=SYNC_EVERY,
        chunked_prefill=chunked, chunk_len=chunk_len))


def _lt_warm(sched, long_plen, with_long):
    """Compile every shape the timed run will hit: short buckets, the
    spread of bound-clamped block lengths, and — when the variant admits
    longs — the long-prompt path itself (monolithic (1, long_plen) prefill
    or the per-chunk mid/final executables)."""
    rng = np.random.default_rng(5)
    news = [1, 3, SYNC_EVERY, LT_SHORT_MAX_NEW]
    for i in range(8):
        sched.submit(rng.integers(0, TRACE_CFG.vocab_size,
                                  (PROMPT_BUCKET,)).astype(np.int32),
                     news[i % len(news)])
    if with_long:
        sched.submit(rng.integers(0, TRACE_CFG.vocab_size,
                                  (long_plen,)).astype(np.int32),
                     LT_SHORT_MAX_NEW)
    sched.run_until_empty()


def _lt_run(sched, shorts, longs, inject_every):
    """Submit the steady traffic up front, inject one long prompt every
    `inject_every` polls, and time each poll wall-to-wall.  Returns
    (per-poll seconds, rid -> tokens)."""
    for p, mn in shorts:
        sched.submit(p, mn)
    queue_longs = list(longs)
    per_poll, done, polls = [], [], 0
    while (sched.queue or sched.core.n_occupied or sched.core.n_pending
           or queue_longs):
        if queue_longs and polls and polls % inject_every == 0:
            p, mn = queue_longs.pop(0)
            sched.submit(p, mn)
        t0 = time.perf_counter()
        done.extend(sched.poll())
        per_poll.append(time.perf_counter() - t0)
        polls += 1
        assert polls < 10000, "latency trace failed to drain"
    return np.asarray(per_poll), {r.rid: r.tokens for r in done}


def _lt_stats(per_poll):
    return {"polls": int(per_poll.size),
            "p50_block_ms": round(float(np.percentile(per_poll, 50)) * 1e3, 3),
            "p95_block_ms": round(float(np.percentile(per_poll, 95)) * 1e3, 3),
            "max_block_ms": round(float(per_poll.max()) * 1e3, 3)}


class _SLOProbe:
    """Per-request token-visibility timestamps via the scheduler's
    emission tap (`emit_hook`): the same source the async service's
    `SLORecord`s use, so the bench reports client-visible TTFT / ITL.
    ITL is block-granular — tokens of one fused block share a drain
    timestamp, so p50 measures intra-block gaps (~0) and p95 the
    block-to-block cadence."""

    def __init__(self, sched):
        self._first: dict = {}
        self._times: dict = {}
        sched.emit_hook = self._on_emit

    def _on_emit(self, req, tok, t):
        self._first.setdefault(req.rid, t - req.submitted_at)
        self._times.setdefault(req.rid, []).append(t)

    def stats(self):
        ttft = np.asarray(list(self._first.values()))
        gaps = [np.diff(ts) for ts in self._times.values() if len(ts) > 1]
        itl = np.concatenate(gaps) if gaps else np.zeros(1)
        return {
            "ttft_p50_ms": round(float(np.percentile(ttft, 50)) * 1e3, 3),
            "ttft_p95_ms": round(float(np.percentile(ttft, 95)) * 1e3, 3),
            "itl_p50_ms": round(float(np.percentile(itl, 50)) * 1e3, 3),
            "itl_p95_ms": round(float(np.percentile(itl, 95)) * 1e3, 3),
        }


def latency_trace(quick=False, write_json=True):
    rows_, _ = _latency_trace(quick=quick, write_json=write_json)
    return rows_


def _latency_trace(quick=False, write_json=True):
    """Per-block decode latency under long-prompt admission pressure
    (ISSUE-8 tentpole): the SAME short-request decode traffic runs three
    ways — no longs at all (baseline), longs admitted monolithically (one
    prefill dispatch stalls every resident row), and longs streamed
    through `chunked_prefill` (one chunk rides each fused decode block).

    Asserted claims:
      * chunked vs monolithic outputs are token-identical per request —
        chunking is a scheduling change, never a model change;
      * (full run) chunked p95 per-block latency stays within
        ``LT_P95_TARGET`` (1.3x) of the no-admission baseline, while the
        monolithic spike (max block / baseline p95) records multi-x.
    """
    shape = LT_QUICK if quick else LT_FULL
    n_short, n_long = shape["n_short"], shape["n_long"]
    long_plen, chunk_len = shape["long_plen"], shape["chunk_len"]
    params = init_params(jax.random.PRNGKey(0), TRACE_CFG)
    ecfg = EngineConfig(mode="uniform",
                        policy=PolicyConfig("sliding_window"),
                        budget_abs=PROMPT_BUCKET, bucket=4, min_budget=4)
    shorts, longs = _lt_trace(n_short, n_long, long_plen)

    variants = {}
    outs = {}
    for name, chunked, use_longs in [("baseline", False, False),
                                     ("monolithic", False, True),
                                     ("chunked", True, True)]:
        sched = _lt_sched(params, ecfg, long_plen, chunk_len, chunked)
        _lt_warm(sched, long_plen, with_long=use_longs)
        best = None
        for _ in range(2):        # best-of-2: p95 is noisy on a shared CPU
            probe = _SLOProbe(sched)     # resets the emission journal
            cd0 = sched.core.chunk_dispatches
            ca0 = sched.core.chunked_admitted
            per_poll, toks = _lt_run(sched, shorts,
                                     longs if use_longs else [],
                                     shape["inject_every"])
            assert len(toks) == n_short + (n_long if use_longs else 0)
            st = _lt_stats(per_poll)
            st.update(probe.stats())
            if chunked:
                st["chunk_dispatches"] = sched.core.chunk_dispatches - cd0
                st["chunked_admitted"] = sched.core.chunked_admitted - ca0
            if best is None or st["p95_block_ms"] < best[0]["p95_block_ms"]:
                best = (st, toks)
        variants[name], outs[name] = best

    # rids differ per kept trial; submission ORDER is deterministic and
    # shared (shorts in sequence, longs at their inject polls)
    mono = [outs["monolithic"][k] for k in sorted(outs["monolithic"])]
    chnk = [outs["chunked"][k] for k in sorted(outs["chunked"])]
    for i, (a, b) in enumerate(zip(mono, chnk)):
        assert np.array_equal(a, b), \
            f"token divergence at request {i} (chunked vs monolithic)"

    base_p95 = variants["baseline"]["p95_block_ms"]
    ratio_ch = variants["chunked"]["p95_block_ms"] / base_p95
    ratio_mono = variants["monolithic"]["p95_block_ms"] / base_p95
    spike_mono = variants["monolithic"]["max_block_ms"] / base_p95
    spike_ch = variants["chunked"]["max_block_ms"] / base_p95
    if not quick:
        assert ratio_ch <= LT_P95_TARGET, \
            (f"chunked p95 {variants['chunked']['p95_block_ms']}ms exceeds "
             f"{LT_P95_TARGET}x baseline p95 {base_p95}ms")

    record = {
        "bench": "latency_trace",
        "ts": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "backend": jax.default_backend(),
        "n_short": n_short, "n_long": n_long,
        "long_plen": long_plen, "chunk_len": chunk_len,
        "sync_every": SYNC_EVERY, "max_concurrency": LT_CONC,
        "baseline": variants["baseline"],
        "monolithic": variants["monolithic"],
        "chunked": variants["chunked"],
        "p95_ratio_chunked": round(ratio_ch, 3),
        "p95_ratio_monolithic": round(ratio_mono, 3),
        "spike_monolithic": round(spike_mono, 3),
        "spike_chunked": round(spike_ch, 3),
        "token_identical": True,
    }
    if write_json:
        _append_json(record)

    return [
        row("latency_baseline", variants["baseline"]["p95_block_ms"] * 1e3,
            f"p95_block_ms={variants['baseline']['p95_block_ms']};"
            f"polls={variants['baseline']['polls']}"),
        row("latency_monolithic",
            variants["monolithic"]["p95_block_ms"] * 1e3,
            f"p95_block_ms={variants['monolithic']['p95_block_ms']};"
            f"max_block_ms={variants['monolithic']['max_block_ms']};"
            f"spike={spike_mono:.2f}x"),
        row("latency_chunked", variants["chunked"]["p95_block_ms"] * 1e3,
            f"p95_block_ms={variants['chunked']['p95_block_ms']};"
            f"max_block_ms={variants['chunked']['max_block_ms']};"
            f"p95_ratio={ratio_ch:.2f}x(gate<={LT_P95_TARGET});"
            f"chunks={variants['chunked']['chunk_dispatches']};"
            f"ttft_p95_ms={variants['chunked']['ttft_p95_ms']};"
            f"itl_p95_ms={variants['chunked']['itl_p95_ms']};"
            f"tokens_identical=True"),
    ], record


# --------------------------------------------------------------------------- #
# emission_overlap: double-buffered emission-ring drain vs synchronous drain
# --------------------------------------------------------------------------- #

EO_STALL_RATIO_MAX = 0.35  # overlapped stall must be <= 0.35x the sync stall
EO_FLOOR_MS = 0.2          # sync stall per block below this is timer noise
EO_HOST_WORK_FACTOR = 1.5  # per-poll host work, as a multiple of block cost


def _eo_trace(n_req, seed=41):
    """Decode-heavy traffic: every request generates `MAX_NEW_CAP` tokens,
    so drained blocks dominate and the drain discipline is the variable.
    `n_req` should be a multiple of the concurrency so every admit burst
    hits a warmed batch shape."""
    rng = np.random.default_rng(seed)
    return [(rng.integers(0, TRACE_CFG.vocab_size,
                          (int(rng.integers(PROMPT_BUCKET // 2,
                                            PROMPT_BUCKET + 1)),)).astype(
        np.int32), MAX_NEW_CAP) for _ in range(n_req)]


def _eo_run(sched, trace, host_work_s):
    """Submit the trace, drain it with one `host_work_s` sleep after every
    poll — the stand-in for the work a real serving loop does between
    blocks (stream pushes, SSE writes, intake pumping), identical for
    both drain disciplines.  Returns (stall_s, blocks, wall_s, outputs)
    deltas for this pass."""
    for p, mn in trace:
        sched.submit(p, mn)
    s0, b0 = sched.core.drain_stall_s, sched.core.drained_blocks
    done = []
    t0 = time.perf_counter()
    while sched.queue or sched.core.n_occupied or sched.core.n_pending:
        done.extend(sched.poll())
        if host_work_s:
            time.sleep(host_work_s)
    sched.core.drain_pending()
    done.extend(sched.poll())
    wall = time.perf_counter() - t0
    return (sched.core.drain_stall_s - s0,
            sched.core.drained_blocks - b0, wall,
            {r.rid: r.tokens for r in done})


def emission_overlap(quick=False, write_json=True):
    rows_, _ = _emission_overlap(quick=quick, write_json=write_json)
    return rows_


def _emission_overlap(quick=False, write_json=True):
    """Drain-stall accounting for the double-buffered emission ring
    (ISSUE-10 tentpole): the SAME decode-heavy trace runs under the
    synchronous drain discipline (device_get right after dispatch — the
    host blocks for the whole block compute, every block) and the
    overlapped one (``async_drain``: the ring's OTHER bank, written by
    the previous block, drains while the new block computes).

    The overlap needs something to overlap WITH: on a FIFO single-stream
    backend a loop that does nothing between polls is device-bound, and
    no drain discipline can wait less than ``block_cost - host_time``.
    So the bench first calibrates the per-block cost from a sync pass,
    then gives BOTH variants the same per-poll host-work interval
    (``EO_HOST_WORK_FACTOR``x the block cost — the stream-push/SSE work
    a real service loop does between blocks).  The sync discipline
    cannot use it (its device_get already paid the full wait at drain
    time); the ring hides the block compute under it.

    Asserted claims:
      * outputs are token-identical per request — the ring is a timing
        change, never a model change;
      * (gate, also wired into --smoke) overlapped drain stall per block
        stays <= ``EO_STALL_RATIO_MAX`` of the synchronous stall —
        unless the sync stall itself sits under the ``EO_FLOOR_MS``
        timing floor (a machine fast enough that both disciplines are
        free proves nothing either way).
    """
    n_req = 8 if quick else 28
    params = init_params(jax.random.PRNGKey(0), TRACE_CFG)
    ecfg = EngineConfig(mode="uniform",
                        policy=PolicyConfig("sliding_window"),
                        budget_abs=PROMPT_BUCKET // 2, bucket=4, min_budget=4)
    trace = _eo_trace(n_req)
    scheds = {}
    for name, overlapped in [("sync_drain", False), ("overlapped", True)]:
        scheds[name] = _continuous(params, ecfg, SYNC_EVERY)
        scheds[name].core.async_drain = overlapped
        _warm(scheds[name])
    # calibrate the per-block device cost: under the sync discipline with
    # no host work, the drain wait IS the block compute
    stall, blocks, _, _ = _eo_run(scheds["sync_drain"], trace, 0.0)
    block_cost_s = stall / blocks
    host_work_s = EO_HOST_WORK_FACTOR * block_cost_s
    variants, outs = {}, {}
    for name in ("sync_drain", "overlapped"):
        best = None
        for _ in range(2):        # best-of-2: stall timing is CPU-noisy
            stall, blocks, wall, toks = _eo_run(scheds[name], trace,
                                                host_work_s)
            assert len(toks) == n_req and blocks > 0
            st = {"wall_s": round(wall, 4),
                  "drained_blocks": int(blocks),
                  "drain_stall_s": round(stall, 5),
                  "stall_ms_per_block": round(stall / blocks * 1e3, 4)}
            if best is None or (st["stall_ms_per_block"]
                                < best[0]["stall_ms_per_block"]):
                best = (st, toks)
        variants[name], outs[name] = best

    # rids differ per kept trial; submission order is deterministic/shared
    sy = [outs["sync_drain"][k] for k in sorted(outs["sync_drain"])]
    ov = [outs["overlapped"][k] for k in sorted(outs["overlapped"])]
    for i, (a, b) in enumerate(zip(sy, ov)):
        assert np.array_equal(a, b), \
            f"token divergence at request {i} (overlapped vs sync drain)"

    sync_ms = variants["sync_drain"]["stall_ms_per_block"]
    over_ms = variants["overlapped"]["stall_ms_per_block"]
    ratio = over_ms / max(sync_ms, 1e-9)
    record = {
        "bench": "emission_overlap",
        "ts": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "backend": jax.default_backend(),
        "n_req": n_req, "sync_every": SYNC_EVERY,
        "max_concurrency": 4,
        "calib_block_cost_ms": round(block_cost_s * 1e3, 4),
        "host_work_ms_per_poll": round(host_work_s * 1e3, 4),
        "sync_drain": variants["sync_drain"],
        "overlapped": variants["overlapped"],
        "stall_ratio": round(ratio, 4),
        "token_identical": True,
    }
    if write_json:
        _append_json(record)
    return [
        row("overlap_sync_drain", sync_ms * 1e3,
            f"stall_ms_per_block={sync_ms};"
            f"drained_blocks={variants['sync_drain']['drained_blocks']};"
            f"wall_s={variants['sync_drain']['wall_s']}"),
        row("overlap_double_buffered", over_ms * 1e3,
            f"stall_ms_per_block={over_ms};"
            f"stall_ratio={ratio:.3f}(gate<={EO_STALL_RATIO_MAX});"
            f"drained_blocks={variants['overlapped']['drained_blocks']};"
            f"wall_s={variants['overlapped']['wall_s']};"
            f"tokens_identical=True"),
    ], record


def _overlap_gate(record):
    """Gate the double-buffered drain: overlapped stall per block must
    stay <= ``EO_STALL_RATIO_MAX`` of the synchronous stall.  Skipped
    below the timing floor — when even the SYNC drain never waits (tiny
    smoke blocks on a fast machine), the ratio is pure timer noise."""
    sync_ms = record["sync_drain"]["stall_ms_per_block"]
    over_ms = record["overlapped"]["stall_ms_per_block"]
    if sync_ms < EO_FLOOR_MS:
        print(f"bench-gate: sync drain stall {sync_ms:.4f}ms/block under "
              f"the {EO_FLOOR_MS}ms floor — overlap gate skipped "
              f"(overlapped {over_ms:.4f}ms/block)")
        return
    ratio = over_ms / sync_ms
    if ratio > EO_STALL_RATIO_MAX:
        raise SystemExit(f"bench-gate REGRESSION: overlapped drain stall "
                         f"{over_ms:.4f}ms/block is {ratio:.3f}x the sync "
                         f"stall {sync_ms:.4f}ms/block "
                         f"(gate <= {EO_STALL_RATIO_MAX})")
    print(f"bench-gate OK: overlapped drain stall {over_ms:.4f}ms/block = "
          f"{ratio:.3f}x sync {sync_ms:.4f}ms/block "
          f"(gate <= {EO_STALL_RATIO_MAX})")


# --------------------------------------------------------------------------- #
# allocation frontier: memory-vs-quality across allocation modes x policies
# --------------------------------------------------------------------------- #

FRONTIER_FRAC = 0.5           # b_init as a fraction of the prompt length
FRONTIER_N_TIERS = 3          # requested zigzag budget levels
FRONTIER_POLICIES = ("h2o", "l2_norm")
FRONTIER_MODES = ("uniform", "squeeze", "zigzag")


def allocation_frontier(quick=False, write_json=True):
    rows_, _ = _allocation_frontier(quick=quick, write_json=write_json)
    return rows_


def _allocation_frontier(quick=False, write_json=True):
    """Memory-vs-quality frontier for the layer-wise allocation modes
    (ISSUE-9 tentpole): uniform (1 tier) / squeeze (2-tier Algorithm 1) /
    zigzag (N-tier rank-quantile) x {h2o, l2_norm}, all at the SAME
    conserved total budget, scored by token agreement against the
    full-cache reference on the trained bench model.

    Asserted claims:
      * every plan conserves the total exactly after bucket quantization
        (``plan.total + plan.slack == n_layers * b_init``) and all modes
        land on the same conserved total — the frontier compares QUALITY
        at EQUAL MEMORY, with the mode totals within one bucket of slack;
      * at that equal memory the N-tier zigzag plan matches or beats the
        2-tier squeeze plan on token agreement, averaged over the policy
        frontier (h2o's accumulated attention vs l2_norm's static key
        norms bracket the score-signal spectrum).
    """
    from benchmarks.common import (decode_fidelity, eval_prompts,
                                   trained_model)
    params, cfg = trained_model()
    prompts = eval_prompts(4 if quick else 8)
    t0 = time.perf_counter()
    cells = {}
    for pol in FRONTIER_POLICIES:
        for mode in FRONTIER_MODES:
            ekw = {"n_tiers": FRONTIER_N_TIERS} if mode == "zigzag" else {}
            r = decode_fidelity(params, cfg, prompts, mode, policy=pol,
                                budget_frac=FRONTIER_FRAC, **ekw)
            plan = r["plan"]
            # exact N-tier conservation, asserted at the bench level too
            assert plan.total + plan.slack == plan.n_layers * plan.b_init, \
                (pol, mode, plan)
            cells[(pol, mode)] = {
                "agreement": round(r["agreement"], 4),
                "cache_slots": int(r["cache_slots"]),
                "plan_total": int(plan.total),
                "plan_slack": int(plan.slack),
                "n_tiers": plan.n_tiers,
                "tiers": plan.describe(),
            }
    wall = time.perf_counter() - t0

    # equal memory: every mode conserves the same n_layers*b_init total,
    # and the realized totals differ only by sub-bucket quantization slack
    conserved = {c["plan_total"] + c["plan_slack"] for c in cells.values()}
    assert len(conserved) == 1, cells
    spread = (max(c["plan_total"] for c in cells.values())
              - min(c["plan_total"] for c in cells.values()))
    assert spread <= 4, cells        # decode_fidelity's bucket

    means = {m: float(np.mean([cells[(p, m)]["agreement"]
                               for p in FRONTIER_POLICIES]))
             for m in FRONTIER_MODES}
    # the frontier claim, asserted: N tiers never lose to 2 at equal memory
    assert means["zigzag"] >= means["squeeze"], means

    record = {
        "bench": "allocation_frontier",
        "ts": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "backend": jax.default_backend(),
        "budget_frac": FRONTIER_FRAC,
        "n_tiers": FRONTIER_N_TIERS,
        "n_prompts": int(prompts.shape[0]),
        "policies": list(FRONTIER_POLICIES),
        "modes": list(FRONTIER_MODES),
        "cells": {f"{p}/{m}": cells[(p, m)] for p in FRONTIER_POLICIES
                  for m in FRONTIER_MODES},
        "mean_agreement": {m: round(v, 4) for m, v in means.items()},
        "conserved_total": int(next(iter(conserved))),
        "total_spread": int(spread),
    }
    if write_json:
        _append_json(record)

    rows_ = [
        row(f"frontier_{m}", wall / len(cells) * 1e6,
            ";".join(f"{p}_agree={cells[(p, m)]['agreement']:.3f}"
                     for p in FRONTIER_POLICIES)
            + f";mean={means[m]:.3f};total={cells[(FRONTIER_POLICIES[0], m)]['plan_total']}"
            + f";tiers={cells[(FRONTIER_POLICIES[0], m)]['tiers']}")
        for m in FRONTIER_MODES
    ] + [
        row("frontier_gate", 0.0,
            f"zigzag_mean={means['zigzag']:.3f}>="
            f"squeeze_mean={means['squeeze']:.3f}(gate);"
            f"uniform_mean={means['uniform']:.3f};"
            f"conserved_total={record['conserved_total']};"
            f"spread={spread};frac={FRONTIER_FRAC};"
            f"n_tiers={FRONTIER_N_TIERS}"),
    ]
    return rows_, record


# --------------------------------------------------------------------------- #
# CI smoke + bench-regression gate
# --------------------------------------------------------------------------- #

REGRESSION_TOL = 1.2      # fail CI on >20% regression vs the last entry


def _last_recorded(path=BENCH_JSON, bench="serving_trace_poisson"):
    if not os.path.exists(path):
        return None
    try:
        with open(path) as f:
            runs = json.load(f).get("runs", [])
    except (json.JSONDecodeError, OSError):
        return None
    runs = [r for r in runs if r.get("bench") == bench]
    return runs[-1] if runs else None


def _regression_gate(record):
    """Compare the smoke run against the last recorded trajectory entry.

    Two gated quantities, both robust to absolute CPU speed differences
    between the recording machine and CI:
      * fused dispatches-per-decode-step (the tentpole fusion claim);
      * the fused/per-step wall-clock RATIO (relative regression of the
        fused path against its own baseline on the same machine).
    >REGRESSION_TOL x worse than recorded fails CI.
    """
    last = _last_recorded()
    if last is None:
        print("bench-gate: no recorded serving_trace_poisson entry — "
              "skipping comparison")
        return
    failures = []
    cur_dps = record["continuous_fused"]["dispatches_per_step"]
    last_dps = last["continuous_fused"]["dispatches_per_step"]
    if cur_dps > last_dps * REGRESSION_TOL:
        failures.append(f"dispatches_per_step {cur_dps:.3f} > "
                        f"{last_dps:.3f} * {REGRESSION_TOL}")
    cur_ratio = (record["continuous_fused"]["wall_s"]
                 / max(record["continuous_per_step"]["wall_s"], 1e-9))
    last_ratio = (last["continuous_fused"]["wall_s"]
                  / max(last["continuous_per_step"]["wall_s"], 1e-9))
    # the smoke trace is smaller and CI runners are noisier than the
    # recording machine, so the wall gate allows the fused path up to
    # parity with per-step dispatch even when the recorded ratio was
    # better than that: fused SLOWER than per-step is the
    # machine-independent regression signal
    wall_thresh = max(last_ratio * REGRESSION_TOL, 1.0)
    if cur_ratio > wall_thresh:
        failures.append(f"fused/per-step wall ratio {cur_ratio:.3f} > "
                        f"max({last_ratio:.3f} * {REGRESSION_TOL}, 1.0)")
    if failures:
        raise SystemExit("bench-gate REGRESSION vs "
                         f"{last['ts']}: " + "; ".join(failures))
    print(f"bench-gate OK vs {last['ts']}: dispatches_per_step "
          f"{cur_dps:.3f} (recorded {last_dps:.3f}), fused/per-step wall "
          f"{cur_ratio:.3f} (recorded {last_ratio:.3f})")


def _latency_gate(record):
    """Compare the smoke latency run against the last recorded
    `latency_trace` entry: the gated quantity is the chunked/baseline p95
    per-block ratio — machine-independent, like the dispatch gates.  The
    threshold floors at ``LT_P95_TARGET`` (the acceptance bar itself) so a
    recorded ratio well under 1.0 doesn't turn CI noise into failures.
    >REGRESSION_TOL x worse than recorded (and above the floor) fails CI.
    """
    last = _last_recorded(bench="latency_trace")
    if last is None:
        print("bench-gate: no recorded latency_trace entry — "
              "skipping comparison")
        return
    cur = record["p95_ratio_chunked"]
    rec = last["p95_ratio_chunked"]
    thresh = max(rec * REGRESSION_TOL, LT_P95_TARGET)
    if cur > thresh:
        raise SystemExit(f"bench-gate REGRESSION vs {last['ts']}: chunked "
                         f"p95 ratio {cur:.3f} > max({rec:.3f} * "
                         f"{REGRESSION_TOL}, {LT_P95_TARGET})")
    print(f"bench-gate OK vs {last['ts']}: chunked/baseline p95 ratio "
          f"{cur:.3f} (recorded {rec:.3f}, gate {thresh:.3f})")


def _admission_smoke():
    """Deterministic (counter-based, no timing) proof that length-sorted
    and packed admission successively cut prefilled tokens on one bimodal
    burst."""
    from repro.serving import ContinuousEngine
    params = init_params(jax.random.PRNGKey(0), TRACE_CFG)
    ecfg = EngineConfig(mode="uniform",
                        policy=PolicyConfig("sliding_window"),
                        budget_abs=PROMPT_BUCKET // 2, bucket=4, min_budget=4)
    rng = np.random.default_rng(3)
    burst = [(rng.integers(0, TRACE_CFG.vocab_size, (n,)).astype(np.int32), 2)
             for n in (17, 24, 30, 120)]      # 3 short + 1 long prompt
    pads = {}
    for name in ADMISSION_LAYOUTS:
        eng = ContinuousEngine(params, TRACE_CFG, ecfg, ContinuousConfig(
            max_concurrency=4, prompt_bucket=PROMPT_BUCKET,
            max_prompt_len=LONG_PLEN[1], max_new_cap=8,
            **ADMISSION_LAYOUTS[name]))
        eng.admit_many(burst)
        pads[name] = eng.prefill_pad_tokens
    assert pads["sorted"] < pads["padded"], pads
    assert pads["packed"] < pads["sorted"], pads
    print(f"admission smoke OK: bimodal burst prefilled tokens "
          f"{pads['padded']} (padded) -> {pads['sorted']} (sorted) -> "
          f"{pads['packed']} (packed)")


def smoke():
    """CI smoke + regression gate: prove the fused decode block, batched
    admission, length-sorted admission and the multimodal intake compile
    and run, and that the dispatch counters / wall-clock ratio have not
    regressed >20% against the last `BENCH_serving.json` entry.  Tiny
    trace, no JSON write."""
    rows_, record = _serving_trace(quick=True, n_req=8, write_json=False)
    for r in rows_:
        print(f"{r['name']},{r['us_per_call']:.1f},\"{r['derived']}\"")
    _regression_gate(record)
    _admission_smoke()
    # tiny mixed text/vlm trace: layout ordering, packed surplus bound,
    # copy-free direct scatter — all counter asserts, no timing
    for r in multimodal_trace(n_req=6, write_json=False):
        print(f"{r['name']},{r['us_per_call']:.1f},\"{r['derived']}\"")
    # tiny shared-prefix trace: radix-tree reuse gate (>=70% of shared
    # tokens by page reference), identity reuse==no_reuse, pool accounting
    for r in prefix_reuse_trace(n_req=8, n_sys=2, write_json=False):
        print(f"{r['name']},{r['us_per_call']:.1f},\"{r['derived']}\"")
    # tiny overcommitted trace: degradation ladder fires (backpressure,
    # >=1 preempt-resume), tokens stay identical, per-poll audit clean,
    # resident-rows gain >= RESIDENT_GAIN_MIN vs worst-case sizing
    for r in pool_pressure_trace(n_req=12, write_json=False):
        print(f"{r['name']},{r['us_per_call']:.1f},\"{r['derived']}\"")
    # tiny long-prompt latency trace: chunked admission rides the decode
    # blocks, tokens identical to monolithic, p95 per-block ratio gated
    # against the recorded trajectory (floor LT_P95_TARGET)
    lt_rows, lt_record = _latency_trace(quick=True, write_json=False)
    for r in lt_rows:
        print(f"{r['name']},{r['us_per_call']:.1f},\"{r['derived']}\"")
    _latency_gate(lt_record)
    # tiny decode-heavy trace: double-buffered emission-ring drain vs the
    # synchronous discipline — tokens identical, overlapped stall gated
    # at <= EO_STALL_RATIO_MAX of sync (floor EO_FLOOR_MS)
    eo_rows, eo_record = _emission_overlap(quick=True, write_json=False)
    for r in eo_rows:
        print(f"{r['name']},{r['us_per_call']:.1f},\"{r['derived']}\"")
    _overlap_gate(eo_record)
    # allocation frontier: uniform / 2-tier squeeze / N-tier zigzag at
    # equal conserved memory, h2o + l2_norm; gates exact budget
    # conservation per plan and zigzag >= squeeze mean token agreement
    fr_rows, _ = _allocation_frontier(quick=True, write_json=False)
    for r in fr_rows:
        print(f"{r['name']},{r['us_per_call']:.1f},\"{r['derived']}\"")
    print("serving_bench smoke OK")


ALL = [serving_trace, admission_trace, multimodal_trace,
       prefix_reuse_trace, pool_pressure_trace, latency_trace,
       emission_overlap, allocation_frontier]


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI run: compile + dispatch-counter asserts, "
                         "no BENCH_serving.json write")
    ap.add_argument("--policy", default="sliding_window")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        for r in serving_trace(quick=args.quick, policy=args.policy) \
                + admission_trace(quick=args.quick) \
                + multimodal_trace(quick=args.quick) \
                + prefix_reuse_trace(quick=args.quick) \
                + pool_pressure_trace(quick=args.quick) \
                + latency_trace(quick=args.quick) \
                + emission_overlap(quick=args.quick) \
                + allocation_frontier(quick=args.quick):
            print(f"{r['name']},{r['us_per_call']:.1f},\"{r['derived']}\"")
