"""Serving-loop benchmark: wave vs continuous batching under a Poisson trace.

Beyond the paper's Table 3 (fixed-shape batches): requests arrive with
exponential inter-arrival gaps and *heterogeneous* generation lengths, the
regime where lock-step waves waste decode steps — every wave member pays
``max(max_new)`` steps and pad rows replicate request 0 — while the
continuous engine retires rows on-device and recycles their slots.

Reported per scheduler: total wall-clock to drain the trace, mean/p95
request latency (arrival -> completion), and emitted tokens/s.  Both
schedulers are warmed on the same shapes first so compile time is excluded.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import row
from repro.core import PolicyConfig
from repro.models import ModelConfig, init_params
from repro.serving import (ContinuousConfig, ContinuousScheduler,
                           EngineConfig, SchedulerConfig, WaveScheduler)

TRACE_CFG = ModelConfig(
    name="trace-4l", arch_type="dense", n_layers=4, d_model=128,
    n_heads=8, n_kv_heads=4, d_ff=256, vocab_size=256,
    dtype="float32", param_dtype="float32")

PROMPT_BUCKET = 32
MAX_NEW_CAP = 48
SHORT_NEW, LONG_NEW, P_LONG = 4, MAX_NEW_CAP, 0.25


def _trace(n_req: int, seed: int = 7):
    """(prompt, max_new, arrival_s) triples; Poisson arrivals, one prompt
    bucket, bimodal max_new (chat-style: mostly short replies, a quarter
    long generations).  With wave_size=4, ~68% of waves contain a long
    request, so the whole wave pays ~LONG_NEW steps for a ~15-step mean —
    the quantization continuous batching removes."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(scale=0.01, size=n_req)     # ~100 req/s offered
    arrivals = np.cumsum(gaps)
    out = []
    for i in range(n_req):
        plen = int(rng.integers(PROMPT_BUCKET // 2, PROMPT_BUCKET + 1))
        max_new = LONG_NEW if rng.random() < P_LONG else SHORT_NEW
        out.append((rng.integers(0, TRACE_CFG.vocab_size, (plen,)).astype(
            np.int32), max_new, float(arrivals[i])))
    return out


def _drive(sched, trace, step_fn):
    """Release requests at their arrival times, drain with `step_fn`."""
    t0 = time.perf_counter()
    pending = list(trace)
    done = []
    while pending or sched.queue or _n_inflight(sched):
        now = time.perf_counter() - t0
        while pending and pending[0][2] <= now:
            prompt, max_new, _ = pending.pop(0)
            sched.submit(prompt, max_new)
        if sched.queue or _n_inflight(sched):
            done.extend(step_fn(sched))
        elif pending:
            time.sleep(min(pending[0][2] - now, 1e-3))
    wall = time.perf_counter() - t0
    # latency_s is completion - submit, and submission happens at the
    # simulated arrival instant, so this is arrival -> completion latency
    lats = np.asarray([r.latency_s for r in done])
    toks = sum(r.tokens.size for r in done)
    return wall, lats, toks, done


def _n_inflight(sched):
    return sched.core.n_occupied if hasattr(sched, "core") else 0


def _warm(sched, n=3):
    rng = np.random.default_rng(0)
    for _ in range(n):
        sched.submit(rng.integers(0, TRACE_CFG.vocab_size,
                                  (PROMPT_BUCKET,)).astype(np.int32),
                     MAX_NEW_CAP)
    sched.run_until_empty()


def _best_of(sched, trace, step_fn, n_req, trials):
    """Repeat the drain (same warmed scheduler, queue empties every trial)
    and keep the fastest — real-time arrival release makes single passes
    noisy on a shared CPU.  Lane utilization is snapshotted per trial (the
    scheduler counters accumulate across warm-up and trials) and reported
    for the kept trial."""
    best = None
    for _ in range(trials):
        r0, u0 = sched.row_steps, sched.useful_row_steps
        wall, lats, toks, done = _drive(sched, trace, step_fn)
        util = (sched.useful_row_steps - u0) / max(sched.row_steps - r0, 1)
        assert len(done) == n_req
        if best is None or wall < best[0]:
            best = (wall, lats, toks, util)
    return best


def serving_trace(quick=False, policy="sliding_window"):
    # the trace length stays fixed (smaller samples of the bimodal max_new
    # mix are unrepresentative); quick just takes fewer timing trials
    n_req = 24
    trials = 2 if quick else 3
    params = init_params(jax.random.PRNGKey(0), TRACE_CFG)
    ecfg = EngineConfig(mode="uniform", policy=PolicyConfig(policy),
                        budget_abs=PROMPT_BUCKET // 2, bucket=4, min_budget=4)
    trace = _trace(n_req)

    wave = WaveScheduler(params, TRACE_CFG, ecfg, SchedulerConfig(
        wave_size=4, prompt_bucket=PROMPT_BUCKET, max_wave_new=MAX_NEW_CAP))
    _warm(wave)
    w_wall, w_lat, w_toks, w_util = _best_of(
        wave, trace, lambda s: s.run_wave(), n_req, trials)

    cont = ContinuousScheduler(params, TRACE_CFG, ecfg, ContinuousConfig(
        max_concurrency=4, prompt_bucket=PROMPT_BUCKET,
        max_prompt_len=PROMPT_BUCKET, max_new_cap=MAX_NEW_CAP,
        sync_every=4))
    _warm(cont)
    c_wall, c_lat, c_toks, c_util = _best_of(
        cont, trace, lambda s: s.poll(), n_req, trials)
    # decode-lane utilization — the fraction of batched decode-row-steps a
    # live request actually wanted — is free of wall-clock measurement
    # noise (though wave composition still depends on arrival interleaving)
    return [
        row("serving_trace_wave", w_wall * 1e6,
            f"wall_ms={w_wall*1e3:.1f};mean_lat_ms={w_lat.mean()*1e3:.1f};"
            f"p95_lat_ms={np.percentile(w_lat, 95)*1e3:.1f};"
            f"tok_s={w_toks/max(w_wall, 1e-9):.1f};"
            f"lane_util={w_util:.2f}"),
        row("serving_trace_continuous", c_wall * 1e6,
            f"wall_ms={c_wall*1e3:.1f};mean_lat_ms={c_lat.mean()*1e3:.1f};"
            f"p95_lat_ms={np.percentile(c_lat, 95)*1e3:.1f};"
            f"tok_s={c_toks/max(c_wall, 1e-9):.1f};"
            f"lane_util={c_util:.2f}"),
        row("serving_trace_speedup", 0.0,
            f"wallclock_speedup={w_wall/max(c_wall, 1e-9):.2f}x;"
            f"lane_util_gain={c_util/max(w_util, 1e-9):.2f}x;"
            f"n_req={n_req};max_new={SHORT_NEW}|{LONG_NEW}@p{P_LONG}"),
    ]


ALL = [serving_trace]
