"""Benchmark harness: one function per paper table/figure + kernel micro-
benchmarks.  Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig3]
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark function names")
    args = ap.parse_args()

    from benchmarks import kernel_bench, paper_tables
    fns = list(paper_tables.ALL) + list(kernel_bench.ALL)
    if args.only:
        fns = [f for f in fns if args.only in f.__name__]

    print("name,us_per_call,derived")
    failures = 0
    for fn in fns:
        try:
            for r in fn(quick=args.quick):
                print(f"{r['name']},{r['us_per_call']:.1f},\"{r['derived']}\"",
                      flush=True)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{fn.__name__},NaN,\"ERROR\"", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
