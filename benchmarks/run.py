"""Benchmark harness: one function per paper table/figure + kernel micro-
benchmarks + serving-loop traces.  Prints ``name,us_per_call,derived`` CSV
rows.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig3] \
        [--policy sink_h2o]

`--policy` accepts every registered sequence-wise policy
(repro.core.policies.POLICIES) and is forwarded to each benchmark that
exercises the decode path.
"""
from __future__ import annotations

import argparse
import inspect
import sys
import traceback


def main() -> None:
    from repro.core import POLICIES

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark function names")
    ap.add_argument("--policy", default="sliding_window",
                    choices=list(POLICIES),
                    help="sequence-wise policy for decode benchmarks")
    args = ap.parse_args()

    from benchmarks import kernel_bench, paper_tables, serving_bench
    fns = list(paper_tables.ALL) + list(kernel_bench.ALL) \
        + list(serving_bench.ALL)
    if args.only:
        fns = [f for f in fns if args.only in f.__name__]

    print("name,us_per_call,derived")
    failures = 0
    for fn in fns:
        kw = {"quick": args.quick}
        if "policy" in inspect.signature(fn).parameters:
            kw["policy"] = args.policy
        try:
            for r in fn(**kw):
                derived = r["derived"]
                if "policy" in kw:     # make policy sweeps attributable
                    derived = f"{derived};policy={args.policy}"
                print(f"{r['name']},{r['us_per_call']:.1f},\"{derived}\"",
                      flush=True)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{fn.__name__},NaN,\"ERROR\"", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
