"""Kernel micro-benchmarks: Pallas (interpret) vs jnp reference wall-time.

Interpret-mode timings are NOT TPU performance (the kernels' perf claims
come from the §Roofline analysis of block shapes and HBM traffic); these
rows exist to (a) prove the kernels run end-to-end under jit, and (b) track
the jnp reference costs that the CPU benchmarks actually exercise.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import row


def _time(fn, *args, reps=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def flash_decode_bench(quick=False):
    from repro.kernels.flash_decode.ops import flash_decode
    from repro.kernels.flash_decode.ref import decode_attention_ref
    B, S, Hkv, G, hd = 2, 512, 2, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (B, Hkv, G, hd))
    k = jax.random.normal(ks[1], (B, S, Hkv, hd))
    v = jax.random.normal(ks[2], (B, S, Hkv, hd))
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    t = jnp.full((B,), S, jnp.int32)
    ref = jax.jit(lambda *a: decode_attention_ref(*a, 1 << 30))
    kern = jax.jit(lambda *a: flash_decode(*a, 1 << 30, block_s=128))
    return [
        row("kern_flash_decode_ref_jnp", _time(ref, q, k, v, pos, t),
            f"S={S}"),
        row("kern_flash_decode_pallas_interp", _time(kern, q, k, v, pos, t),
            "interpret=True (CPU emulation of TPU kernel)"),
    ]


def ssd_bench(quick=False):
    from repro.kernels.ssd_scan.ops import ssd
    from repro.kernels.ssd_scan.ref import ssd_recurrent_ref, ssd_ref
    B, S, H, P, N = 1, 256, 2, 32, 32
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    xh = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    bh = jax.random.normal(ks[1], (B, S, N)) * 0.5
    ch = jax.random.normal(ks[2], (B, S, N)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[3], (B, S, H)) - 2.0)
    a_log = jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32))
    d = jnp.ones((H,))
    rec = jax.jit(lambda *a: ssd_recurrent_ref(*a))
    chunked = jax.jit(lambda *a: ssd_ref(*a, 64))
    kern = jax.jit(lambda *a: ssd(*a, chunk=64))
    return [
        row("kern_ssd_recurrent_ref", _time(rec, xh, bh, ch, dt, a_log, d),
            f"S={S} literal scan"),
        row("kern_ssd_chunked_jnp", _time(chunked, xh, bh, ch, dt, a_log, d),
            "model's production path"),
        row("kern_ssd_pallas_interp", _time(kern, xh, bh, ch, dt, a_log, d),
            "interpret=True"),
    ]


def swa_bench(quick=False):
    from repro.kernels.swa_prefill.ops import swa_attention
    from repro.kernels.swa_prefill.ref import swa_attention_ref
    B, Hq, Hkv, S, hd = 1, 4, 2, 512, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Hq, S, hd))
    k = jax.random.normal(ks[1], (B, Hkv, S, hd))
    v = jax.random.normal(ks[2], (B, Hkv, S, hd))
    ref = jax.jit(lambda *a: swa_attention_ref(*a, 128))
    kern = jax.jit(lambda *a: swa_attention(*a, window=128, bq=128, bk=128))
    return [
        row("kern_swa_ref_jnp", _time(ref, q, k, v), f"S={S} w=128"),
        row("kern_swa_pallas_interp", _time(kern, q, k, v), "interpret=True"),
    ]


ALL = [flash_decode_bench, ssd_bench, swa_bench]
