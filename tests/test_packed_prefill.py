"""Packed prefill: block-diagonal masking + packed admission (DESIGN.md §5).

The load-bearing property: admitting a burst through ONE packed prefill
(prompts concatenated into few rows, positions reset per segment, attention
masked block-diagonal, recurrent scans reset at segment boundaries) is
token-identical to BOTH the PR-3 bucketed admission path and solo
`Engine.generate` runs, across dense / ssm / hybrid families — packing is a
layout change, not a model change.  Fast-lane units pin the pieces: the
packing planner, the segment-masked attention, and the SSD segment
resets/state snapshots.
"""
import pytest

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import PolicyConfig
from repro.models import ModelConfig, init_params
from repro.serving import (ContinuousConfig, ContinuousEngine,
                           ContinuousScheduler, Engine, EngineConfig,
                           pad_prompt, plan_pack)

DENSE = ModelConfig(name="s", arch_type="dense", n_layers=2, d_model=64,
                    n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97,
                    dtype="float32", param_dtype="float32")
HYBRID = ModelConfig(name="h", arch_type="hybrid", n_layers=4, d_model=64,
                     n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97,
                     ssm_state=8, ssm_expand=2, ssm_head_dim=32, ssm_chunk=8,
                     attn_period=2, dtype="float32", param_dtype="float32")
SSM = ModelConfig(name="m", arch_type="ssm", n_layers=2, d_model=64,
                  n_heads=1, n_kv_heads=1, head_dim=32, d_ff=0, vocab_size=97,
                  ssm_state=8, ssm_expand=2, ssm_head_dim=32, ssm_chunk=8,
                  dtype="float32", param_dtype="float32")

ECFG = EngineConfig(mode="uniform", policy=PolicyConfig("sliding_window"),
                    budget_abs=12, bucket=4, min_budget=4)


def _ccfg(**kw):
    base = dict(max_concurrency=3, prompt_bucket=8, max_prompt_len=24,
                max_new_cap=8, sync_every=2, packed_prefill=True)
    base.update(kw)
    return ContinuousConfig(**base)


# ------------------------------------------------------------ planner units
@pytest.mark.fast
def test_plan_pack_respects_capacity_and_quantum():
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 97, (n,)).astype(np.int32)
               for n in (5, 11, 16, 3, 9, 20)]
    plan = plan_pack(prompts, bucket=8, pack_len=32, quantum=8)
    assert plan.pack_len <= 32 and plan.pack_len % 8 == 0
    # slots are quantum-padded and never straddle rows
    for i, p in enumerate(prompts):
        slot = -(-max(len(p), 1) // 8) * 8
        assert plan.slot_len[i] == slot
        assert plan.start[i] + slot <= plan.pack_len
        assert plan.start[i] % 8 == 0          # chunk-aligned segment starts
    # per-row loads within capacity, segments monotone, tail pad distinct
    for r in range(plan.n_rows):
        segs = plan.segments[r]
        assert (np.diff(segs) >= 0).all()
    # every token of a prompt landed where the plan says, positions reset
    for i, p in enumerate(prompts):
        r, s = plan.row[i], plan.start[i]
        assert (plan.tokens[r, s:s + len(p)] == p).all()
        assert (plan.valid[r, s:s + len(p)]).all()
        assert (plan.positions[r, s:s + plan.slot_len[i]]
                == np.arange(plan.slot_len[i])).all()
        assert plan.take_last[r, plan.seg[i]] == s + len(p) - 1
        assert plan.take_state[r, plan.seg[i]] == s + plan.slot_len[i] - 1


@pytest.mark.fast
def test_plan_pack_overflow_opens_rows_and_degenerate_single():
    rng = np.random.default_rng(1)
    # total content 3 * 16 = 48 > pack_len 32: must overflow into 2+ rows
    prompts = [rng.integers(0, 97, (16,)).astype(np.int32) for _ in range(3)]
    plan = plan_pack(prompts, bucket=8, pack_len=32, quantum=8)
    assert plan.n_rows == 2
    loads = np.zeros(plan.n_rows, int)
    for i in range(3):
        loads[plan.row[i]] += plan.slot_len[i]
    assert (loads <= 32).all()
    # degenerate pack: one prompt, one row, one segment
    single = plan_pack(prompts[:1], bucket=8, pack_len=32, quantum=8)
    assert single.n_rows == 1 and single.max_segments == 1
    assert single.start[0] == 0 and single.row[0] == 0
    # a prompt longer than pack_len still packs (capacity grows to fit)
    big = plan_pack([rng.integers(0, 97, (40,)).astype(np.int32)],
                    bucket=8, pack_len=32, quantum=1)
    assert big.pack_len >= 40


@pytest.mark.fast
def test_plan_pack_raw_quantum_has_no_intra_bucket_padding():
    prompts = [np.arange(n, dtype=np.int32) for n in (5, 11, 16)]
    plan = plan_pack(prompts, bucket=8, pack_len=64, quantum=1)
    assert (plan.slot_len == np.asarray([5, 11, 16])).all()
    assert plan.n_rows == 1
    # valid mask covers exactly the prompt content
    assert plan.valid.sum() == 32


# ----------------------------------------------- segment-masked model units
@pytest.mark.fast
def test_full_attention_block_diagonal_matches_separate_rows():
    """One packed row of two segments == two separate rows, bit-exact
    (same positions, same per-token values; the mask only adds exact
    zeros to softmax sums)."""
    from repro.models import attention as attn_lib
    cfg = DENSE
    params = init_params(jax.random.PRNGKey(0), cfg)
    bp = jax.tree.map(lambda a: a[0], params["layers"])
    ap = attn_lib.AttnParams(**bp["attn"])
    rng = np.random.default_rng(2)
    x1 = jnp.asarray(rng.standard_normal((1, 8, cfg.d_model)), jnp.float32)
    x2 = jnp.asarray(rng.standard_normal((1, 8, cfg.d_model)), jnp.float32)
    pos = jnp.arange(8, dtype=jnp.int32)[None]
    o1, k1, _, c1 = attn_lib.full_attention(ap, x1, pos, cfg,
                                            return_colsums=True)
    o2, k2, _, c2 = attn_lib.full_attention(ap, x2, pos, cfg,
                                            return_colsums=True)
    xp = jnp.concatenate([x1, x2], axis=1)
    posp = jnp.concatenate([pos, pos], axis=1)
    seg = jnp.concatenate([jnp.zeros((1, 8), jnp.int32),
                           jnp.ones((1, 8), jnp.int32)], axis=1)
    op, kp, _, cp = attn_lib.full_attention(ap, xp, posp, cfg, segments=seg,
                                            return_colsums=True)
    np.testing.assert_array_equal(np.asarray(op[:, :8]), np.asarray(o1))
    np.testing.assert_array_equal(np.asarray(op[:, 8:]), np.asarray(o2))
    np.testing.assert_array_equal(np.asarray(cp[..., :8]), np.asarray(c1))
    np.testing.assert_array_equal(np.asarray(cp[..., 8:]), np.asarray(c2))


@pytest.mark.fast
def test_ssd_segment_reset_and_snapshots_match_solo():
    """Chunk-aligned packed segments: y is exact per token and the
    snapshot at each segment's end equals the solo run's final state
    bit-for-bit (the aligned readout reuses the scan's own chunk states)."""
    from repro.models import ssm as ssm_lib
    cfg = SSM
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    L = cfg.ssm_chunk
    rng = np.random.default_rng(3)
    lens = (8, 16, 8)          # chunk-aligned slots
    xs, bs, cs, ds = [], [], [], []
    for n in lens:
        xs.append(rng.standard_normal((1, n, H, P)).astype(np.float32))
        bs.append(rng.standard_normal((1, n, N)).astype(np.float32))
        cs.append(rng.standard_normal((1, n, N)).astype(np.float32))
        ds.append(rng.uniform(0.01, 0.1, (1, n, H)).astype(np.float32))
    a_log = jnp.zeros((H,))
    d_skip = jnp.ones((H,))
    finals = [ssm_lib.ssd_chunked(*map(jnp.asarray, (x, b, c, d)),
                                  a_log, d_skip, L)[1]
              for x, b, c, d in zip(xs, bs, cs, ds)]
    cat = lambda arrs: jnp.asarray(np.concatenate(arrs, axis=1))
    seg = jnp.asarray(np.concatenate(
        [np.full((1, n), i) for i, n in enumerate(lens)], axis=1), jnp.int32)
    ends = np.cumsum(lens) - 1
    take = jnp.asarray(ends[None], jnp.int32)
    yp, _, snaps = ssm_lib.ssd_chunked(
        cat(xs), cat(bs), cat(cs), cat(ds), a_log, d_skip, L,
        segments=seg, take_pos=take)
    for i, f in enumerate(finals):
        np.testing.assert_array_equal(np.asarray(snaps[:, i]),
                                      np.asarray(f))
    # y: per-token equality vs solo runs (same chunk grid per segment)
    off = 0
    for i, n in enumerate(lens):
        y_solo = ssm_lib.ssd_chunked(*map(jnp.asarray,
                                          (xs[i], bs[i], cs[i], ds[i])),
                                     a_log, d_skip, L)[0]
        np.testing.assert_allclose(np.asarray(yp[:, off:off + n]),
                                   np.asarray(y_solo), atol=1e-6)
        off += n
    # unused take slots read as zeros
    take2 = jnp.asarray([[int(ends[0]), -1, -1]], jnp.int32)
    _, _, s2 = ssm_lib.ssd_chunked(cat(xs), cat(bs), cat(cs), cat(ds),
                                   a_log, d_skip, L, segments=seg,
                                   take_pos=take2)
    assert (np.asarray(s2[:, 1:]) == 0).all()


@pytest.mark.fast
def test_packed_recurrent_requires_chunk_aligned_bucket():
    """The ctor refuses packed admission whose segment grid cannot align
    with the SSD chunk grid — the config that would silently break
    bit-identity."""
    with pytest.raises(ValueError, match="multiple of ssm_chunk"):
        ContinuousEngine(None, SSM, ECFG, _ccfg(prompt_bucket=12))


# ----------------------------------------------------- system: admission
@pytest.mark.system
@pytest.mark.parametrize("cfg", [DENSE, SSM, HYBRID],
                         ids=["dense", "ssm", "hybrid"])
def test_packed_admission_token_identity(cfg):
    """Packed admission == bucketed admission == solo generate, per
    request, under greedy sampling.  The burst (6 requests, 3 slots)
    overflows one pack row AND forces slot recycling; the final single
    submission exercises the degenerate one-segment pack."""
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    specs = [(5, 4), (11, 7), (16, 8), (3, 1), (9, 6), (20, 5)]
    prompts = [rng.integers(0, 97, (n,)).astype(np.int32) for n, _ in specs]

    outs = {}
    for name, ccfg in (("packed", _ccfg(pack_len=24)),
                       ("bucketed", _ccfg(packed_prefill=False))):
        sched = ContinuousScheduler(params, cfg, ECFG, ccfg)
        rids = [sched.submit(p, max_new=mn)
                for p, (_, mn) in zip(prompts, specs)]
        done = {r.rid: r for r in sched.run_until_empty()}
        # degenerate pack: one request admitted alone
        solo_rid = sched.submit(prompts[0], max_new=4)
        done.update({r.rid: r for r in sched.run_until_empty()})
        outs[name] = [done[rid].tokens.tolist()
                      for rid in rids + [solo_rid]]
    assert outs["packed"] == outs["bucketed"]

    solo = Engine(params, cfg, ECFG)
    for i, (p, (_, mn)) in enumerate(zip(prompts, specs)):
        toks, valid = pad_prompt(p, 8)
        ref = solo.generate(tokens=toks, valid=valid,
                            max_new_tokens=mn).tokens[0]
        assert outs["packed"][i] == ref.tolist(), i


@pytest.mark.system
def test_packed_h2o_matches_solo_on_unpadded_prompt():
    """Score-based policies: a packed attention-only request's H2O
    statistics have no pad-query artifact, so it matches solo generate on
    the UNPADDED prompt (the documented identity scope — the bucketed
    layouts instead match the bucket-PADDED solo run)."""
    ecfg = EngineConfig(mode="uniform", policy=PolicyConfig("h2o"),
                        budget_abs=12, bucket=4, min_budget=4)
    params = init_params(jax.random.PRNGKey(0), DENSE)
    sched = ContinuousScheduler(params, DENSE, ecfg, _ccfg())
    rng = np.random.default_rng(0)
    specs = [(5, 4), (11, 7), (16, 8), (9, 6), (20, 5)]
    prompts = [rng.integers(0, 97, (n,)).astype(np.int32) for n, _ in specs]
    rids = [sched.submit(p, max_new=mn)
            for p, (_, mn) in zip(prompts, specs)]
    done = {r.rid: r for r in sched.run_until_empty()}
    solo = Engine(params, DENSE, ecfg)
    for rid, p, (_, mn) in zip(rids, prompts, specs):
        ref = solo.generate(tokens=p[None], max_new_tokens=mn).tokens[0]
        assert done[rid].tokens.tolist() == ref.tolist(), rid


@pytest.mark.system
def test_packed_admission_never_retraces():
    """Packed admission obeys the traced-index discipline: one compiled
    packed prefill + one compiled unpack-admit per layout shape, reused
    across bursts that land in different slots."""
    params = init_params(jax.random.PRNGKey(0), SSM)
    sched = ContinuousScheduler(params, SSM, ECFG, _ccfg())
    rng = np.random.default_rng(1)
    for wave in range(3):                      # same lengths, rotating slots
        for n in (5, 11, 16):
            sched.submit(rng.integers(0, 97, (n,)), max_new=4)
        done = sched.run_until_empty()
        assert len(done) == 3
    core = sched.core
    assert all(fn._cache_size() == 1 for fn in core._padmit_fns.values())
    assert len(core._padmit_fns) == 1          # one layout shape -> one fn
    assert core.admit_dispatches == 3


@pytest.mark.system
def test_packed_prefill_counts_fewer_tokens_than_bucketed():
    """The point of the layout: a bimodal burst prefills fewer tokens
    packed than length-sorted, and the packed surplus over the prompt
    content stays below one pack row."""
    params = init_params(jax.random.PRNGKey(0), DENSE)
    rng = np.random.default_rng(2)
    burst = [(rng.integers(0, 97, (n,)).astype(np.int32), 2)
             for n in (5, 7, 6, 23)]
    pads = {}
    for name, ccfg in (("packed", _ccfg(max_concurrency=4)),
                       ("sorted", _ccfg(max_concurrency=4,
                                        packed_prefill=False))):
        eng = ContinuousEngine(params, DENSE, ECFG, ccfg)
        eng.admit_many(burst)
        pads[name] = (eng.prefill_pad_tokens, eng.prompt_tokens)
    assert pads["packed"][1] == pads["sorted"][1]
    assert pads["packed"][0] < pads["sorted"][0], pads
    assert pads["packed"][0] - pads["packed"][1] < \
        ContinuousConfig(prompt_bucket=8,
                         max_prompt_len=24).resolved_pack_len()
