"""Policy / allocator conformance suite.

One matrix pins the whole serving stack: every eviction policy x every
allocation mode (uniform / squeeze / zigzag) x both KV layouts
(contiguous arenas, paged pool) x both model families (dense, hybrid
attn+SSM) must

  (a) serve token-identically to solo ``Engine.generate`` runs,
  (b) conserve the budget total exactly after bucket quantization
      (``plan.total + plan.slack == n_layers * b_init``), and
  (c) never retrace a compiled executable across admission, fused
      decode blocks, retirement and slot recycling.

Identity scope: squeeze/zigzag calibrate the layer grouping from the
FIRST admitted batch's cosine sims, so the continuous plan only equals
the solo plan when both paths see the same prefill.  The matrix uses
identical prompt contents for the calibrated modes (uniform mode keeps
distinct prompts — its plan is request-independent).
"""
import pytest

pytestmark = [pytest.mark.system, pytest.mark.conformance]

import numpy as np

import jax

from repro.core import PolicyConfig
from repro.core.policies import POLICIES
from repro.models import ModelConfig, init_params
from repro.serving import (ContinuousConfig, ContinuousScheduler, Engine,
                           EngineConfig, pad_prompt)

DENSE = ModelConfig(name="c4", arch_type="dense", n_layers=4, d_model=64,
                    n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97,
                    dtype="float32", param_dtype="float32")
HYBRID = ModelConfig(name="h4", arch_type="hybrid", n_layers=4, d_model=64,
                     n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97,
                     ssm_state=8, ssm_expand=2, ssm_head_dim=32, ssm_chunk=8,
                     attn_period=2, dtype="float32", param_dtype="float32")

MODES = ("uniform", "squeeze", "zigzag")
LAYOUTS = {"contiguous": 0, "paged": 4}

_PARAMS = {}
_SOLO_REFS = {}     # (cfg, policy, mode, prompt bytes) -> solo greedy tokens


def _params(cfg):
    if cfg.name not in _PARAMS:
        _PARAMS[cfg.name] = init_params(jax.random.PRNGKey(0), cfg)
    return _PARAMS[cfg.name]


def _solo_ref(cfg, ecfg, prompt, bucket, mn):
    """Solo greedy reference, cached across layouts (the paged/contiguous
    axis must not change tokens, so both compare against ONE solo run)."""
    key = (cfg.name, ecfg.policy.name, ecfg.mode, prompt.tobytes(), mn)
    if key not in _SOLO_REFS:
        solo = Engine(_params(cfg), cfg, ecfg)
        toks, valid = pad_prompt(prompt, bucket)
        _SOLO_REFS[key] = solo.generate(
            tokens=toks, valid=valid, max_new_tokens=mn).tokens[0].tolist()
    return _SOLO_REFS[key]


def _prompts(mode, rng):
    """Three length-7 prompts; identical contents under calibrated modes
    so the continuous plan (first-batch cosine sims) matches solo plans."""
    if mode == "uniform":
        return [rng.integers(0, 97, (7,)).astype(np.int32) for _ in range(3)]
    p = rng.integers(0, 97, (7,)).astype(np.int32)
    return [p.copy() for _ in range(3)]


@pytest.mark.parametrize("psize", list(LAYOUTS.values()), ids=list(LAYOUTS))
@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("cfg", [DENSE, HYBRID], ids=["dense", "hybrid"])
@pytest.mark.parametrize("policy", sorted(POLICIES))
def test_policy_mode_layout_conformance(policy, cfg, mode, psize):
    params = _params(cfg)
    ecfg = EngineConfig(mode=mode, policy=PolicyConfig(policy),
                        budget_abs=12, bucket=4, min_budget=4, n_tiers=2)
    ccfg = ContinuousConfig(max_concurrency=2, prompt_bucket=8,
                            max_prompt_len=16, max_new_cap=6, sync_every=2,
                            page_size=psize)
    sched = ContinuousScheduler(params, cfg, ecfg, ccfg)
    rng = np.random.default_rng(0)
    prompts = _prompts(mode, rng)
    # three requests on two slots: the third lands on a recycled row
    rids = [sched.submit(p, max_new=4) for p in prompts]
    done = {r.rid: r for r in sched.run_until_empty()}
    assert len(done) == len(rids)
    core = sched.core

    # (b) exact conservation after bucket quantization, floors respected
    plan = core.plan
    assert plan is not None
    assert plan.total + plan.slack == plan.n_layers * plan.b_init
    assert (plan.budgets >= min(ecfg.min_budget, plan.b_init)).all()
    assert sum(len(idx) for _, idx in plan.layer_tiers()) == plan.n_layers
    if mode == "uniform":
        assert plan.n_tiers == 1 and plan.slack == 0

    # (c) zero-retrace discipline: one executable per shape family
    assert all(fn._cache_size() == 1 for fn in core._block_fns.values())
    assert all(fn._cache_size() == 1 for fn in core._admit_fns.values())
    assert core._clear_fn._cache_size() == 1
    assert all(fn._cache_size() == 1 for fn in core._padmit_fns.values())

    # (a) token identity against solo generate on the same padded prompts
    for rid, p in zip(rids, prompts):
        ref = _solo_ref(cfg, ecfg, p, ccfg.prompt_bucket, 4)
        assert done[rid].tokens.tolist() == ref, (policy, mode, psize, rid)
