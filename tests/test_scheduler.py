"""Wave scheduler + EOS handling over the SqueezeAttention engine."""
import pytest

pytestmark = pytest.mark.system

import numpy as np

import jax

from repro.core import PolicyConfig
from repro.models import ModelConfig, init_params
from repro.serving import (Engine, EngineConfig, SchedulerConfig,
                           WaveScheduler, pad_prompt, pad_prompts)

CFG = ModelConfig(name="s", arch_type="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97,
                  dtype="float32", param_dtype="float32")


def _params():
    return init_params(jax.random.PRNGKey(0), CFG)


def test_wave_scheduler_serves_mixed_lengths():
    sched = WaveScheduler(
        _params(), CFG,
        EngineConfig(mode="squeeze", policy=PolicyConfig("sliding_window"),
                     budget_frac=0.5, bucket=4, min_budget=4),
        SchedulerConfig(wave_size=4, prompt_bucket=8, max_wave_new=6))
    rng = np.random.default_rng(0)
    rids = [sched.submit(rng.integers(0, 97, (n,)), max_new=5)
            for n in (5, 11, 16, 3, 9)]          # 5 requests -> 2 waves
    done = sched.run_until_empty()
    assert len(done) == 5
    assert sorted(r.rid for r in done) == sorted(rids)
    for r in done:
        assert r.tokens.shape == (5,)
        assert (r.tokens >= 0).all() and (r.tokens < 97).all()
        assert r.latency_s > 0


def test_padded_rows_do_not_change_real_rows():
    """A request served in a full wave == the same request in a padded wave."""
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, 97, (12,))

    def serve(extra):
        sched = WaveScheduler(
            _params(), CFG,
            EngineConfig(mode="full"),
            SchedulerConfig(wave_size=4, prompt_bucket=4, max_wave_new=4))
        rid = sched.submit(prompt, max_new=4)
        for _ in range(extra):
            sched.submit(rng.integers(0, 97, (8,)), max_new=4)
        done = {r.rid: r for r in sched.run_until_empty()}
        return done[rid].tokens.tolist()

    assert serve(0) == serve(3)


def test_partial_wave_smaller_than_wave_size():
    """queue < wave_size: the wave pads with replicas of request 0 and every
    real request still gets its own output."""
    sched = WaveScheduler(
        _params(), CFG, EngineConfig(mode="full"),
        SchedulerConfig(wave_size=8, prompt_bucket=4, max_wave_new=4))
    rng = np.random.default_rng(5)
    rids = [sched.submit(rng.integers(0, 97, (n,)), max_new=3)
            for n in (7, 12)]                       # 2 requests, wave of 8
    done = sched.run_until_empty()
    assert len(done) == 2
    assert sorted(r.rid for r in done) == sorted(rids)
    assert not sched.queue
    for r in done:
        assert r.tokens.shape == (3,)


def test_pad_prompts_bucketing_and_valid_masks():
    rng = np.random.default_rng(6)
    prompts = [rng.integers(0, 97, (n,)).astype(np.int32) for n in (5, 11, 9)]
    toks, valid = pad_prompts(prompts, bucket=8, batch=4)
    assert toks.shape == valid.shape == (4, 16)     # 11 -> bucket 16
    for i, p in enumerate(prompts):
        assert (toks[i, :len(p)] == p).all()
        assert valid[i, :len(p)].all() and not valid[i, len(p):].any()
    assert not valid[3].any()                        # pad row: all invalid

    t1, v1 = pad_prompt(prompts[0], bucket=8)
    assert t1.shape == (1, 8) and v1[0, :5].all() and not v1[0, 5:].any()
    try:
        pad_prompt(np.zeros(20, np.int32), bucket=8, max_len=16)
        assert False, "expected ValueError"
    except ValueError:
        pass


def test_eos_early_stop_and_masking():
    params = _params()
    # pick whatever greedy emits at step 2 as the EOS token to force a stop
    probe = Engine(params, CFG, EngineConfig(mode="full", max_new_tokens=6))
    prompt = np.random.default_rng(2).integers(0, 97, (1, 10)).astype(np.int32)
    first = probe.generate(tokens=prompt).tokens[0]
    eos = int(first[2])
    eng = Engine(params, CFG, EngineConfig(mode="full", max_new_tokens=12,
                                           eos_token=eos, eos_check_every=2))
    r = eng.generate(tokens=prompt)
    toks = r.tokens[0]
    hit = np.where(toks == eos)[0]
    assert hit.size > 0
    assert (toks[hit[0]:] == eos).all()          # everything after EOS masked
