"""Continuous-batching invariants: admission, retirement, slot recycling.

The load-bearing property is the first test: per-request outputs through the
persistent-arena engine are token-identical to solo `Engine.generate` runs
under greedy sampling — continuous batching is a scheduling change, not a
model change.  (Identity requires request-independent budgets: `budget_abs`
here; with `budget_frac` solo budgets scale with each prompt while the
continuous plan is fixed, so outputs legitimately differ.)
"""
import pytest

pytestmark = pytest.mark.system

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import PolicyConfig
from repro.core.cache import SlotCache, clear_row, empty_cache, insert_row
from repro.models import ModelConfig, init_params
from repro.serving import (ContinuousConfig, ContinuousScheduler, Engine,
                           EngineConfig, pad_prompt)

CFG = ModelConfig(name="s", arch_type="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97,
                  dtype="float32", param_dtype="float32")

ECFG = EngineConfig(mode="uniform", policy=PolicyConfig("sliding_window"),
                    budget_abs=12, bucket=4, min_budget=4)
CCFG = ContinuousConfig(max_concurrency=3, prompt_bucket=8, max_prompt_len=24,
                        max_new_cap=8, sync_every=2)


def _params():
    return init_params(jax.random.PRNGKey(0), CFG)


# --------------------------------------------------------------- cache units
def test_insert_row_and_clear_row():
    arena = empty_cache(2, 4, 6, 2, 4, jnp.float32)
    row_cache = SlotCache(
        k=jnp.ones((2, 1, 6, 2, 4)), v=jnp.full((2, 1, 6, 2, 4), 2.0),
        pos=jnp.arange(6, dtype=jnp.int32).reshape(1, 1, 6).repeat(2, 0),
        score=jnp.full((2, 1, 6), 0.5))
    # traced row index: one executable serves every slot
    ins = jax.jit(insert_row)
    arena = ins(arena, row_cache, 2)
    assert np.asarray(arena.pos[:, 2]).tolist() == [list(range(6))] * 2
    assert (np.asarray(arena.pos[:, 0]) == -1).all()      # other rows empty
    assert (np.asarray(arena.k[:, 2]) == 1.0).all()
    arena = ins(arena, row_cache, 0)
    assert ins._cache_size() == 1                          # no retrace

    cleared = jax.jit(clear_row)(arena, 2)
    assert (np.asarray(cleared.pos[:, 2]) == -1).all()
    assert (np.asarray(cleared.score[:, 2]) == 0.0).all()
    assert np.asarray(cleared.pos[:, 0]).tolist() == [list(range(6))] * 2


def test_insert_rows_scatter_and_drop_sentinel():
    """Batched admission scatter: traced row-index vectors reuse one
    executable; indices >= batch (pad rows of a partial admit batch) are
    dropped, never clamped onto a real row."""
    from repro.core.cache import insert_rows
    B = 4
    arena = empty_cache(2, B, 6, 2, 4, jnp.float32)
    rows_cache = SlotCache(
        k=jnp.ones((2, 2, 6, 2, 4)), v=jnp.full((2, 2, 6, 2, 4), 2.0),
        pos=jnp.arange(6, dtype=jnp.int32).reshape(1, 1, 6).repeat(
            2, 0).repeat(2, 1) + jnp.asarray([[10], [20]], jnp.int32)[None],
        score=jnp.full((2, 2, 6), 0.5))
    ins = jax.jit(insert_rows)
    out = ins(arena, rows_cache, jnp.asarray([3, 1], jnp.int32))
    assert np.asarray(out.pos[:, 3, 0]).tolist() == [10, 10]
    assert np.asarray(out.pos[:, 1, 0]).tolist() == [20, 20]
    assert (np.asarray(out.pos[:, 0]) == -1).all()
    assert (np.asarray(out.pos[:, 2]) == -1).all()
    # different slots, same executable (traced indices)
    out = ins(arena, rows_cache, jnp.asarray([0, 2], jnp.int32))
    assert ins._cache_size() == 1
    # drop sentinel: row index B vanishes instead of clamping onto row B-1
    out = ins(arena, rows_cache, jnp.asarray([1, B], jnp.int32))
    assert np.asarray(out.pos[:, 1, 0]).tolist() == [10, 10]
    assert (np.asarray(out.pos[:, B - 1]) == -1).all()
    assert (np.asarray(out.k[:, B - 1]) == 0.0).all()


# ------------------------------------------------------------ token identity
def test_continuous_matches_solo_generate_greedy():
    """Mixed prompt lengths AND mixed max_new: every request's continuous
    output must equal its solo greedy `Engine.generate` output."""
    params = _params()
    sched = ContinuousScheduler(params, CFG, ECFG, CCFG)
    rng = np.random.default_rng(0)
    specs = [(5, 4), (11, 7), (16, 8), (3, 1), (9, 6), (20, 5)]
    prompts = [rng.integers(0, 97, (n,)).astype(np.int32) for n, _ in specs]
    rids = [sched.submit(p, max_new=mn)
            for p, (_, mn) in zip(prompts, specs)]
    done = {r.rid: r for r in sched.run_until_empty()}
    assert len(done) == len(specs)

    solo = Engine(params, CFG, ECFG)
    for rid, p, (_, mn) in zip(rids, prompts, specs):
        toks, valid = pad_prompt(p, CCFG.prompt_bucket)
        ref = solo.generate(tokens=toks, valid=valid,
                            max_new_tokens=mn).tokens[0]
        assert done[rid].tokens.tolist() == ref.tolist(), rid


def test_admission_never_retraces_decode_or_insert():
    """Fixed (max_concurrency, tier sizes) => one compiled fused block per
    block length, one compiled admit per (batch, prompt) bucket, serving the
    whole request stream."""
    params = _params()
    sched = ContinuousScheduler(params, CFG, ECFG, CCFG)
    rng = np.random.default_rng(1)
    for n in (5, 11, 16, 9, 20, 7, 13):
        sched.submit(rng.integers(0, 97, (n,)), max_new=4)
    done = sched.run_until_empty()
    assert len(done) == 7
    core = sched.core
    # fused decode blocks memoize per length, at most sync_every of them,
    # each compiled exactly once
    assert set(core._block_fns) <= set(range(1, CCFG.sync_every + 1))
    assert all(fn._cache_size() == 1 for fn in core._block_fns.values())
    assert core._clear_fn._cache_size() == 1
    # admit executables key on (pow2 admit batch, prompt bucket); admitting
    # into different slots (traced row indices) never retraced any of them
    for nb, p in core._admit_fns:
        assert nb in (1, 2, 4) and p % CCFG.prompt_bucket == 0
    assert all(fn._cache_size() == 1 for fn in core._admit_fns.values())
    # the whole 7-request stream amortized into few admission dispatches
    assert core.admit_dispatches < core.admitted == 7
    # fused blocks: strictly fewer dispatches than decode steps
    assert core.decode_dispatches < core.decode_steps


# ------------------------------------------------------- retirement/recycle
def test_retired_slot_is_recycled_and_cleared():
    params = _params()
    sched = ContinuousScheduler(params, CFG, ECFG, CCFG)
    rng = np.random.default_rng(2)
    n_slots = CCFG.max_concurrency
    # twice as many requests as slots forces recycling
    for i in range(2 * n_slots):
        sched.submit(rng.integers(0, 97, (8,)), max_new=2 + i % 3)
    done = sched.run_until_empty()
    assert len(done) == 2 * n_slots
    core = sched.core
    assert sorted(core._free) == list(range(n_slots))      # all recycled
    assert core.n_occupied == 0
    # retired rows were cleared on-device: every slot of every row is empty
    pos = np.asarray(core.state.dec.tiers[0].pos)
    assert (pos == -1).all()
    assert not np.asarray(core.state.dec.active).any()


def test_eos_retires_row_early():
    params = _params()
    prompt = np.random.default_rng(3).integers(0, 97, (10,)).astype(np.int32)
    # probe what greedy emits so we can use it as the EOS token
    toks, valid = pad_prompt(prompt, CCFG.prompt_bucket)
    probe = Engine(params, CFG, ECFG)
    ref = probe.generate(tokens=toks, valid=valid, max_new_tokens=8).tokens[0]
    eos = int(ref[2])

    ecfg = EngineConfig(mode=ECFG.mode, policy=ECFG.policy,
                        budget_abs=ECFG.budget_abs, bucket=ECFG.bucket,
                        min_budget=ECFG.min_budget, eos_token=eos)
    sched = ContinuousScheduler(params, CFG, ecfg, CCFG)
    rid = sched.submit(prompt, max_new=8)
    done = {r.rid: r for r in sched.run_until_empty()}
    out = done[rid].tokens
    hit = np.where(out == eos)[0]
    assert hit.size > 0
    assert (out[hit[0]:] == eos).all()          # post-EOS tail masked to EOS
    # the row actually stopped decoding: it spent fewer steps than max_new-1
    assert not np.asarray(sched.core.state.dec.active).any()


def test_continuous_squeeze_mode_serves():
    """Algorithm-1 tier plan calibrated on the first request, then reused."""
    params = _params()
    ecfg = EngineConfig(mode="squeeze", policy=PolicyConfig("sink_h2o"),
                        budget_abs=12, bucket=4, min_budget=4)
    sched = ContinuousScheduler(params, CFG, ecfg, CCFG)
    rng = np.random.default_rng(4)
    for n in (6, 14, 21):
        sched.submit(rng.integers(0, 97, (n,)), max_new=5)
    done = sched.run_until_empty()
    assert len(done) == 3
    plan = sched.core.plan
    assert plan is not None and plan.n_layers == 2
    for r in done:
        assert r.tokens.shape == (5,)
        assert (r.tokens >= 0).all() and (r.tokens < 97).all()
