"""Per-assigned-architecture smoke tests (reduced family variants).

For each of the 10 assigned archs (+ the paper's own 2): instantiate the
reduced config, run one forward and one train step on CPU, assert output
shapes and the absence of NaNs.  Decode-capable archs also run one
serve_step against a compacted cache.
"""

import pytest

pytestmark = pytest.mark.system

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ALL_ARCHS, get_reduced
from repro.core import PolicyConfig
from repro.models import forward, init_params
from repro.models.frontend import audio_stub_embeds, vision_stub_embeds
from repro.serving import Engine, EngineConfig
from repro.training import AdamWConfig, TrainBatch, init_opt_state, train_step

B, S = 2, 24


def _inputs(cfg, key):
    if cfg.frontend == "vision_stub":
        e, pos3 = vision_stub_embeds(key, B, S, cfg)
        return None, e, pos3
    if cfg.frontend == "audio_stub":
        return None, audio_stub_embeds(key, B, S, cfg), None
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return toks, None, None


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_smoke(arch):
    cfg = get_reduced(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks, embeds, pos = _inputs(cfg, jax.random.PRNGKey(1))
    out = forward(params, cfg, tokens=toks, embeds=embeds, positions=pos,
                  collect_kv=cfg.has_attention)
    assert out.logits.shape == (B, S, cfg.vocab_size)
    assert not np.isnan(np.asarray(out.logits)).any()
    if cfg.has_attention:
        assert not np.isnan(np.asarray(out.cos_sims)).any()
        assert (np.asarray(out.cos_sims) <= 1.0 + 1e-5).all()


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_smoke(arch):
    cfg = get_reduced(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = init_opt_state(params)
    toks, embeds, pos = _inputs(cfg, jax.random.PRNGKey(1))
    tgt = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size)
    batch = TrainBatch(tokens=toks, targets=tgt, embeds=embeds, positions=pos)
    params2, opt2, m = train_step(params, opt, batch, cfg,
                                  AdamWConfig(total_steps=10, warmup_steps=1))
    assert np.isfinite(float(m["loss"]))
    assert float(m["grad_norm"]) > 0
    # params actually changed (the unembed always receives gradient; the
    # embedding table doesn't when inputs are stub embeds)
    a = np.asarray(params["unembed"], np.float32)
    b = np.asarray(params2["unembed"], np.float32)
    assert not np.allclose(a, b)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_generate_smoke(arch):
    cfg = get_reduced(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, EngineConfig(
        mode="squeeze", policy=PolicyConfig("sliding_window"),
        budget_frac=0.5, max_new_tokens=4, bucket=4, min_budget=4))
    toks, embeds, pos = _inputs(cfg, jax.random.PRNGKey(1))
    r = eng.generate(tokens=np.asarray(toks) if toks is not None else None,
                     embeds=np.asarray(embeds) if embeds is not None else None,
                     positions=pos)
    assert r.tokens.shape == (B, 4)
    assert (r.tokens >= 0).all() and (r.tokens < cfg.vocab_size).all()
    if cfg.has_attention:
        assert r.plan.total > 0
