import os

# Tests run on the single real CPU device (the 512-device forcing is
# exclusively for the dry-run launcher, per the assignment).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
