"""Async serving front end (DESIGN.md §5, ISSUE-10).

The load-bearing property: moving the scheduler onto a background loop
thread — with the engine's double-buffered emission ring draining block
N-1 while block N computes — changes NOTHING about the tokens.  The
stream each `RequestHandle` yields is exactly `Request.tokens` from the
synchronous `run_to_completion` drive of the same trace, across dense /
hybrid families and contiguous / paged layouts.  Around that identity:
cancellation recycles rows (pool audit-clean), `close` drains or cancels
including mid-chunked-prefill, the host-side pool + radix tree survive
multi-threaded hammering, SLO records populate, and the HTTP front end
round-trips the whole stack on an ephemeral port.
"""
import json
import threading
import time
import urllib.error
import urllib.request

import pytest

import numpy as np

import jax

from repro.core import PolicyConfig
from repro.core.paging import PagePool, audit_pool_accounting
from repro.launch.http_api import encode_prompt, make_server
from repro.models import ModelConfig, init_params
from repro.serving import (ContinuousConfig, ContinuousScheduler,
                           EngineConfig, PrefixCache, ServingService)

DENSE = ModelConfig(name="s", arch_type="dense", n_layers=2, d_model=64,
                    n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97,
                    dtype="float32", param_dtype="float32")
HYBRID = ModelConfig(name="h", arch_type="hybrid", n_layers=4, d_model=64,
                     n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97,
                     ssm_state=8, ssm_expand=2, ssm_head_dim=32, ssm_chunk=8,
                     attn_period=2, dtype="float32", param_dtype="float32")

ECFG = EngineConfig(mode="uniform", policy=PolicyConfig("sliding_window"),
                    budget_abs=12, bucket=4, min_budget=4)


def _ccfg(**kw):
    base = dict(max_concurrency=3, prompt_bucket=8, max_prompt_len=24,
                max_new_cap=8, sync_every=2)
    base.update(kw)
    return ContinuousConfig(**base)


_PARAMS = {}


def _params(cfg):
    if cfg.name not in _PARAMS:
        _PARAMS[cfg.name] = init_params(jax.random.PRNGKey(0), cfg)
    return _PARAMS[cfg.name]


def _prompts(seed=1, lens=(6, 21, 5, 19, 9)):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, 97, (n,)).astype(np.int32) for n in lens]


def _sched(cfg, ccfg):
    return ContinuousScheduler(_params(cfg), cfg, ECFG, ccfg, seed=0)


def _sync_ref(cfg, ccfg, prompts, max_new=6):
    s = _sched(cfg, ccfg)
    for p in prompts:
        s.submit(p, max_new=max_new)
    return {r.rid: r.tokens for r in s.run_to_completion()}


# ------------------------------------------------- engine async-drain unit
@pytest.mark.system
def test_async_drain_engine_identity_and_overlap_counters():
    """Flipping `async_drain` re-times the device→host copies but cannot
    change a single token; the stall/drain counters account every block."""
    prompts = _prompts()
    ref = _sync_ref(DENSE, _ccfg(), prompts)
    s = _sched(DENSE, _ccfg())
    s.core.async_drain = True
    for p in prompts:
        s.submit(p, max_new=6)
    got = {r.rid: r.tokens for r in s.run_until_empty()}
    assert set(got) == set(ref)
    for k in ref:
        np.testing.assert_array_equal(ref[k], got[k])
    assert s.core.drained_blocks > 0
    assert s.core.drain_stall_s >= 0.0


# ------------------------------------------------------ streaming identity
@pytest.mark.system
@pytest.mark.parametrize("cfg", [DENSE, HYBRID], ids=["dense", "hybrid"])
@pytest.mark.parametrize("paged", [False, True], ids=["contig", "paged"])
def test_service_streaming_identity(cfg, paged):
    """The async service's per-token streams reassemble to exactly the
    synchronous scheduler's outputs (including EOS tail padding)."""
    kw = dict(page_size=4) if paged else {}
    prompts = _prompts()
    ref = _sync_ref(cfg, _ccfg(**kw), prompts)
    with ServingService(_sched(cfg, _ccfg(**kw))) as svc:
        handles = [svc.submit(p, max_new=6) for p in prompts]
        streams = [list(h.stream(timeout=120)) for h in handles]
        for h, st in zip(handles, streams):
            out = h.result(timeout=30)
            np.testing.assert_array_equal(np.asarray(st, np.int32), out)
            np.testing.assert_array_equal(out, ref[h.rid])
            assert not h.cancelled and h.error is None
    assert svc.metrics.completed == len(prompts)
    if paged:
        svc.engine.audit_pool(deep=True)


@pytest.mark.system
def test_service_slo_records_populated():
    prompts = _prompts(lens=(6, 21, 5))
    with ServingService(_sched(DENSE, _ccfg())) as svc:
        handles = [svc.submit(p, max_new=6) for p in prompts]
        for h in handles:
            h.result(timeout=120)
            slo = h.slo
            assert slo.n_tokens == 6
            assert slo.ttft_s > 0.0
            assert slo.queue_wait_s >= 0.0
            assert slo.e2e_s >= slo.ttft_s
            assert all(g >= 0.0 for g in slo.itl_s)
            assert slo.itl_p95_ms >= slo.itl_p50_ms >= 0.0
        snap = svc.metrics.snapshot()
    assert snap["completed"] == len(prompts)
    assert snap["tokens_streamed"] == 6 * len(prompts)
    assert snap["ttft_p95_ms"] >= snap["ttft_p50_ms"] > 0.0


@pytest.mark.system
def test_on_token_callback_streams_live():
    seen = []
    with ServingService(_sched(DENSE, _ccfg())) as svc:
        h = svc.submit(_prompts(lens=(7,))[0], max_new=6,
                       on_token=lambda t, ts: seen.append((t, ts)))
        out = h.result(timeout=120)
    # the callback sees every TIMED emission (EOS tail padding is pushed
    # untimed, so it reaches the stream but not the callback)
    toks = [t for t, _ in seen]
    np.testing.assert_array_equal(np.asarray(toks, np.int32),
                                  out[:len(toks)])
    assert all(b[1] >= a[1] for a, b in zip(seen, seen[1:]))


# ----------------------------------------------------------- cancellation
@pytest.mark.system
def test_cancel_mid_generation_recycles_slot():
    """Cancel from inside the token stream: the handle ends `cancelled`
    with a partial stream, the row recycles, and a follow-up request
    completes with the pool audit-clean."""
    ccfg = _ccfg(page_size=4)
    prompts = _prompts(lens=(9, 11))
    ref = _sync_ref(DENSE, ccfg, prompts, max_new=8)
    with ServingService(_sched(DENSE, ccfg)) as svc:
        h0 = svc.submit(prompts[0], max_new=8)
        h0._on_token = lambda t, ts: h0.cancel() \
            if len(h0._streamed) >= 2 else None
        streamed = list(h0.stream(timeout=120))
        assert h0.cancelled
        assert 2 <= len(streamed) < 8
        np.testing.assert_array_equal(h0.result(timeout=10), streamed)
        # pre-cancel tokens match the reference prefix (rid order is
        # submit order in both drives)
        np.testing.assert_array_equal(
            np.asarray(streamed, np.int32), ref[h0.rid][:len(streamed)])
        h1 = svc.submit(prompts[1], max_new=8)
        out = h1.result(timeout=120)
        np.testing.assert_array_equal(out, ref[1])
    assert svc.metrics.cancelled == 1 and svc.metrics.completed == 1
    assert svc.engine.cancellations == 1
    svc.engine.audit_pool(deep=True)


@pytest.mark.system
def test_cancel_queued_request_never_occupies_a_row():
    ccfg = _ccfg(max_concurrency=1)
    with ServingService(_sched(DENSE, ccfg)) as svc:
        hs = [svc.submit(p, max_new=8) for p in _prompts(lens=(9, 9, 9))]
        hs[2].cancel()                    # still queued behind 2 others
        assert hs[2].result(timeout=120).size < 8 or hs[2].cancelled
        assert hs[2].cancelled
        for h in hs[:2]:
            assert h.result(timeout=120).size == 8
    assert svc.metrics.cancelled == 1 and svc.metrics.completed == 2


# ------------------------------------------------------------------ close
@pytest.mark.system
def test_close_drain_false_cancels_everything_audit_clean():
    ccfg = _ccfg(page_size=4, chunked_prefill=True, chunk_len=8)
    svc = ServingService(_sched(DENSE, ccfg))
    hs = [svc.submit(p, max_new=8) for p in _prompts(lens=(21, 19, 23, 9))]
    time.sleep(0.3)                       # let some work start
    svc.close(drain=False)
    for h in hs:
        assert h.done                     # resolved: completed or cancelled
    assert svc.engine.n_occupied == 0 and svc.engine.n_pending == 0
    svc.engine.audit_pool(deep=True)
    with pytest.raises(RuntimeError):
        svc.submit(_prompts(lens=(5,))[0])


@pytest.mark.system
def test_cancel_pending_mid_chunked_prefill_audit_clean():
    """The deterministic mid-chunk case, driven synchronously: a long
    prompt parked in the staged chunked-prefill slot is cancelled between
    chunks; its pages free and the row serves the next request."""
    ccfg = _ccfg(page_size=4, chunked_prefill=True, chunk_len=8,
                 max_concurrency=2)
    s = _sched(DENSE, ccfg)
    s.submit(_prompts(lens=(5,))[0], max_new=2)   # calibrate the plan
    s.run_until_empty()                   # (chunk_ready needs a first
    rid = s.submit(_prompts(lens=(21,))[0], max_new=6)   # monolithic admit)
    s.poll()                              # begins the chunked prefill
    assert s.core.n_pending == 1
    assert s.cancel_request(rid)
    assert s.core.n_pending == 0
    s.core.audit_pool(deep=True)
    ref = _sync_ref(DENSE, ccfg, _prompts(lens=(9,)))
    s2rid = s.submit(_prompts(lens=(9,))[0], max_new=6)
    done = {r.rid: r.tokens for r in s.run_until_empty()}
    np.testing.assert_array_equal(done[s2rid], ref[0])
    s.core.audit_pool(deep=True)


# ------------------------------------------------------------ thread safety
@pytest.mark.fast
def test_pool_and_prefix_survive_concurrent_hammering():
    """Host-side stress on the shared lock: mutators allocate/free pool
    pages and grow/evict the radix tree while readers poll every stat.
    The books must balance afterwards."""
    pool = PagePool(n_pages=64)
    cache = PrefixCache(pool, page_size=4, n_layers=2)
    stop = threading.Event()
    errors = []
    held = [[] for _ in range(3)]

    def mutate(slot):
        rng = np.random.default_rng(slot)
        try:
            while not stop.is_set():
                if rng.random() < 0.5 and len(held[slot]) < 8:
                    ids = pool.try_alloc(2)
                    if ids is not None:
                        held[slot].append(ids)
                elif held[slot]:
                    pool.decref(held[slot].pop())
                toks = rng.integers(0, 17, (rng.integers(4, 17),))
                cache.insert(toks)
                m = cache.lookup(np.concatenate([toks, toks[:1]]))
                cache.release(m)
        except BaseException as e:      # pragma: no cover - failure path
            errors.append(e)

    def read():
        try:
            while not stop.is_set():
                assert 0 <= pool.n_free <= pool.n_pages - 1
                assert pool.n_resident >= 0
                assert cache.reclaimable_pages >= 0
                assert cache.resident_pages == cache.n_nodes * 2
                cache.page_ids()
        except BaseException as e:      # pragma: no cover - failure path
            errors.append(e)

    threads = [threading.Thread(target=mutate, args=(i,)) for i in range(3)]
    threads += [threading.Thread(target=read) for _ in range(2)]
    for t in threads:
        t.start()
    time.sleep(0.6)
    stop.set()
    for t in threads:
        t.join(10)
        assert not t.is_alive()
    assert not errors, errors
    audit_pool_accounting(
        pool, {"held": [i for h in held for i in h],
               "cache": cache.page_ids()})


@pytest.mark.system
def test_concurrent_submitters_and_metric_pollers():
    """Several client threads submit while another polls counters and
    metric snapshots — the single-loop-thread ownership plus the pool
    lock keep every output identical to the synchronous reference."""
    ccfg = _ccfg(page_size=4, prefix_cache=True)
    prompts = _prompts(lens=(6, 21, 5, 19, 9, 13))
    ref = _sync_ref(DENSE, ccfg, prompts)
    with ServingService(_sched(DENSE, ccfg)) as svc:
        out, errors = {}, []
        stop = threading.Event()

        def client(idx):
            try:
                h = svc.submit(prompts[idx], max_new=6)
                out[idx] = (h, np.asarray(list(h.stream(timeout=120)),
                                          np.int32))
            except BaseException as e:  # pragma: no cover - failure path
                errors.append(e)

        def poller():
            while not stop.is_set():
                svc.counters()
                svc.metrics.snapshot()

        ts = [threading.Thread(target=client, args=(i,))
              for i in range(len(prompts))]
        ts.append(threading.Thread(target=poller))
        for t in ts:
            t.start()
        for t in ts[:-1]:
            t.join(180)
        stop.set()
        ts[-1].join(10)
        assert not errors, errors
        # submission order is racy across threads, but greedy decode is
        # batch-composition invariant (the conformance matrix pins the
        # continuous path to solo generate), so each prompt's output
        # matches the sync reference regardless of admission order
        assert len(out) == len(ref)
        for idx, (h, toks) in out.items():
            np.testing.assert_array_equal(toks, ref[idx])
    svc.engine.audit_pool(deep=True)
    assert svc.metrics.completed == len(prompts)


# ------------------------------------------------------------------- HTTP
@pytest.mark.fast
def test_encode_prompt_validation():
    np.testing.assert_array_equal(encode_prompt([3, 1, 4], 97), [3, 1, 4])
    s = encode_prompt("hi", 97)
    np.testing.assert_array_equal(s, [ord("h") % 97, ord("i") % 97])
    for bad in ("", [], [[1, 2]], [98]):
        with pytest.raises(ValueError):
            encode_prompt(bad, 97)


@pytest.mark.system
def test_http_endpoint_end_to_end():
    """curl-equivalent round trip on an ephemeral port: non-streamed and
    SSE-streamed completions, /metrics SLO rows, /healthz, 400 on junk."""
    ccfg = _ccfg()
    prompt = [5, 9, 11, 2]
    ref = _sync_ref(DENSE, ccfg, [np.asarray(prompt, np.int32)], max_new=5)
    svc = ServingService(_sched(DENSE, ccfg))
    httpd = make_server(svc, port=0)
    port = httpd.server_address[1]
    threading.Thread(target=httpd.serve_forever, daemon=True).start()
    base = f"http://127.0.0.1:{port}"

    def post(path, obj):
        return urllib.request.Request(
            base + path, data=json.dumps(obj).encode(),
            headers={"Content-Type": "application/json"})

    try:
        with urllib.request.urlopen(base + "/healthz") as r:
            assert json.load(r)["status"] == "ok"
        with urllib.request.urlopen(
                post("/v1/completions",
                     {"prompt": prompt, "max_tokens": 5})) as r:
            obj = json.load(r)
        np.testing.assert_array_equal(obj["choices"][0]["tokens"], ref[0])
        assert obj["usage"]["completion_tokens"] == 5
        assert obj["slo"]["ttft_ms"] > 0.0
        # streamed chat completion: one SSE chunk per token, then the
        # finish_reason chunk, then [DONE]
        toks, done, fins = [], False, []
        with urllib.request.urlopen(
                post("/v1/chat/completions",
                     {"messages": [{"role": "user", "content": "hi"}],
                      "max_tokens": 4, "stream": True})) as r:
            assert r.headers["Content-Type"].startswith("text/event-stream")
            for line in r:
                line = line.decode().strip()
                if not line.startswith("data: "):
                    continue
                if line[6:] == "[DONE]":
                    done = True
                    break
                c = json.loads(line[6:])["choices"][0]
                fins.append(c["finish_reason"])
                if "token" in c:
                    toks.append(c["token"])
        assert done and len(toks) == 4 and fins[-1] == "length"
        assert all(f is None for f in fins[:-1])
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(post("/v1/completions", {"prompt": []}))
        assert ei.value.code == 400
        with urllib.request.urlopen(base + "/metrics") as r:
            rows = dict(line.split(" ", 1)
                        for line in r.read().decode().splitlines())
        assert float(rows["serving_completed"]) == 2
        assert float(rows["serving_ttft_p50_ms"]) > 0.0
        assert "serving_itl_p95_ms" in rows
        assert "serving_drain_stall_s" in rows
    finally:
        httpd.shutdown()
        httpd.server_close()
        svc.close(drain=True)
