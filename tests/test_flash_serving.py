"""Flash-decode in the serving hot path: parity on serving shapes.

Three layers of pinning (ISSUE 2 satellite):
  * ops-level: `flash_decode` (+`extra_kv` new-token fold) vs the pure-jnp
    `decode_attention_ref` oracle on serving shapes — GQA groups, batch > 1,
    padded/evicted slots (pos = -1), sliding windows, softcap.
  * module-level: `decode_attention(use_flash=True)` vs the dense einsum
    branch — same `DecodeAttnOut` (output, H2O slot mass, new KV).
  * engine-level: the `EngineConfig.use_flash_decode` flag is
    token-identity-preserving through `Engine.generate` AND the continuous
    persistent-arena path (the kernel sits inside `_attend_tier` under
    `lax.cond` + `lax.scan` + the fused decode block).
"""

import pytest

pytestmark = pytest.mark.kernels

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import PolicyConfig
from repro.kernels.flash_decode.ops import flash_decode
from repro.kernels.flash_decode.ref import decode_attention_ref
from repro.models import ModelConfig, init_params
from repro.models import attention as attn_lib
from repro.serving import (ContinuousConfig, ContinuousScheduler, Engine,
                           EngineConfig)

GLOBAL = 1 << 30

CFG = ModelConfig(name="s", arch_type="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97,
                  dtype="float32", param_dtype="float32")


def _params():
    return init_params(jax.random.PRNGKey(0), CFG)


# ---------------------------------------------------------------- ops level
@pytest.mark.parametrize("B,S,Hkv,G,hd,window,softcap", [
    (3, 12, 2, 2, 16, GLOBAL, None),      # serving arena: tiny S, batch 3
    (2, 24, 2, 4, 32, 10, None),          # GQA 4, sliding window
    (2, 16, 1, 8, 16, GLOBAL, 25.0),      # softcap
])
def test_flash_extra_kv_matches_ref(B, S, Hkv, G, hd, window, softcap):
    """flash_decode with the new-token fold == ref over [cache ++ new]."""
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    q = jax.random.normal(ks[0], (B, Hkv, G, hd))
    k = jax.random.normal(ks[1], (B, S, Hkv, hd))
    v = jax.random.normal(ks[2], (B, S, Hkv, hd))
    k_new = jax.random.normal(ks[3], (B, 1, Hkv, hd))
    v_new = jax.random.normal(ks[4], (B, 1, Hkv, hd))
    # half the slots evicted/empty, incl. a fully-empty row's worth
    pos = jnp.where(jax.random.bernoulli(ks[5], 0.5, (B, S)),
                    jax.random.randint(ks[5], (B, S), 0, 2 * S), -1)
    t = jnp.arange(B, dtype=jnp.int32) * 7 + S
    out, cols = flash_decode(q, k, v, pos, t, window, softcap=softcap,
                             extra_kv=(k_new, v_new), return_colsums=True)
    # oracle: the new token is one more always-valid slot at position t
    k_all = jnp.concatenate([k, k_new], axis=1)
    v_all = jnp.concatenate([v, v_new], axis=1)
    pos_all = jnp.concatenate([pos, t[:, None]], axis=1)
    ref_out, ref_cols = decode_attention_ref(q, k_all, v_all, pos_all, t,
                                             window, softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                               atol=2e-5)
    np.testing.assert_allclose(np.asarray(cols), np.asarray(ref_cols),
                               atol=2e-5)
    assert cols.shape == (B, Hkv, S + 1)


# ------------------------------------------------------------- module level
def test_decode_attention_flash_matches_dense():
    """use_flash=True reproduces the dense branch of decode_attention:
    output, H2O slot statistic and the new token's KV, including a retired
    row (t = -1, every cache slot masked)."""
    B, S = 3, 12
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    p = attn_lib.init_attn(ks[0], CFG)
    x = jax.random.normal(ks[1], (B, 1, CFG.d_model))
    t = jnp.asarray([7, 30, -1], jnp.int32)
    k = jax.random.normal(ks[2], (B, S, CFG.n_kv_heads, CFG.hd))
    v = jax.random.normal(ks[3], (B, S, CFG.n_kv_heads, CFG.hd))
    pos = jax.random.randint(ks[4], (B, S), -1, 32)
    for window in (GLOBAL, 8):
        dense = attn_lib.decode_attention(p, x, t, k, v, pos, CFG, window)
        flash = attn_lib.decode_attention(p, x, t, k, v, pos, CFG, window,
                                          use_flash=True)
        np.testing.assert_allclose(np.asarray(flash.out),
                                   np.asarray(dense.out), atol=1e-5)
        np.testing.assert_allclose(np.asarray(flash.slot_probs),
                                   np.asarray(dense.slot_probs), atol=1e-5)
        np.testing.assert_allclose(np.asarray(flash.k_new),
                                   np.asarray(dense.k_new), atol=1e-6)
    # retired row: all mass on the new token in both branches
    assert np.allclose(np.asarray(flash.slot_probs)[2, :, :S], 0.0)
    assert np.allclose(np.asarray(flash.slot_probs)[2, :, S], 1.0, atol=1e-5)


# ------------------------------------------------------------- engine level
def test_engine_flash_flag_token_identity():
    """Flagged Engine.generate (flash inside the fused scan blocks) emits
    the same greedy tokens as the dense path — batch > 1, GQA, budgeted
    arenas with empty (pos=-1) slots."""
    params = _params()
    prompts = np.random.default_rng(7).integers(
        0, 97, (2, 8)).astype(np.int32)
    base = dict(mode="uniform", policy=PolicyConfig("sink_h2o"),
                budget_abs=12, bucket=4, min_budget=4)
    dense = Engine(params, CFG, EngineConfig(**base)).generate(
        tokens=prompts, max_new_tokens=8)
    flash = Engine(params, CFG, EngineConfig(
        **base, use_flash_decode=True)).generate(
        tokens=prompts, max_new_tokens=8)
    assert flash.tokens.tolist() == dense.tokens.tolist()


def test_continuous_flash_flag_token_identity():
    """The flag holds through the continuous path too: fused blocks,
    admission inserts, on-device retirement."""
    params = _params()
    ccfg = ContinuousConfig(max_concurrency=2, prompt_bucket=8,
                            max_prompt_len=16, max_new_cap=6, sync_every=3)
    rng = np.random.default_rng(8)
    prompts = [rng.integers(0, 97, (n,)).astype(np.int32) for n in (5, 11, 9)]

    def run(use_flash):
        ecfg = EngineConfig(mode="uniform",
                            policy=PolicyConfig("sliding_window"),
                            budget_abs=12, bucket=4, min_budget=4,
                            use_flash_decode=use_flash)
        sched = ContinuousScheduler(params, CFG, ecfg, ccfg)
        rids = [sched.submit(p, max_new=5) for p in prompts]
        done = {r.rid: r for r in sched.run_until_empty()}
        return [done[rid].tokens.tolist() for rid in rids]

    assert run(False) == run(True)
