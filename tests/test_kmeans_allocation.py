"""Unit + property tests for KMeans layer clustering and Algorithm-1 budgets."""

import pytest

pytestmark = pytest.mark.fast

import numpy as np

from _hypothesis_compat import given, settings, st

from repro.core.allocation import (allocate, allocate_zigzag, page_quota,
                                   plan_page_quota, plan_pool_pages,
                                   uniform_plan)
from repro.core.kmeans import kmeans_1d, kmeans_1d_jax


def test_kmeans_three_groups():
    x = np.concatenate([np.full(3, 0.2), np.full(10, 0.55), np.full(19, 0.93)])
    lab, cen = kmeans_1d(x, k=3)
    assert (lab[:3] == 0).all() and (lab[3:13] == 1).all() and (lab[13:] == 2).all()
    assert cen[0] < cen[1] < cen[2]


def test_kmeans_jax_matches_numpy():
    rng = np.random.RandomState(0)
    for _ in range(5):
        x = rng.rand(32)
        l1, _ = kmeans_1d(x)
        l2, _ = kmeans_1d_jax(x)
        assert (np.asarray(l2) == l1).all()


def test_kmeans_degenerate_inputs():
    lab, _ = kmeans_1d(np.array([0.5, 0.5, 0.5, 0.5]), k=3)
    assert lab.shape == (4,)
    lab2, _ = kmeans_1d(np.array([0.1, 0.9]), k=3)   # n < k
    assert lab2.shape == (2,)


@settings(max_examples=200, deadline=None)
@given(
    n=st.integers(3, 96),
    b_init=st.integers(64, 8192),
    p=st.floats(0.05, 0.95),
    seed=st.integers(0, 1000),
)
def test_allocation_conserves_budget(n, b_init, p, seed):
    """Algorithm 1 invariant: total budget never grows, slack bounded by
    bucket quantization."""
    rng = np.random.RandomState(seed)
    cos = np.clip(rng.normal(0.7, 0.2, n), 0, 1)
    plan = allocate(cos, b_init, p=p, bucket=16, min_budget=16)
    assert plan.n_layers == n
    assert plan.total <= n * b_init + n * 16          # min_budget floor slack
    # every layer got one of exactly two budgets
    assert set(plan.budgets.tolist()) <= {plan.b_small, plan.b_big}


@settings(max_examples=100, deadline=None)
@given(n=st.integers(3, 96), seed=st.integers(0, 100))
def test_allocation_squeezes_highest_similarity(n, seed):
    """G3 (highest cosine sim) layers must get the SMALL budget."""
    rng = np.random.RandomState(seed)
    cos = np.clip(rng.normal(0.5, 0.25, n), 0, 1)
    plan = allocate(cos, 1024, p=0.3, bucket=16)
    if plan.p == 1.0:      # degenerate clustering fallback
        return
    small_sims = [cos[i] for i, s in enumerate(plan.is_small) if s]
    big_sims = [cos[i] for i, s in enumerate(plan.is_small) if not s]
    assert min(small_sims) >= max(big_sims) - 1e-9
    assert plan.b_small <= plan.b_big


def test_uniform_plan():
    plan = uniform_plan(8, 512)
    assert plan.total == 8 * 512
    assert plan.n_small == 0
    assert plan.n_tiers == 1
    assert plan.layer_tiers() == ((512, tuple(range(8))),)


def test_allocate_is_two_tier_special_case():
    """`allocate` fills the same N-tier record zigzag does: 2 tiers,
    exact slack bookkeeping, legacy views consistent with tier fields."""
    rng = np.random.RandomState(7)
    cos = np.clip(rng.normal(0.6, 0.25, 12), 0, 1)
    plan = allocate(cos, 256, p=0.35, bucket=16, min_budget=16)
    if plan.p == 1.0:
        return
    assert plan.n_tiers == 2
    assert plan.tier_budgets == (plan.b_big, plan.b_small)
    assert plan.tier_counts == (plan.n_big, plan.n_small)
    assert plan.total + plan.slack == 12 * 256
    big, small = plan.layer_order()
    tiers = plan.layer_tiers()
    assert tiers[0][1] == big and tiers[1][1] == small


def test_zigzag_deterministic_invariants():
    """Deterministic twin of the zigzag property test (runs without the
    hypothesis extra): conservation, ordering, merge/split bounds."""
    for n, b_init, n_tiers, bucket, seed in [
            (8, 128, 4, 16, 0), (24, 256, 4, 16, 1), (12, 200, 3, 4, 2),
            (32, 512, 8, 32, 3), (5, 96, 5, 1, 4), (16, 64, 2, 16, 5)]:
        rng = np.random.RandomState(seed)
        cos = np.clip(rng.normal(0.6, 0.25, n), 0, 1)
        plan = allocate_zigzag(cos, b_init, n_tiers=n_tiers, bucket=bucket,
                               min_budget=bucket)
        assert plan.total + plan.slack == n * b_init, (n, b_init, n_tiers)
        assert 0 <= plan.slack < bucket or plan.n_tiers == 1
        bt = list(plan.tier_budgets)
        assert bt == sorted(bt, reverse=True) and len(set(bt)) == len(bt)
        assert all(c > 0 for c in plan.tier_counts)
        assert sum(plan.tier_counts) == n
        assert plan.n_tiers <= n_tiers + 1
        u = np.clip(1.0 - cos, 0.0, None)
        ordered = plan.budgets[np.argsort(-u, kind="stable")]
        assert (np.diff(ordered) <= 0).all()


def test_zigzag_degenerate_cases():
    # flat sensitivity / tiny models fall back to the uniform plan
    assert allocate_zigzag(np.full(8, 0.5), 128).n_tiers == 1
    assert allocate_zigzag([0.1, 0.9], 128, n_tiers=4).n_tiers == 1
    assert allocate_zigzag([0.3], 128, n_tiers=1).n_tiers == 1
    # min_budget floor dominating the total: single tier AT the floor,
    # negative slack mirrors `allocate`'s floor overshoot
    plan = allocate_zigzag(np.linspace(0.1, 0.9, 8), 8, n_tiers=4,
                           bucket=16, min_budget=16)
    assert plan.n_tiers == 1 and plan.tier_budgets == (16,)
    assert plan.total + plan.slack == 8 * 8
    assert plan.slack < 0


def test_allocate_p1_is_uniform():
    plan = allocate(np.linspace(0, 1, 10), 256, p=1.0)
    assert plan.b_small == plan.b_big == 256


@settings(max_examples=60, deadline=None)
@given(n=st.integers(4, 96), seed=st.integers(0, 200),
       bucket=st.sampled_from([1, 4, 16, 32]),
       min_budget=st.sampled_from([1, 16, 64]))
def test_allocate_jax_matches_host(n, seed, bucket, min_budget):
    """On-device Algorithm 1 == host Algorithm 1, INCLUDING the bucket
    quantization and min_budget floor (the in-graph parity contract)."""
    import jax
    from repro.core.allocation import allocate_jax

    rng = np.random.RandomState(seed)
    cos = np.clip(rng.normal(0.6, 0.25, n), 0, 1)
    budgets, is_small = jax.jit(
        lambda c: allocate_jax(c, 1024, p=0.3, bucket=bucket,
                               min_budget=min_budget))(cos)
    budgets = np.asarray(budgets)
    is_small = np.asarray(is_small)
    host = allocate(cos, 1024, p=0.3, bucket=bucket, min_budget=min_budget)
    if host.p == 1.0:          # host degenerated -> jax must too
        assert not is_small.any()
        assert (budgets == 1024).all()
    else:
        assert (np.asarray(host.is_small) == is_small).all()
        assert (budgets == host.budgets).all()
        # host bookkeeping pins the same totals the device arithmetic hit
        assert int(budgets.sum()) + host.slack == n * 1024


@settings(max_examples=150, deadline=None)
@given(n=st.integers(2, 96), b_init=st.integers(64, 4096),
       n_tiers=st.integers(2, 8), seed=st.integers(0, 500),
       bucket=st.sampled_from([1, 4, 16, 32]))
def test_zigzag_conserves_budget_any_n_tiers(n, b_init, n_tiers, seed,
                                             bucket):
    """N-tier invariants for arbitrary n_tiers: exact bucket-unit
    conservation, non-increasing tier budgets, non-empty tiers, and
    monotone sensitivity -> budget mapping."""
    rng = np.random.RandomState(seed)
    cos = np.clip(rng.normal(0.6, 0.25, n), 0, 1)
    plan = allocate_zigzag(cos, b_init, n_tiers=n_tiers, bucket=bucket,
                           min_budget=bucket)
    assert plan.n_layers == n
    # conservation is exact modulo the sub-bucket remainder
    assert plan.total + plan.slack == n * b_init
    assert 0 <= plan.slack < bucket or plan.n_tiers == 1
    bt = list(plan.tier_budgets)
    assert bt == sorted(bt, reverse=True)
    assert len(set(bt)) == len(bt)            # merged: budgets distinct
    counts = plan.tier_counts
    assert all(c > 0 for c in counts)         # no empty tier survives
    assert sum(counts) == n
    assert plan.n_tiers <= n_tiers + 1        # leftover pass splits <= 1 tier
    # more sensitive (lower cos) layers never get a smaller budget
    budgets = plan.budgets
    u = np.clip(1.0 - cos, 0.0, None)
    order = np.argsort(-u, kind="stable")
    ordered = budgets[order]
    assert (np.diff(ordered) <= 0).all()


@settings(max_examples=100, deadline=None)
@given(budget=st.integers(1, 4096), psize=st.sampled_from([1, 3, 4, 16, 64]))
def test_page_quota_bounds(budget, psize):
    """ceil-division bounds: the quota covers the budget, never by more
    than one page, and grows monotonically with the budget."""
    q = page_quota(budget, psize)
    assert (q - 1) * psize < budget <= q * psize
    assert page_quota(budget + 1, psize) >= q


@settings(max_examples=80, deadline=None)
@given(n=st.integers(2, 32), b_init=st.integers(32, 1024),
       n_tiers=st.integers(1, 5), seed=st.integers(0, 100),
       psize=st.sampled_from([3, 4, 16]), batch=st.integers(1, 16),
       overcommit=st.floats(0.05, 2.0))
def test_plan_pool_pages_invariants(n, b_init, n_tiers, seed, psize, batch,
                                    overcommit):
    """Pool sizing invariants: the row region scales monotonically with
    overcommit but never drops below ONE full row quota (liveness floor),
    and the per-row quota covers every layer's tier budget."""
    rng = np.random.RandomState(seed)
    cos = np.clip(rng.normal(0.6, 0.25, n), 0, 1)
    plan = allocate_zigzag(cos, b_init, n_tiers=n_tiers, bucket=4,
                           min_budget=4)
    quota = plan_page_quota(plan, psize)
    assert quota == sum(page_quota(b, psize) for b in plan.budgets)
    total = plan_pool_pages(plan, batch, psize, overcommit=overcommit)
    # liveness floor: 1 null page + at least one full row quota
    assert total >= 1 + quota
    # monotone in overcommit and in prefix headroom
    assert plan_pool_pages(plan, batch, psize,
                           overcommit=min(2.0, overcommit * 2)) >= total
    assert plan_pool_pages(plan, batch, psize, prefix_pages=7,
                           overcommit=overcommit) == total + 7
    # worst-case sizing covers every row at quota
    full = plan_pool_pages(plan, batch, psize, overcommit=1.0)
    assert full >= 1 + batch * quota
