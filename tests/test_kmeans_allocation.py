"""Unit + property tests for KMeans layer clustering and Algorithm-1 budgets."""

import pytest

pytestmark = pytest.mark.fast

import numpy as np

from _hypothesis_compat import given, settings, st

from repro.core.allocation import allocate, uniform_plan
from repro.core.kmeans import kmeans_1d, kmeans_1d_jax


def test_kmeans_three_groups():
    x = np.concatenate([np.full(3, 0.2), np.full(10, 0.55), np.full(19, 0.93)])
    lab, cen = kmeans_1d(x, k=3)
    assert (lab[:3] == 0).all() and (lab[3:13] == 1).all() and (lab[13:] == 2).all()
    assert cen[0] < cen[1] < cen[2]


def test_kmeans_jax_matches_numpy():
    rng = np.random.RandomState(0)
    for _ in range(5):
        x = rng.rand(32)
        l1, _ = kmeans_1d(x)
        l2, _ = kmeans_1d_jax(x)
        assert (np.asarray(l2) == l1).all()


def test_kmeans_degenerate_inputs():
    lab, _ = kmeans_1d(np.array([0.5, 0.5, 0.5, 0.5]), k=3)
    assert lab.shape == (4,)
    lab2, _ = kmeans_1d(np.array([0.1, 0.9]), k=3)   # n < k
    assert lab2.shape == (2,)


@settings(max_examples=200, deadline=None)
@given(
    n=st.integers(3, 96),
    b_init=st.integers(64, 8192),
    p=st.floats(0.05, 0.95),
    seed=st.integers(0, 1000),
)
def test_allocation_conserves_budget(n, b_init, p, seed):
    """Algorithm 1 invariant: total budget never grows, slack bounded by
    bucket quantization."""
    rng = np.random.RandomState(seed)
    cos = np.clip(rng.normal(0.7, 0.2, n), 0, 1)
    plan = allocate(cos, b_init, p=p, bucket=16, min_budget=16)
    assert plan.n_layers == n
    assert plan.total <= n * b_init + n * 16          # min_budget floor slack
    # every layer got one of exactly two budgets
    assert set(plan.budgets.tolist()) <= {plan.b_small, plan.b_big}


@settings(max_examples=100, deadline=None)
@given(n=st.integers(3, 96), seed=st.integers(0, 100))
def test_allocation_squeezes_highest_similarity(n, seed):
    """G3 (highest cosine sim) layers must get the SMALL budget."""
    rng = np.random.RandomState(seed)
    cos = np.clip(rng.normal(0.5, 0.25, n), 0, 1)
    plan = allocate(cos, 1024, p=0.3, bucket=16)
    if plan.p == 1.0:      # degenerate clustering fallback
        return
    small_sims = [cos[i] for i, s in enumerate(plan.is_small) if s]
    big_sims = [cos[i] for i, s in enumerate(plan.is_small) if not s]
    assert min(small_sims) >= max(big_sims) - 1e-9
    assert plan.b_small <= plan.b_big


def test_uniform_plan():
    plan = uniform_plan(8, 512)
    assert plan.total == 8 * 512
    assert plan.n_small == 0


def test_allocate_p1_is_uniform():
    plan = allocate(np.linspace(0, 1, 10), 256, p=1.0)
    assert plan.b_small == plan.b_big == 256


@settings(max_examples=60, deadline=None)
@given(n=st.integers(4, 96), seed=st.integers(0, 200))
def test_allocate_jax_matches_host(n, seed):
    """On-device Algorithm 1 == host Algorithm 1 (pre-quantization)."""
    import jax
    from repro.core.allocation import allocate_jax

    rng = np.random.RandomState(seed)
    cos = np.clip(rng.normal(0.6, 0.25, n), 0, 1)
    budgets, is_small = jax.jit(
        lambda c: allocate_jax(c, 1024, p=0.3))(cos)
    budgets = np.asarray(budgets)
    is_small = np.asarray(is_small)
    # conservation (exact, pre-bucketing)
    assert abs(budgets.sum() - n * 1024) < 1.0
    host = allocate(cos, 1024, p=0.3, bucket=1, min_budget=1)
    if host.p == 1.0:          # host degenerated -> jax must too
        assert not is_small.any()
    else:
        assert (np.asarray(host.is_small) == is_small).all()
