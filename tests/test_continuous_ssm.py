"""Family-agnostic continuous batching: SSM / hybrid rows.

The load-bearing property mirrors tests/test_continuous.py: a recurrent
model's per-request outputs through the persistent-arena engine —
admission → fused decode blocks → retirement → slot recycling — are
token-identical to solo `Engine.generate` runs under greedy sampling.  The
recurrent state is the degenerate fixed-cost budget tier, so the same
scheduling machinery must be invisible to it.
"""
import pytest

pytestmark = pytest.mark.system

import numpy as np

import jax

from repro.configs import ALL_ARCHS, get_reduced
from repro.core import PolicyConfig
from repro.models import ModelConfig, init_params
from repro.serving import (ContinuousConfig, ContinuousEngine,
                           ContinuousScheduler, Engine, EngineConfig,
                           continuous_capability, pad_prompt)

HYBRID = ModelConfig(name="h", arch_type="hybrid", n_layers=4, d_model=64,
                     n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97,
                     ssm_state=8, ssm_expand=2, ssm_head_dim=32, ssm_chunk=8,
                     attn_period=2, dtype="float32", param_dtype="float32")
SSM = ModelConfig(name="m", arch_type="ssm", n_layers=2, d_model=64,
                  n_heads=1, n_kv_heads=1, head_dim=32, d_ff=0, vocab_size=97,
                  ssm_state=8, ssm_expand=2, ssm_head_dim=32, ssm_chunk=8,
                  dtype="float32", param_dtype="float32")

ECFG = EngineConfig(mode="uniform", policy=PolicyConfig("sliding_window"),
                    budget_abs=12, bucket=4, min_budget=4)
CCFG = ContinuousConfig(max_concurrency=3, prompt_bucket=8, max_prompt_len=24,
                        max_new_cap=8, sync_every=2)


def _params(cfg):
    return init_params(jax.random.PRNGKey(0), cfg)


@pytest.mark.parametrize("cfg", [HYBRID, SSM], ids=["hybrid", "ssm"])
def test_recurrent_continuous_matches_solo_generate_greedy(cfg):
    """Mixed prompt lengths AND mixed max_new, twice as many requests as
    slots (forces recycling of recurrent-state rows): every request's
    continuous output must equal its solo greedy `Engine.generate` output."""
    params = _params(cfg)
    sched = ContinuousScheduler(params, cfg, ECFG, CCFG)
    rng = np.random.default_rng(0)
    specs = [(5, 4), (11, 7), (16, 8), (3, 1), (9, 6), (20, 5)]
    prompts = [rng.integers(0, 97, (n,)).astype(np.int32) for n, _ in specs]
    rids = [sched.submit(p, max_new=mn)
            for p, (_, mn) in zip(prompts, specs)]
    done = {r.rid: r for r in sched.run_until_empty()}
    assert len(done) == len(specs)

    solo = Engine(params, cfg, ECFG)
    for rid, p, (_, mn) in zip(rids, prompts, specs):
        toks, valid = pad_prompt(p, CCFG.prompt_bucket)
        ref = solo.generate(tokens=toks, valid=valid,
                            max_new_tokens=mn).tokens[0]
        assert done[rid].tokens.tolist() == ref.tolist(), rid


def test_recycled_recurrent_row_is_cleared_and_reused():
    """Retirement zeroes a row's SSD/conv state on device, the frozen-row
    discipline keeps it zero across subsequent decode blocks, and a request
    admitted into the recycled slot decodes exactly as if the slot were
    fresh."""
    cfg = HYBRID
    params = _params(cfg)
    sched = ContinuousScheduler(params, cfg, ECFG, CCFG)
    rng = np.random.default_rng(2)
    n_slots = CCFG.max_concurrency
    prompts = [rng.integers(0, 97, (8,)).astype(np.int32)
               for _ in range(2 * n_slots)]
    rids = [sched.submit(p, max_new=2 + i % 3)
            for i, p in enumerate(prompts)]
    done = {r.rid: r for r in sched.run_until_empty()}
    assert len(done) == 2 * n_slots
    core = sched.core
    assert sorted(core._free) == list(range(n_slots))      # all recycled
    # cleared recurrent rows stayed exactly zero (no sentinel can hide a
    # stale state — the decode step must freeze inactive rows)
    assert (np.asarray(core.state.dec.ssm_state) == 0).all()
    assert (np.asarray(core.state.dec.conv_state) == 0).all()
    assert (np.asarray(core.state.dec.tiers[0].pos) == -1).all()
    # reuse correctness: the second wave of requests (which landed on
    # recycled rows) still matches solo generate
    solo = Engine(params, cfg, ECFG)
    for i in (n_slots, n_slots + 1):
        toks, valid = pad_prompt(prompts[i], CCFG.prompt_bucket)
        ref = solo.generate(tokens=toks, valid=valid,
                            max_new_tokens=2 + i % 3).tokens[0]
        assert done[rids[i]].tokens.tolist() == ref.tolist(), i


def test_recurrent_admission_never_retraces():
    """Traced row indices hold for the recurrent-state scatters too: one
    compiled admit per (batch, prompt) bucket, one fused block per length,
    across a stream that recycles every slot."""
    cfg = SSM
    params = _params(cfg)
    sched = ContinuousScheduler(params, cfg, ECFG, CCFG)
    rng = np.random.default_rng(1)
    for n in (5, 11, 16, 9, 20, 7, 13):
        sched.submit(rng.integers(0, 97, (n,)), max_new=4)
    done = sched.run_until_empty()
    assert len(done) == 7
    core = sched.core
    assert all(fn._cache_size() == 1 for fn in core._block_fns.values())
    assert all(fn._cache_size() == 1 for fn in core._admit_fns.values())
    assert core._clear_fn._cache_size() == 1
    assert core.admit_dispatches < core.admitted == 7


@pytest.mark.parametrize("arch", ["olmo-1b", "mixtral-8x22b", "qwen2-vl-7b",
                                  "musicgen-large", "mamba2-1.3b",
                                  "zamba2-2.7b"])
def test_every_family_serves_continuously(arch):
    """One representative per architecture family (dense, moe, vlm, audio,
    ssm, hybrid): the capability report admits it, and an actual admission →
    fused decode → retirement round-trip completes with sane tokens."""
    cfg = get_reduced(arch)
    cap = continuous_capability(cfg)
    assert cap.ok, (arch, cap.reason)
    params = init_params(jax.random.PRNGKey(0), cfg)
    ecfg = EngineConfig(mode="uniform", policy=PolicyConfig("sliding_window"),
                        budget_abs=8, bucket=4, min_budget=4)
    ccfg = ContinuousConfig(max_concurrency=2, prompt_bucket=8,
                            max_prompt_len=16, max_new_cap=4, sync_every=2)
    sched = ContinuousScheduler(params, cfg, ecfg, ccfg)
    rng = np.random.default_rng(0)
    for n in (6, 11):
        sched.submit(rng.integers(0, cfg.vocab_size, (n,)), max_new=3)
    done = sched.run_until_empty()
    assert len(done) == 2
    for r in done:
        assert r.tokens.shape == (3,)
        assert (r.tokens >= 0).all() and (r.tokens < cfg.vocab_size).all()
    assert sched.core.n_occupied == 0


def test_all_config_families_admit_or_raise_precisely():
    """Config-driven sweep of the whole registry: every reduced config
    either reports admissible (and `ContinuousEngine` construction agrees)
    or `ContinuousEngine` raises exactly the capability's reason."""
    for arch in ALL_ARCHS:
        cfg = get_reduced(arch)
        cap = continuous_capability(cfg)
        if cap.ok:
            continue     # construction cost covered by the family test above
        import re
        with pytest.raises(ValueError, match=re.escape(cap.reason[:40])):
            ContinuousEngine(None, cfg, ECFG, CCFG)
