"""Unit tests for the paged-KV building blocks (DESIGN.md §3).

Host allocator lifecycle (alloc/free/refcount/eviction), the page-count
bounds that let sequence-wise squeezing release pages, the radix-tree
prefix cache (partial matches on page boundaries, pinning, LRU leaf
eviction, best-effort inserts), the canonical slot sort the ctx-prefill
admission relies on, and the device gather/scatter round trip — including
page sizes that do NOT divide the arena budget.
"""
import pytest

pytestmark = pytest.mark.fast

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.cache import SlotCache, sort_slots
from repro.core.allocation import page_quota, plan_pool_pages, uniform_plan
from repro.core.paging import (KVPool, PagePool, clear_tier_row, empty_pool,
                               empty_paged_tier, gather_layer_pages,
                               insert_tier_rows, pages_for, pages_needed,
                               scatter_rows_to_pages)
from repro.serving.prefix import PrefixCache


# ------------------------------------------------------------- page counting
def test_pages_for_and_needed_bounds():
    assert pages_for(16, 4) == 4
    assert pages_for(17, 4) == 5          # no divisibility requirement
    assert pages_for(1, 8) == 1
    # a request with t prompt slots + max_new-1 decode writes can never
    # touch a slot past min(budget, t + max_new - 1)
    assert pages_needed(t=5, budget=32, max_new=4, page_size=4) == 2  # 8 slots
    assert pages_needed(t=30, budget=32, max_new=8, page_size=4) == 8  # capped
    assert pages_needed(t=0, budget=32, max_new=1, page_size=4) == 1
    # short request in a big arena: far fewer pages than the budget ceiling
    assert pages_needed(t=4, budget=128, max_new=2, page_size=16) == 1
    assert pages_for(128, 16) == 8


def test_plan_pool_pages_covers_worst_case():
    plan = uniform_plan(n_layers=4, b_init=24)
    # per row: every layer's budget in pages; +1 null page
    per_row = 4 * page_quota(24, 8)
    assert plan_pool_pages(plan, batch=3, page_size=8) == 1 + 3 * per_row
    assert plan_pool_pages(plan, batch=3, page_size=8,
                           prefix_pages=10) == 1 + 3 * per_row + 10


# ------------------------------------------------------------ host allocator
def test_page_pool_alloc_free_refcount():
    pool = PagePool(8)                    # pages 1..7 usable, 0 = null
    assert pool.sentinel == 8
    assert pool.n_free == 7 and pool.n_resident == 0
    a = pool.alloc(3)
    assert sorted(a.tolist()) == [1, 2, 3]
    assert pool.n_resident == 3
    pool.incref(a[:1])                    # share page 1
    pool.free(a)                          # rows drop their refs
    assert pool.n_resident == 1           # page 1 still held by the share
    pool.decref(a[:1])
    assert pool.n_resident == 0 and pool.n_free == 7
    b = pool.alloc(7)                     # the freed pages recycle
    assert sorted(b.tolist()) == list(range(1, 8))
    with pytest.raises(RuntimeError, match="page pool exhausted"):
        pool.alloc(1)
    pool.free(b)
    with pytest.raises(RuntimeError, match="double free"):
        pool.decref(b[:1])                # survives `python -O`


def test_page_pool_evict_hook_under_pressure():
    pool = PagePool(5)
    held = [pool.alloc(1) for _ in range(4)]

    def evict():
        if held:
            pool.decref(held.pop())
            return True
        return False

    pool.evict_hook = evict
    got = pool.alloc(2)                   # forces two evictions
    assert got.size == 2 and len(held) == 2
    assert pool.try_alloc(99) is None     # beyond any eviction's reach


# ------------------------------------------------------------- prefix cache
def _mk_cache(n_pages=64, psize=4, n_layers=2):
    pool = PagePool(n_pages)
    return pool, PrefixCache(pool, psize, n_layers)


def test_prefix_insert_lookup_partial_match_on_page_boundary():
    pool, pc = _mk_cache()
    toks = np.arange(100, 111, dtype=np.int32)          # 11 tokens, psize 4
    created = pc.insert(toks, max_chunks=len(toks) // 4)
    assert [c for c, _ in created] == [0, 1]            # 2 full chunks cached
    assert pc.n_nodes == 2 and pool.n_resident == 4     # 2 nodes x 2 layers

    # identical prompt: lookup matches down to the page boundary, capped so
    # at least one suffix token remains
    m = pc.lookup(toks)
    assert m.matched == 8 and m.ids.shape == (2, 2)
    pc.release(m)
    # exactly page-aligned prompt: the cap keeps the last chunk as suffix
    m = pc.lookup(toks[:8])
    assert m.matched == 4
    pc.release(m)
    # diverging token inside chunk 2 of a longer prompt: matches chunks 0-1
    other = np.concatenate([toks[:8], [7, 7, 7, 7, 7]]).astype(np.int32)
    m = pc.lookup(other)
    assert m.matched == 8
    pc.release(m)
    # divergence inside chunk 0: no match
    assert pc.lookup(other[::-1]).matched == 0


def test_prefix_insert_dedupes_and_extends():
    pool, pc = _mk_cache()
    a = np.arange(0, 12, dtype=np.int32)
    b = np.concatenate([a[:8], np.arange(50, 58)]).astype(np.int32)  # shares 2
    assert len(pc.insert(a, max_chunks=3)) == 3
    created = pc.insert(b, max_chunks=4)
    assert [c for c, _ in created] == [2, 3]   # only the divergent tail
    assert pc.n_nodes == 5
    # re-inserting an identical prompt creates nothing (same-burst dedup)
    assert pc.insert(a, max_chunks=3) == []


def test_prefix_lru_leaf_eviction_respects_pins():
    pool, pc = _mk_cache(n_pages=9, psize=4, n_layers=2)   # 4 nodes capacity
    a = np.arange(0, 9, dtype=np.int32)
    b = np.arange(100, 109, dtype=np.int32)
    pc.insert(a, max_chunks=2)
    pc.insert(b, max_chunks=2)                # pool now full (4 nodes)
    ma = pc.lookup(a)                         # pin a's path, refresh its LRU
    assert ma.matched == 8
    # allocation pressure: the unpinned LRU LEAF falls — b's deepest node
    got = pool.alloc(2)
    assert got.size == 2
    assert pc.evictions == 1 and pc.n_nodes == 3
    mb = pc.lookup(b)
    assert mb.matched == 4                    # b lost its leaf, kept chunk 0
    pc.release(ma)
    # with a released (and b's survivor pinned), pressure strips a's leaf
    pool.alloc(2)
    assert pc.evictions == 2
    m = pc.lookup(a)
    assert m.matched == 4
    pc.release(m)
    pc.release(mb)


def test_prefix_insert_best_effort_when_pool_full():
    pool, pc = _mk_cache(n_pages=5, psize=4, n_layers=2)   # 2 nodes capacity
    toks = np.arange(0, 17, dtype=np.int32)
    created = pc.insert(toks, max_chunks=4)
    assert [c for c, _ in created] == [0, 1]   # caches a prefix, then stops
    assert pc.n_nodes == 2


# ------------------------------------------------------- canonical slot sort
def test_sort_slots_moves_empties_to_tail():
    pos = jnp.asarray([[[3, -1, 0, -1, 8, 1]]], jnp.int32)     # [1, 1, 6]
    k = jnp.arange(6, dtype=jnp.float32).reshape(1, 1, 6, 1, 1)
    score = jnp.asarray([[[.3, 0., .0, 0., .8, .1]]], jnp.float32)
    out = sort_slots(SlotCache(k=k, v=k, pos=pos, score=score))
    assert np.asarray(out.pos[0, 0]).tolist() == [0, 1, 3, 8, -1, -1]
    # k/v/score moved with their slots
    assert np.asarray(out.k[0, 0, :, 0, 0]).tolist() == [2., 5., 0., 4., 1., 3.]
    np.testing.assert_allclose(np.asarray(out.score[0, 0]),
                               [.0, .1, .3, .8, 0., 0.], rtol=1e-6)


# -------------------------------------------------- device gather / scatter
def test_paged_scatter_gather_roundtrip_non_divisible():
    psize, S, L, B = 4, 10, 2, 3                  # 10 slots -> 3 pages, torn
    npp = pages_for(S, psize)
    pool_h = PagePool(1 + L * B * npp)
    pool = empty_pool(pool_h.n_pages, psize, kv_heads=2, head_dim=2,
                      dtype=jnp.float32)
    rng = np.random.default_rng(0)
    k = jnp.asarray(rng.normal(size=(L, B, S, 2, 2)), jnp.float32)
    v = -k
    tbl = np.stack([pool_h.alloc(npp * B).reshape(B, npp) for _ in range(L)])
    pool = scatter_rows_to_pages(pool, k, v, jnp.asarray(tbl))
    for lay in range(L):
        gk, gv = gather_layer_pages(pool, jnp.asarray(tbl[lay]), S)
        np.testing.assert_array_equal(np.asarray(gk), np.asarray(k[lay]))
        np.testing.assert_array_equal(np.asarray(gv), np.asarray(v[lay]))


def test_insert_tier_rows_sentinel_and_clear():
    psize, S, B = 4, 6, 4
    npp = pages_for(S, psize)
    tier = empty_paged_tier(1, B, S, psize)
    sent = 99
    rows_pos = jnp.asarray([[[0, 1, 2, -1, -1, -1]],
                            [[0, 1, 2, 3, 4, 5]]], jnp.int32).transpose(1, 0, 2)
    rows = SlotCache(k=(), v=(), pos=rows_pos,
                     score=jnp.zeros((1, 2, S), jnp.float32))
    # row 0 releases its second page (sentinel); row 3 is a pad row (drop)
    tbl = jnp.asarray([[[5, sent], [7, 8]]], jnp.int32)
    out = insert_tier_rows(tier, rows, jnp.asarray([0, B], jnp.int32), tbl,
                           sent)
    assert np.asarray(out.tbl[0, 0]).tolist() == [5, 0]   # sentinel -> null
    assert np.asarray(out.pos[0, 0]).tolist() == [0, 1, 2, -1, -1, -1]
    assert (np.asarray(out.pos[0, 1:]) == -1).all()       # pad row dropped
    cleared = clear_tier_row(out, 0)
    assert (np.asarray(cleared.tbl) == 0).all()
    assert (np.asarray(cleared.pos) == -1).all()
