"""Multimodal intake: embeds-native admission for vlm/audio families.

The load-bearing property mirrors tests/test_continuous.py: a
frontend-carrying request (image patches / audio frames + text), encoded
once by the intake, decodes token-identically through continuous batching —
bucketed AND packed embeds layouts, admit → fused decode → retire →
recycle — and through solo `Engine.generate` on the very same stub embeds.
Fast-lane units pin the pieces: batch-invariant bucketed encoding, the
text-segment/token-prompt equivalence, the embeds padding/packing layout
helpers, and the direct packed→arena scatter staying copy-free.
"""
import pytest

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import PolicyConfig
from repro.models import ModelConfig, init_params
from repro.models.frontend import mixed_positions
from repro.serving import (AudioSegment, ContinuousConfig, ContinuousEngine,
                           ContinuousScheduler, Engine, EngineConfig,
                           ImageSegment, IntakeEncoder, MultimodalRequest,
                           TextSegment, pack_embeds, pad_embeds, pad_prompt,
                           plan_pack, plan_pack_lengths)

VLM = ModelConfig(name="v", arch_type="vlm", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97,
                  mrope_sections=(4, 2, 2), frontend="vision_stub",
                  frontend_tokens=8, dtype="float32", param_dtype="float32")
AUDIO = ModelConfig(name="a", arch_type="audio", n_layers=2, d_model=64,
                    n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=97,
                    norm_type="layernorm", mlp_type="gelu",
                    frontend="audio_stub", frontend_tokens=8,
                    dtype="float32", param_dtype="float32")

ECFG = EngineConfig(mode="uniform", policy=PolicyConfig("sliding_window"),
                    budget_abs=12, bucket=4, min_budget=4)


def _ccfg(**kw):
    base = dict(max_concurrency=3, prompt_bucket=8, max_prompt_len=40,
                max_new_cap=8, sync_every=2)
    base.update(kw)
    return ContinuousConfig(**base)


def _front(cfg, n):
    return ImageSegment(n) if cfg.frontend == "vision_stub" \
        else AudioSegment(n)


def _reqs(cfg, rng, specs):
    """specs: [(n_frontend, n_text, max_new), ...] -> typed requests."""
    return [MultimodalRequest(
        (_front(cfg, nf),
         TextSegment(rng.integers(0, cfg.vocab_size, (nt,)).astype(np.int32))),
        max_new=mn, seed=100 + i)
        for i, (nf, nt, mn) in enumerate(specs)]


# ------------------------------------------------------------- fast: types
@pytest.mark.fast
def test_request_lengths_and_text_only_degradation():
    toks = np.arange(5, dtype=np.int32)
    r = MultimodalRequest((ImageSegment(9), TextSegment(toks)), max_new=4)
    assert (r.n_frontend, r.n_text, r.total_len) == (9, 5, 14)
    assert not r.is_text_only
    t = MultimodalRequest((TextSegment(toks), TextSegment(toks + 7)),
                          max_new=4)
    assert t.is_text_only and t.total_len == 10
    assert t.text_tokens().tolist() == list(toks) + list(toks + 7)
    with pytest.raises(AssertionError):
        MultimodalRequest((), max_new=1)


@pytest.mark.fast
def test_encoder_bucketing_one_dispatch_per_kind_length():
    """A burst's segments bucket by (kind, length): one encoder dispatch
    per bucket, counters exact."""
    params = init_params(jax.random.PRNGKey(0), VLM)
    enc = IntakeEncoder(params, VLM)
    rng = np.random.default_rng(0)
    reqs = _reqs(VLM, rng, [(9, 5, 2), (9, 7, 2), (4, 5, 2)])
    out = enc.encode_burst(reqs)
    # buckets: image(9) x2, image(4) x1, text(5) x2, text(7) x1 -> 4
    assert enc.encode_dispatches == 4
    assert enc.encoded_segments == 6
    assert enc.frontend_tokens_encoded == 9 * 2 + 4
    for r, e in zip(reqs, out):
        assert e.shape == (r.total_len, VLM.d_model)
        assert e.dtype == np.float32
    # repeat traffic reuses the memoized encoders (pow2-padded batches)
    enc.encode_burst(reqs)
    assert len(enc._fns) == 4


@pytest.mark.fast
def test_encoding_is_batch_invariant():
    """Row i of a bucketed encode depends only on request i's seed — the
    property that lets tests replay the exact embeds into solo
    generate."""
    params = init_params(jax.random.PRNGKey(0), VLM)
    enc = IntakeEncoder(params, VLM)
    rng = np.random.default_rng(1)
    text = rng.integers(0, 97, (5,)).astype(np.int32)
    reqs = [MultimodalRequest((ImageSegment(9), TextSegment(text)),
                              max_new=2, seed=100 + i) for i in range(3)]
    burst = enc.encode_burst(reqs)
    for r, e in zip(reqs, burst):
        np.testing.assert_array_equal(enc.encode_request(r), e)
    # different seeds -> different frontend embeds (same text)
    assert not np.array_equal(burst[0][:9], burst[1][:9])
    np.testing.assert_array_equal(burst[0][9:], burst[1][9:])


@pytest.mark.fast
def test_text_segment_matches_token_embedding_path():
    """An intake text segment IS the token path: table lookup + sqrt(d)
    scaling, bit-identical to what `forward(tokens=...)` embeds."""
    from repro.models.transformer import embed_tokens
    params = init_params(jax.random.PRNGKey(0), VLM)
    enc = IntakeEncoder(params, VLM)
    toks = np.arange(6, dtype=np.int32)
    e = enc.encode_request(MultimodalRequest((TextSegment(toks),), max_new=1))
    ref = np.asarray(embed_tokens(params, VLM, jnp.asarray(toks)), np.float32)
    np.testing.assert_array_equal(e, ref)


@pytest.mark.fast
def test_encoder_rejects_foreign_segments_and_unknown_frontend():
    params = init_params(jax.random.PRNGKey(0), AUDIO)
    enc = IntakeEncoder(params, AUDIO)
    with pytest.raises(ValueError, match="image"):
        enc.encode_burst([MultimodalRequest((ImageSegment(4),), max_new=1)])
    import dataclasses
    bad = dataclasses.replace(AUDIO, frontend="retina_v9")
    with pytest.raises(ValueError, match="retina_v9"):
        IntakeEncoder(params, bad)


@pytest.mark.fast
def test_submit_time_validation_protects_the_queue():
    """Invalid multimodal/embeds submissions raise AT SUBMIT — a poll-time
    rejection would drop the whole admission burst the bad request rode
    in on."""
    params = init_params(jax.random.PRNGKey(0), VLM)
    sched = ContinuousScheduler(params, VLM, ECFG, _ccfg())
    with pytest.raises(ValueError, match="audio"):
        sched.submit_multimodal(MultimodalRequest((AudioSegment(4),),
                                                  max_new=2))
    with pytest.raises(ValueError, match="exceeds"):    # max_prompt_len=40
        sched.submit_multimodal(MultimodalRequest((ImageSegment(64),),
                                                  max_new=2))
    with pytest.raises(ValueError, match="d_model"):
        sched.submit_embeds(np.zeros((4, 3), np.float32), 2)
    with pytest.raises(ValueError, match="exceeds"):
        sched.submit_embeds(np.zeros((60, VLM.d_model), np.float32), 2)
    assert not sched.queue          # nothing slipped into the queue


@pytest.mark.fast
def test_positions_for_is_mixed_sequential():
    r = MultimodalRequest((ImageSegment(4),
                           TextSegment(np.arange(3, dtype=np.int32))),
                          max_new=1)
    params = init_params(jax.random.PRNGKey(0), VLM)
    enc = IntakeEncoder(params, VLM)
    np.testing.assert_array_equal(enc.positions_for(r),
                                  np.asarray(mixed_positions(1, 4, 3)))


# ------------------------------------------------- fast: layout helpers
@pytest.mark.fast
def test_pad_embeds_mirrors_pad_prompts():
    d = 8
    embs = [np.full((n, d), i, np.float32) for i, n in enumerate((5, 11))]
    out, valid = pad_embeds(embs, bucket=8, batch=4)
    assert out.shape == (4, 16, d) and valid.shape == (4, 16)
    assert valid.sum() == 16
    np.testing.assert_array_equal(out[0, :5], embs[0])
    assert (out[0, 5:] == 0).all() and (out[2:] == 0).all()
    with pytest.raises(ValueError, match="exceeds"):
        pad_embeds(embs, bucket=8, max_len=10)


@pytest.mark.fast
def test_plan_pack_lengths_matches_plan_pack_and_pack_embeds_scatters():
    """The planner is payload-agnostic: `plan_pack` is `plan_pack_lengths`
    + a token fill, and `pack_embeds` writes each request's rows exactly
    where the plan says."""
    rng = np.random.default_rng(2)
    lens = (5, 11, 16, 3)
    prompts = [rng.integers(0, 97, (n,)).astype(np.int32) for n in lens]
    pt = plan_pack(prompts, bucket=8, pack_len=32, quantum=1)
    pl = plan_pack_lengths(lens, bucket=8, pack_len=32, quantum=1)
    for field in ("valid", "positions", "segments", "take_last",
                  "take_state", "row", "start", "seg", "lengths",
                  "slot_len"):
        np.testing.assert_array_equal(getattr(pt, field), getattr(pl, field))
    assert (pl.tokens == 0).all()

    embs = [np.full((n, 4), i + 1, np.float32) for i, n in enumerate(lens)]
    packed = pack_embeds(pl, embs)
    assert packed.shape == (pl.n_rows, pl.pack_len, 4)
    for i, e in enumerate(embs):
        r, s = pl.row[i], pl.start[i]
        np.testing.assert_array_equal(packed[r, s:s + len(e)], e)
    # everything outside the planned slots is zero (masked by plan.valid)
    assert packed.sum() == sum(e.sum() for e in embs)


# --------------------------------------------------- system: token identity
def _solo_reference(params, cfg, enc, req, bucket=8):
    """Solo `Engine.generate` on the SAME stub embeds (bucket-padded, the
    documented identity scope of the bucketed layouts under position-based
    policies)."""
    emb, valid = pad_embeds([enc.encode_request(req)], bucket)
    solo = Engine(params, cfg, ECFG)
    return solo.generate(embeds=emb, valid=valid,
                         max_new_tokens=req.max_new).tokens[0]


@pytest.mark.system
@pytest.mark.parametrize("cfg", [VLM, AUDIO], ids=["vlm", "audio"])
@pytest.mark.parametrize("layout", ["bucketed", "packed"])
def test_multimodal_continuous_matches_solo_generate(cfg, layout):
    """vlm/audio continuous serving == solo generate on the same stub
    embeds, per request, greedy — through admit → decode → retire →
    recycle (6 requests on 3 rows force recycling), bucketed AND packed
    embeds layouts."""
    params = init_params(jax.random.PRNGKey(0), cfg)
    ccfg = _ccfg(packed_prefill=(layout == "packed"))
    sched = ContinuousScheduler(params, cfg, ECFG, ccfg)
    rng = np.random.default_rng(0)
    specs = [(9, 5, 4), (4, 11, 7), (16, 8, 8), (9, 3, 1), (4, 5, 6),
             (16, 16, 5)]
    reqs = _reqs(cfg, rng, specs)
    rids = [sched.submit_multimodal(r) for r in reqs]
    done = {r.rid: r for r in sched.run_until_empty()}
    assert len(done) == len(specs)

    enc = IntakeEncoder(params, cfg)   # fresh encoder: same seeds, same embeds
    for rid, req in zip(rids, reqs):
        ref = _solo_reference(params, cfg, enc, req)
        assert done[rid].tokens.tolist() == ref.tolist(), rid
    # the packed unpack stayed copy-free (direct packed->arena scatter)
    if layout == "packed":
        assert sched.core.admit_kv_copy_elems == 0


@pytest.mark.system
def test_mixed_text_and_multimodal_burst_one_poll():
    """A burst mixing token prompts and multimodal requests admits in ONE
    scheduler poll (modality-partitioned inside admit_many) and every
    member matches its solo reference."""
    params = init_params(jax.random.PRNGKey(0), VLM)
    sched = ContinuousScheduler(params, VLM, ECFG,
                                _ccfg(max_concurrency=4,
                                      packed_prefill=True))
    rng = np.random.default_rng(3)
    text = rng.integers(0, 97, (7,)).astype(np.int32)
    text2 = rng.integers(0, 97, (13,)).astype(np.int32)
    mm = _reqs(VLM, rng, [(9, 5, 4), (4, 6, 5)])
    rid_t = sched.submit(text, max_new=4)
    rid_m0 = sched.submit_multimodal(mm[0])
    rid_t2 = sched.submit(text2, max_new=6)
    rid_m1 = sched.submit_multimodal(mm[1])
    sched.poll()
    assert sched.core.admitted == 4          # one poll admitted the burst
    assert not sched.queue
    done = {r.rid: r for r in sched.run_until_empty()}

    solo = Engine(params, VLM, ECFG)
    enc = IntakeEncoder(params, VLM)
    for rid, t, mn in ((rid_t, text, 4), (rid_t2, text2, 6)):
        toks, valid = pad_prompt(t, 8)
        ref = solo.generate(tokens=toks, valid=valid,
                            max_new_tokens=mn).tokens[0]
        assert done[rid].tokens.tolist() == ref.tolist(), rid
    for rid, req in ((rid_m0, mm[0]), (rid_m1, mm[1])):
        ref = _solo_reference(params, VLM, enc, req)
        assert done[rid].tokens.tolist() == ref.tolist(), rid


@pytest.mark.system
def test_text_only_multimodal_request_equals_token_submission():
    """submit_multimodal on a text-only request degrades to the token
    path — same tokens as a plain submit of the same ids."""
    params = init_params(jax.random.PRNGKey(0), AUDIO)
    rng = np.random.default_rng(4)
    toks = rng.integers(0, 97, (9,)).astype(np.int32)
    outs = []
    for submit in ("token", "mm"):
        sched = ContinuousScheduler(params, AUDIO, ECFG, _ccfg())
        if submit == "token":
            rid = sched.submit(toks, max_new=5)
        else:
            rid = sched.submit_multimodal(MultimodalRequest(
                (TextSegment(toks),), max_new=5))
        done = {r.rid: r for r in sched.run_until_empty()}
        outs.append(done[rid].tokens.tolist())
        assert sched.intake.encode_dispatches == 0   # no embeds needed
    assert outs[0] == outs[1]


@pytest.mark.system
def test_embeds_admission_never_retraces():
    """Embeds bursts obey the traced-index discipline, and token + embeds
    bursts SHARE the fused admit executables (PrefillOut and the packed
    prefill output are modality-blind)."""
    params = init_params(jax.random.PRNGKey(0), VLM)
    eng = ContinuousEngine(params, VLM, ECFG, _ccfg(packed_prefill=True))
    enc = IntakeEncoder(params, VLM)
    rng = np.random.default_rng(5)
    for wave in range(2):              # same lengths, rotating slots
        reqs = _reqs(VLM, rng, [(9, 7, 2), (4, 4, 2)])
        embs = enc.encode_burst(reqs)
        slots = eng.admit_many([(e, r.max_new) for e, r in zip(embs, reqs)])
        while eng.n_occupied:
            eng.decode_block()
        eng.pop_completed()
        assert len(slots) == 2
    assert all(fn._cache_size() == 1 for fn in eng._padmit_fns.values())
    assert len(eng._padmit_fns) == 1
    # a token burst with the same packed layout reuses the SAME executable
    toks = [rng.integers(0, 97, (n,)).astype(np.int32) for n in (16, 8)]
    eng.admit_many([(t, 2) for t in toks])
    assert len(eng._padmit_fns) == 1
