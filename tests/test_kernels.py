"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles.

All kernels run in interpret mode (CPU container; TPU is the lowering
target).  Tolerances: fp32 ~1e-5, bf16 ~5e-2 (inputs are bf16-rounded but
accumulation is fp32 in both kernel and oracle).
"""

import pytest

pytestmark = pytest.mark.kernels

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_decode.ops import flash_decode
from repro.kernels.flash_decode.ref import decode_attention_ref
from repro.kernels.ssd_scan.ops import ssd
from repro.kernels.ssd_scan.ref import ssd_recurrent_ref
from repro.kernels.swa_prefill.ops import swa_attention
from repro.kernels.swa_prefill.ref import swa_attention_ref

GLOBAL = 1 << 30


# ------------------------------------------------------------- flash_decode
@pytest.mark.parametrize("B,S,Hkv,G,hd,window,dtype,softcap", [
    (2, 256, 2, 4, 64, GLOBAL, jnp.float32, None),
    (2, 256, 2, 4, 64, 100, jnp.float32, None),
    (1, 300, 1, 8, 128, GLOBAL, jnp.float32, None),     # pad path
    (2, 256, 2, 4, 64, GLOBAL, jnp.bfloat16, None),
    (2, 128, 4, 1, 32, 50, jnp.float32, 30.0),          # softcap (gemma2)
    (1, 64, 2, 2, 16, 8, jnp.float32, None),            # tiny window
])
def test_flash_decode_matches_ref(B, S, Hkv, G, hd, window, dtype, softcap):
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (B, Hkv, G, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, hd), jnp.float32).astype(dtype)
    pos = jax.random.randint(ks[3], (B, S), -1, 2 * S)
    t = jnp.full((B,), int(1.5 * S), jnp.int32)
    o1, c1 = flash_decode(q, k, v, pos, t, window, block_s=128,
                          softcap=softcap, return_colsums=True)
    o2, c2 = decode_attention_ref(q, k, v, pos, t, window, softcap=softcap)
    tol = 5e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=tol)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), atol=tol)


def test_flash_decode_empty_slots_ignored():
    """Evicted (-1) slots never contribute attention mass."""
    B, S, Hkv, G, hd = 1, 128, 1, 1, 32
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, Hkv, G, hd))
    k = jax.random.normal(ks[1], (B, S, Hkv, hd))
    v = jax.random.normal(ks[2], (B, S, Hkv, hd))
    pos = jnp.where(jnp.arange(S) < 64, jnp.arange(S), -1)[None]
    t = jnp.asarray([1000], jnp.int32)
    _, cols = flash_decode(q, k, v, pos, t, GLOBAL, block_s=64,
                           return_colsums=True)
    assert float(jnp.abs(cols[0, 0, 64:]).max()) == 0.0
    assert np.isclose(float(cols.sum()), 1.0, atol=1e-5)   # probs sum to 1


# ----------------------------------------------------------------- ssd_scan
@pytest.mark.parametrize("B,S,H,P,N,chunk,dtype", [
    (2, 64, 2, 32, 16, 16, jnp.float32),
    (1, 128, 4, 64, 128, 32, jnp.float32),
    (2, 40, 2, 32, 16, 16, jnp.float32),                # pad path
    (2, 64, 2, 32, 16, 16, jnp.bfloat16),
])
def test_ssd_matches_recurrence(B, S, H, P, N, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    xh = (jax.random.normal(ks[0], (B, S, H, P)) * 0.5).astype(dtype)
    bh = (jax.random.normal(ks[1], (B, S, N)) * 0.5).astype(dtype)
    ch = (jax.random.normal(ks[2], (B, S, N)) * 0.5).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[3], (B, S, H)) - 2.0)
    a_log = jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32))
    d_skip = jnp.ones((H,), jnp.float32)
    y1, f1 = ssd(xh, bh, ch, dt, a_log, d_skip, chunk=chunk)
    y2, f2 = ssd_recurrent_ref(xh, bh, ch, dt, a_log, d_skip)
    tol = 6e-2 if dtype == jnp.bfloat16 else 5e-4
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=tol)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2), atol=tol)


def test_ssd_state_continuation():
    """Scanning two halves with carried state == scanning the whole."""
    from repro.models.ssm import ssd_chunked
    B, S, H, P, N = 1, 64, 2, 32, 16
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    xh = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    bh = jax.random.normal(ks[1], (B, S, N)) * 0.5
    ch = jax.random.normal(ks[2], (B, S, N)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[3], (B, S, H)) - 2.0)
    a_log = jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32))
    d = jnp.ones((H,))
    y_all, f_all = ssd_chunked(xh, bh, ch, dt, a_log, d, 16)
    h_ = S // 2
    y1, f1 = ssd_chunked(xh[:, :h_], bh[:, :h_], ch[:, :h_], dt[:, :h_],
                         a_log, d, 16)
    y2, f2 = ssd_chunked(xh[:, h_:], bh[:, h_:], ch[:, h_:], dt[:, h_:],
                         a_log, d, 16, initial_state=f1)
    np.testing.assert_allclose(np.asarray(y_all[:, h_:]), np.asarray(y2),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(f_all), np.asarray(f2), atol=1e-4)


# -------------------------------------------------------------- swa_prefill
@pytest.mark.parametrize("B,Hq,Hkv,S,hd,window,dtype,softcap", [
    (2, 4, 2, 256, 64, GLOBAL, jnp.float32, None),
    (2, 4, 2, 256, 64, 64, jnp.float32, None),
    (1, 8, 2, 256, 32, 100, jnp.float32, None),
    (2, 4, 4, 200, 64, 64, jnp.float32, None),          # pad path
    (2, 4, 2, 256, 64, 64, jnp.bfloat16, 50.0),
])
def test_swa_prefill_matches_ref(B, Hq, Hkv, S, hd, window, dtype, softcap):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Hq, S, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, Hkv, S, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, Hkv, S, hd), jnp.float32).astype(dtype)
    o1 = swa_attention(q, k, v, window=window, bq=64, bk=64,
                       softcap=softcap).astype(jnp.float32)
    o2 = swa_attention_ref(q, k, v, window, softcap)
    tol = 5e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=tol)


@pytest.mark.parametrize("window", [GLOBAL, 16])
def test_swa_prefill_segment_mask_matches_ref(window):
    """Packed-prefill block-diagonal masking: ragged segment boundaries
    (not block-aligned), plus the S-padding path."""
    B, Hq, Hkv, S, hd = 2, 4, 2, 200, 32
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (B, Hq, S, hd))
    k = jax.random.normal(ks[1], (B, Hkv, S, hd))
    v = jax.random.normal(ks[2], (B, Hkv, S, hd))
    seg = jnp.asarray(np.concatenate(
        [np.zeros(37), np.ones(90), np.full(73, 2)])[None].repeat(
            B, 0).astype(np.int32))
    o1 = swa_attention(q, k, v, window=window, bq=64, bk=64, segments=seg)
    o2 = swa_attention_ref(q, k, v, window, segments=seg)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)
    # a segment's output is independent of the other segments' content
    k2 = k.at[:, :, 37:].set(jax.random.normal(ks[1], (B, Hkv, 163, hd)) * 3)
    v2 = v.at[:, :, 37:].set(0.5)
    o3 = swa_attention(q, k2, v2, window=window, bq=64, bk=64, segments=seg)
    np.testing.assert_allclose(np.asarray(o3[:, :, :37]),
                               np.asarray(o1[:, :, :37]), atol=2e-5)


def test_swa_matches_model_flash_path():
    """Kernel == the pure-jnp flash used by the model stack (same geometry)."""
    import repro.models.attention as A
    from repro.models.config import ModelConfig
    cfg = ModelConfig(name="t", arch_type="dense", n_layers=1, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64)
    B, S, hd = 1, 128, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, 4, S, hd))
    k = jax.random.normal(ks[1], (B, 2, S, hd))
    v = jax.random.normal(ks[2], (B, 2, S, hd))
    o_kernel = swa_attention(q, k, v, window=32, bq=32, bk=32)
    qf = q.transpose(0, 2, 1, 3).reshape(B, S, 2, 2, hd)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    o_flash, _ = A._flash_attention(qf, k.transpose(0, 2, 1, 3),
                                    v.transpose(0, 2, 1, 3), pos, cfg, 32,
                                    None, False, block=32)
    o_flash = o_flash.reshape(B, S, 4, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(o_kernel), np.asarray(o_flash),
                               atol=2e-5)
