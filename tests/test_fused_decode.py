"""Fused decode blocks: token-identity against the per-step loop.

The tentpole invariant: fusing `sync_every` (continuous) / `eos_check_every`
(one-shot) decode steps into one `lax.scan` executable with on-device
emission buffers is a DISPATCH change, not a model change.  Greedy (and
stochastic — the per-step key-split sequence is preserved) outputs must be
token-identical to dispatching one step at a time.
"""
import pytest

pytestmark = pytest.mark.system

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import PolicyConfig
from repro.models import ModelConfig, init_params
from repro.serving import (ContinuousConfig, ContinuousScheduler, Engine,
                           EngineConfig, SamplerConfig, sample)

CFG = ModelConfig(name="s", arch_type="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97,
                  dtype="float32", param_dtype="float32")

ECFG = EngineConfig(mode="uniform", policy=PolicyConfig("sliding_window"),
                    budget_abs=12, bucket=4, min_budget=4)


def _params():
    return init_params(jax.random.PRNGKey(0), CFG)


def _per_step_generate(eng: Engine, tokens, valid, max_new, seed=0):
    """The pre-fusion `Engine.generate` decode loop, verbatim: one jit'd
    step dispatch per token, EOS checked by re-stacking the emitted tokens
    every `eos_check_every` steps.  The fused path is pinned against this."""
    B, P = tokens.shape
    pre = eng._prefill_fn((B, P))(eng.params, tokens, None, None, valid)
    cos = np.asarray(pre.cos_sims).mean(axis=-1) if pre.cos_sims.size \
        else np.zeros(0)
    plan = eng.plan_budgets(cos, P, max_new)
    state = eng.build_state(pre, plan, B)
    shape_key = (B, P) + tuple(plan.tier_budgets) + tuple(plan.tier_counts)
    step = eng._step_fn(shape_key)
    token = sample(pre.last_logits, jax.random.PRNGKey(seed),
                   eng.ecfg.sampler)
    key = jax.random.PRNGKey(seed + 1)
    out = []
    eos = eng.ecfg.eos_token
    for i in range(max_new):
        out.append(token)
        key, sub = jax.random.split(key)
        token, _, state = step(eng.params, state, token, sub)
        if eos >= 0 and (i + 1) % eng.ecfg.eos_check_every == 0:
            done = np.asarray(jnp.stack(out) == eos).any(axis=0)
            if done.all():
                break
    toks = np.stack([np.asarray(t) for t in out], axis=1)
    if eos >= 0:
        hit = np.cumsum(toks == eos, axis=1) > 0
        mask = np.concatenate(
            [np.zeros((toks.shape[0], 1), bool), hit[:, :-1]], axis=1)
        toks = np.where(mask, eos, toks)
    return toks


def test_generate_fused_block_matches_per_step_loop():
    """No EOS: the whole generation is ONE dispatch, same tokens."""
    params = _params()
    eng = Engine(params, CFG, ECFG)
    prompts = np.random.default_rng(0).integers(
        0, 97, (3, 16)).astype(np.int32)
    ref = _per_step_generate(eng, prompts, None, max_new=10)
    d0 = eng.decode_dispatches
    r = eng.generate(tokens=prompts, max_new_tokens=10)
    assert r.tokens.tolist() == ref.tolist()
    assert eng.decode_dispatches - d0 == 1        # one fused dispatch total


def test_generate_fused_block_matches_per_step_loop_with_eos():
    """EOS set: blocks of eos_check_every steps, running done mask, early
    exit at the same boundaries as the per-step loop."""
    params = _params()
    prompts = np.random.default_rng(1).integers(
        0, 97, (2, 12)).astype(np.int32)
    # probe what greedy emits early so the EOS actually fires mid-generation
    probe = Engine(params, CFG, ECFG)
    eos = int(probe.generate(tokens=prompts, max_new_tokens=4).tokens[0, 2])
    ecfg = EngineConfig(mode=ECFG.mode, policy=ECFG.policy,
                        budget_abs=ECFG.budget_abs, bucket=ECFG.bucket,
                        min_budget=ECFG.min_budget, eos_token=eos,
                        eos_check_every=3)
    eng = Engine(params, CFG, ecfg)
    ref = _per_step_generate(eng, prompts, None, max_new=14)
    d0 = eng.decode_dispatches
    r = eng.generate(tokens=prompts, max_new_tokens=14)
    assert r.tokens.tolist() == ref.tolist()
    assert r.tokens.shape[1] % 3 == 0 or r.tokens.shape[1] == 14
    # fewer dispatches than decoded steps
    assert eng.decode_dispatches - d0 <= -(-r.tokens.shape[1] // 3)


def test_generate_fused_block_matches_per_step_stochastic():
    """The fused scan splits the PRNG key exactly like the per-step loop,
    so even stochastic sampling is trajectory-identical."""
    params = _params()
    ecfg = EngineConfig(mode=ECFG.mode, policy=ECFG.policy,
                        budget_abs=ECFG.budget_abs, bucket=ECFG.bucket,
                        min_budget=ECFG.min_budget,
                        sampler=SamplerConfig(temperature=0.8, top_k=20))
    eng = Engine(params, CFG, ecfg)
    prompts = np.random.default_rng(2).integers(
        0, 97, (2, 8)).astype(np.int32)
    ref = _per_step_generate(eng, prompts, None, max_new=9, seed=5)
    r = eng.generate(tokens=prompts, max_new_tokens=9, seed=5)
    assert r.tokens.tolist() == ref.tolist()


def test_continuous_outputs_invariant_to_sync_every():
    """The fused block length is a scheduling knob: the same greedy request
    stream must produce identical tokens for sync_every 1 vs 4 (per-step
    dispatch regime vs fused blocks)."""
    params = _params()
    rng = np.random.default_rng(3)
    specs = [(5, 7), (11, 4), (16, 8), (9, 2), (20, 6)]
    prompts = [rng.integers(0, 97, (n,)).astype(np.int32) for n, _ in specs]

    def run(sync_every):
        sched = ContinuousScheduler(params, CFG, ECFG, ContinuousConfig(
            max_concurrency=3, prompt_bucket=8, max_prompt_len=24,
            max_new_cap=8, sync_every=sync_every))
        rids = [sched.submit(p, max_new=mn)
                for p, (_, mn) in zip(prompts, specs)]
        done = {r.rid: r for r in sched.run_until_empty()}
        return [done[rid].tokens.tolist() for rid in rids], sched.core

    out1, core1 = run(1)
    out4, core4 = run(4)
    assert out1 == out4
    # fused blocks amortize dispatches: the sync_every=4 run launched
    # strictly fewer decode executables for the same decoded steps
    assert core4.decode_dispatches < core1.decode_dispatches
    assert core1.decode_dispatches == core1.decode_steps


def test_continuous_block_dispatch_count_exact():
    """One request, max_new=9, sync_every=4: 8 decode steps must cost
    exactly 2 fused dispatches (bound-clamped blocks of 4+4)."""
    params = _params()
    sched = ContinuousScheduler(params, CFG, ECFG, ContinuousConfig(
        max_concurrency=2, prompt_bucket=8, max_prompt_len=16,
        max_new_cap=16, sync_every=4))
    sched.submit(np.random.default_rng(4).integers(0, 97, (6,)), max_new=9)
    done = sched.run_until_empty()
    assert len(done) == 1 and done[0].tokens.shape == (9,)
    assert sched.core.decode_steps == 8
    assert sched.core.decode_dispatches == 2
    assert sched.core.admit_dispatches == 1
