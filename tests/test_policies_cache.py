"""Eviction-policy semantics over slot arenas (the paper's C_seq compressors)."""

import pytest

pytestmark = pytest.mark.fast

import jax
import jax.numpy as jnp
import numpy as np

from _hypothesis_compat import given, settings, st

from repro.core.cache import SlotCache, compact, pad_cache, write_token
from repro.core.policies import (BIG, PolicyConfig, accumulates_scores,
                                 keep_priority, key_norms, uses_key_norms)


def _arena(L=1, B=1, P=16, H=2, D=4, scores=None):
    k = jnp.arange(L * B * P * H * D, dtype=jnp.float32).reshape(L, B, P, H, D)
    pos = jnp.broadcast_to(jnp.arange(P, dtype=jnp.int32), (L, B, P))
    sc = jnp.asarray(scores, jnp.float32).reshape(L, B, P) if scores is not None \
        else jnp.zeros((L, B, P))
    return k, k + 1, pos, sc


def test_sliding_window_keeps_most_recent():
    k, v, pos, sc = _arena(P=16)
    c = compact(PolicyConfig("sliding_window"), k, v, pos, sc, budget=6, t=16)
    assert list(np.asarray(c.pos[0, 0])) == [10, 11, 12, 13, 14, 15]


def test_streaming_llm_keeps_sinks():
    k, v, pos, sc = _arena(P=16)
    c = compact(PolicyConfig("streaming_llm", n_sink=4), k, v, pos, sc,
                budget=6, t=16)
    assert list(np.asarray(c.pos[0, 0])) == [0, 1, 2, 3, 14, 15]


def test_h2o_keeps_heavy_hitters_plus_recent():
    scores = np.zeros(16)
    scores[[2, 5]] = 10.0                       # heavy hitters
    k, v, pos, sc = _arena(P=16, scores=scores)
    c = compact(PolicyConfig("h2o", recent_frac=0.5), k, v, pos, sc,
                budget=8, t=16)
    kept = set(np.asarray(c.pos[0, 0]).tolist())
    assert {2, 5} <= kept                        # heavy hitters survive
    assert {13, 14, 15} <= kept                  # recency window survives


def test_compact_gathers_kv_consistently():
    k, v, pos, sc = _arena(P=8, H=1, D=2)
    c = compact(PolicyConfig("sliding_window"), k, v, pos, sc, budget=3, t=8)
    # the K rows must be the rows of the kept positions
    kept = np.asarray(c.pos[0, 0])
    expect = np.asarray(k[0, 0])[kept]
    assert np.allclose(np.asarray(c.k[0, 0]), expect)


def test_write_token_fills_empty_first():
    cache = SlotCache(
        k=jnp.zeros((1, 4, 2, 2)), v=jnp.zeros((1, 4, 2, 2)),
        pos=jnp.asarray([[0, 1, -1, -1]], jnp.int32),
        score=jnp.zeros((1, 4)))
    out = write_token(PolicyConfig("sliding_window"), cache,
                      jnp.ones((1, 1, 2, 2)), jnp.ones((1, 1, 2, 2)),
                      jnp.asarray([7]), jnp.zeros((1, 5)))
    p = set(np.asarray(out.pos[0]).tolist())
    assert 7 in p and 0 in p and 1 in p and -1 in p


def test_write_token_evicts_oldest_when_full():
    cache = SlotCache(
        k=jnp.zeros((1, 4, 2, 2)), v=jnp.zeros((1, 4, 2, 2)),
        pos=jnp.asarray([[3, 5, 4, 6]], jnp.int32),
        score=jnp.zeros((1, 4)))
    out = write_token(PolicyConfig("sliding_window"), cache,
                      jnp.ones((1, 1, 2, 2)), jnp.ones((1, 1, 2, 2)),
                      jnp.asarray([7]), jnp.zeros((1, 5)))
    p = np.asarray(out.pos[0]).tolist()
    assert 3 not in p and 7 in p


def test_h2o_score_accumulation():
    cache = SlotCache(
        k=jnp.zeros((1, 4, 1, 1)), v=jnp.zeros((1, 4, 1, 1)),
        pos=jnp.asarray([[0, 1, 2, 3]], jnp.int32),
        score=jnp.asarray([[1.0, 0.1, 1.0, 1.0]]))
    probs = jnp.asarray([[0.2, 0.0, 0.2, 0.2, 0.4]])  # last = new token
    out = write_token(PolicyConfig("h2o", recent_frac=0.25), cache,
                      jnp.ones((1, 1, 1, 1)), jnp.ones((1, 1, 1, 1)),
                      jnp.asarray([4]), probs)
    p = np.asarray(out.pos[0]).tolist()
    assert 1 not in p                 # lowest accumulated score, not protected
    assert 4 in p
    new_slot = p.index(4)
    assert np.isclose(np.asarray(out.score[0])[new_slot], 0.4)


@settings(max_examples=100, deadline=None)
@given(
    policy=st.sampled_from(["sliding_window", "streaming_llm", "h2o"]),
    budget=st.integers(4, 16),
    steps=st.integers(1, 12),
    seed=st.integers(0, 99),
)
def test_arena_invariants_under_decode(policy, budget, steps, seed):
    """Property: positions stay unique & valid; arena never exceeds budget;
    the newest token is always present after a write."""
    rng = np.random.RandomState(seed)
    pol = PolicyConfig(policy, n_sink=2)      # sinks < min budget
    P0 = budget
    pos0 = np.arange(P0)
    cache = SlotCache(
        k=jnp.zeros((1, P0, 1, 2)), v=jnp.zeros((1, P0, 1, 2)),
        pos=jnp.asarray(pos0[None], jnp.int32),
        score=jnp.asarray(rng.rand(1, P0).astype(np.float32)))
    t = P0
    for _ in range(steps):
        probs = rng.rand(1, cache.pos.shape[-1] + 1).astype(np.float32)
        cache = write_token(pol, cache, jnp.ones((1, 1, 1, 2)),
                            jnp.ones((1, 1, 1, 2)), jnp.asarray([t]),
                            jnp.asarray(probs))
        ps = np.asarray(cache.pos[0])
        valid = ps[ps >= 0]
        assert len(set(valid.tolist())) == len(valid)      # unique
        assert t in ps                                      # newest present
        assert len(ps) == P0                                # fixed arena
        if policy == "streaming_llm":
            assert 0 in ps and 1 in ps                      # sinks survive
        t += 1


def test_l2_norm_keeps_low_norm_keys_plus_recent():
    """l2_norm (arXiv:2406.11430): LOW key norm = important.  The score
    channel holds ||K||_2, so compaction keeps the lowest-norm slots plus
    the recency window — no attention-score accumulation anywhere."""
    k, v, pos, sc = _arena(P=16)
    norms = key_norms(k)                 # [L, B, P], increasing with slot id
    assert (np.diff(np.asarray(norms[0, 0])) > 0).all()
    c = compact(PolicyConfig("l2_norm", recent_frac=0.5), k, v, pos, norms,
                budget=8, t=16)
    kept = set(np.asarray(c.pos[0, 0]).tolist())
    assert {0, 1, 2, 3} <= kept          # lowest norms survive
    assert {13, 14, 15} <= kept          # recency window survives (pos > 11)


def test_write_token_l2_norm_scores_are_static_norms():
    """Decode writes under l2_norm: the victim is the highest-norm
    unprotected slot, and the incoming slot's score is ITS key norm —
    `slot_probs` (the H2O colsum plumbing) is ignored entirely."""
    kc = jnp.stack([jnp.full((2, 2), s) for s in (9.0, 1.0, 2.0, 3.0)])
    cache = SlotCache(
        k=kc[None], v=jnp.zeros((1, 4, 2, 2)),
        pos=jnp.asarray([[0, 1, 2, 3]], jnp.int32),
        score=key_norms(kc[None]))
    pol = PolicyConfig("l2_norm", recent_frac=0.25)   # window = 1 slot
    k_new = jnp.full((1, 1, 2, 2), 0.5)
    garbage = jnp.full((1, 5), 123.0)    # would corrupt an accumulating path
    out = write_token(pol, cache, k_new, jnp.ones((1, 1, 2, 2)),
                      jnp.asarray([4]), garbage)
    p = np.asarray(out.pos[0]).tolist()
    assert 0 not in p                    # highest norm, outside the window
    assert 4 in p
    new_slot = p.index(4)
    expect = float(np.asarray(key_norms(k_new[:, 0]))[0])
    assert np.isclose(np.asarray(out.score[0])[new_slot], expect)
    # surviving slots kept their STATIC norms (no accumulation happened)
    for slot, pos_v in enumerate(p):
        if pos_v in (1, 2, 3):
            assert np.isclose(np.asarray(out.score[0])[slot],
                              float(np.asarray(cache.score[0, pos_v])))


def test_policy_predicates():
    assert accumulates_scores(PolicyConfig("h2o"))
    assert accumulates_scores(PolicyConfig("sink_h2o"))
    assert not accumulates_scores(PolicyConfig("l2_norm"))
    assert not accumulates_scores(PolicyConfig("sliding_window"))
    assert uses_key_norms(PolicyConfig("l2_norm"))
    assert not uses_key_norms(PolicyConfig("h2o"))


def test_keep_priority_empty_slots_always_lose():
    """Empty slots (pos == -1) read -BIG under EVERY policy, below any
    real slot's priority — they are always the eviction victim."""
    pos = jnp.asarray([[-1, 0, 5]], jnp.int32)
    score = jnp.asarray([[0.0, 100.0, 0.5]])
    for name in ("sliding_window", "streaming_llm", "h2o", "sink_h2o",
                 "l2_norm"):
        pri = np.asarray(keep_priority(PolicyConfig(name), pos, score,
                                       t=6, budget=3))[0]
        assert pri[0] == -BIG
        assert pri[0] < pri[1] and pri[0] < pri[2]


def test_keep_priority_budget_one_window_floor():
    """budget == 1: recent_w floors at 1, so the slot AT the current
    position stays protected — the window never collapses to zero slots."""
    pos = jnp.asarray([[3, 4, 5]], jnp.int32)
    score = jnp.asarray([[2.0, 3.0, 1.0]])
    for name in ("h2o", "sink_h2o", "l2_norm"):
        pri = np.asarray(keep_priority(
            PolicyConfig(name, n_sink=0, recent_frac=0.5), pos, score,
            t=5, budget=1))[0]
        assert pri[2] > BIG / 2                  # pos 5 > t-1: protected
        assert pri[0] < BIG / 2 and pri[1] < BIG / 2


def test_keep_priority_t_below_window_protects_everything():
    """t < recent_w: every occupied slot sits inside the recency window, so
    no real slot can be evicted before the window fills — only empties."""
    pos = jnp.asarray([[0, 1, 2, -1]], jnp.int32)
    score = jnp.asarray([[5.0, 1.0, 3.0, 0.0]])
    for name in ("h2o", "l2_norm"):
        pri = np.asarray(keep_priority(
            PolicyConfig(name, recent_frac=0.5), pos, score,
            t=3, budget=16))[0]                  # recent_w = 8 > t
        assert (pri[:3] > BIG / 2).all()
        assert pri[3] == -BIG


def test_keep_priority_l2_norm_orders_by_negated_norm():
    """Outside the protected window, HIGH norm -> LOW priority (victim)."""
    pos = jnp.asarray([[0, 1, 2]], jnp.int32)
    score = jnp.asarray([[3.0, 1.0, 2.0]])       # key norms
    pri = np.asarray(keep_priority(
        PolicyConfig("l2_norm", recent_frac=0.1), pos, score,
        t=100, budget=4))[0]                     # window far in the future
    assert pri.argmin() == 0 and pri.argmax() == 1


def test_sink_h2o_protects_both_sets():
    """Beyond-paper composite policy: sinks AND heavy hitters AND recents."""
    scores = np.zeros(16)
    scores[[5, 7]] = 10.0
    k, v, pos, sc = _arena(P=16, scores=scores)
    c = compact(PolicyConfig("sink_h2o", n_sink=2, recent_frac=0.25), k, v,
                pos, sc, budget=8, t=16)
    kept = set(np.asarray(c.pos[0, 0]).tolist())
    assert {0, 1} <= kept          # sinks
    assert {5, 7} <= kept          # heavy hitters
    assert 15 in kept              # recency window (0.25 * 8 = 2 -> pos > 14)
