"""Eviction-policy semantics over slot arenas (the paper's C_seq compressors)."""

import pytest

pytestmark = pytest.mark.fast

import jax
import jax.numpy as jnp
import numpy as np

from _hypothesis_compat import given, settings, st

from repro.core.cache import SlotCache, compact, pad_cache, write_token
from repro.core.policies import PolicyConfig, keep_priority


def _arena(L=1, B=1, P=16, H=2, D=4, scores=None):
    k = jnp.arange(L * B * P * H * D, dtype=jnp.float32).reshape(L, B, P, H, D)
    pos = jnp.broadcast_to(jnp.arange(P, dtype=jnp.int32), (L, B, P))
    sc = jnp.asarray(scores, jnp.float32).reshape(L, B, P) if scores is not None \
        else jnp.zeros((L, B, P))
    return k, k + 1, pos, sc


def test_sliding_window_keeps_most_recent():
    k, v, pos, sc = _arena(P=16)
    c = compact(PolicyConfig("sliding_window"), k, v, pos, sc, budget=6, t=16)
    assert list(np.asarray(c.pos[0, 0])) == [10, 11, 12, 13, 14, 15]


def test_streaming_llm_keeps_sinks():
    k, v, pos, sc = _arena(P=16)
    c = compact(PolicyConfig("streaming_llm", n_sink=4), k, v, pos, sc,
                budget=6, t=16)
    assert list(np.asarray(c.pos[0, 0])) == [0, 1, 2, 3, 14, 15]


def test_h2o_keeps_heavy_hitters_plus_recent():
    scores = np.zeros(16)
    scores[[2, 5]] = 10.0                       # heavy hitters
    k, v, pos, sc = _arena(P=16, scores=scores)
    c = compact(PolicyConfig("h2o", recent_frac=0.5), k, v, pos, sc,
                budget=8, t=16)
    kept = set(np.asarray(c.pos[0, 0]).tolist())
    assert {2, 5} <= kept                        # heavy hitters survive
    assert {13, 14, 15} <= kept                  # recency window survives


def test_compact_gathers_kv_consistently():
    k, v, pos, sc = _arena(P=8, H=1, D=2)
    c = compact(PolicyConfig("sliding_window"), k, v, pos, sc, budget=3, t=8)
    # the K rows must be the rows of the kept positions
    kept = np.asarray(c.pos[0, 0])
    expect = np.asarray(k[0, 0])[kept]
    assert np.allclose(np.asarray(c.k[0, 0]), expect)


def test_write_token_fills_empty_first():
    cache = SlotCache(
        k=jnp.zeros((1, 4, 2, 2)), v=jnp.zeros((1, 4, 2, 2)),
        pos=jnp.asarray([[0, 1, -1, -1]], jnp.int32),
        score=jnp.zeros((1, 4)))
    out = write_token(PolicyConfig("sliding_window"), cache,
                      jnp.ones((1, 1, 2, 2)), jnp.ones((1, 1, 2, 2)),
                      jnp.asarray([7]), jnp.zeros((1, 5)))
    p = set(np.asarray(out.pos[0]).tolist())
    assert 7 in p and 0 in p and 1 in p and -1 in p


def test_write_token_evicts_oldest_when_full():
    cache = SlotCache(
        k=jnp.zeros((1, 4, 2, 2)), v=jnp.zeros((1, 4, 2, 2)),
        pos=jnp.asarray([[3, 5, 4, 6]], jnp.int32),
        score=jnp.zeros((1, 4)))
    out = write_token(PolicyConfig("sliding_window"), cache,
                      jnp.ones((1, 1, 2, 2)), jnp.ones((1, 1, 2, 2)),
                      jnp.asarray([7]), jnp.zeros((1, 5)))
    p = np.asarray(out.pos[0]).tolist()
    assert 3 not in p and 7 in p


def test_h2o_score_accumulation():
    cache = SlotCache(
        k=jnp.zeros((1, 4, 1, 1)), v=jnp.zeros((1, 4, 1, 1)),
        pos=jnp.asarray([[0, 1, 2, 3]], jnp.int32),
        score=jnp.asarray([[1.0, 0.1, 1.0, 1.0]]))
    probs = jnp.asarray([[0.2, 0.0, 0.2, 0.2, 0.4]])  # last = new token
    out = write_token(PolicyConfig("h2o", recent_frac=0.25), cache,
                      jnp.ones((1, 1, 1, 1)), jnp.ones((1, 1, 1, 1)),
                      jnp.asarray([4]), probs)
    p = np.asarray(out.pos[0]).tolist()
    assert 1 not in p                 # lowest accumulated score, not protected
    assert 4 in p
    new_slot = p.index(4)
    assert np.isclose(np.asarray(out.score[0])[new_slot], 0.4)


@settings(max_examples=100, deadline=None)
@given(
    policy=st.sampled_from(["sliding_window", "streaming_llm", "h2o"]),
    budget=st.integers(4, 16),
    steps=st.integers(1, 12),
    seed=st.integers(0, 99),
)
def test_arena_invariants_under_decode(policy, budget, steps, seed):
    """Property: positions stay unique & valid; arena never exceeds budget;
    the newest token is always present after a write."""
    rng = np.random.RandomState(seed)
    pol = PolicyConfig(policy, n_sink=2)      # sinks < min budget
    P0 = budget
    pos0 = np.arange(P0)
    cache = SlotCache(
        k=jnp.zeros((1, P0, 1, 2)), v=jnp.zeros((1, P0, 1, 2)),
        pos=jnp.asarray(pos0[None], jnp.int32),
        score=jnp.asarray(rng.rand(1, P0).astype(np.float32)))
    t = P0
    for _ in range(steps):
        probs = rng.rand(1, cache.pos.shape[-1] + 1).astype(np.float32)
        cache = write_token(pol, cache, jnp.ones((1, 1, 1, 2)),
                            jnp.ones((1, 1, 1, 2)), jnp.asarray([t]),
                            jnp.asarray(probs))
        ps = np.asarray(cache.pos[0])
        valid = ps[ps >= 0]
        assert len(set(valid.tolist())) == len(valid)      # unique
        assert t in ps                                      # newest present
        assert len(ps) == P0                                # fixed arena
        if policy == "streaming_llm":
            assert 0 in ps and 1 in ps                      # sinks survive
        t += 1


def test_sink_h2o_protects_both_sets():
    """Beyond-paper composite policy: sinks AND heavy hitters AND recents."""
    scores = np.zeros(16)
    scores[[5, 7]] = 10.0
    k, v, pos, sc = _arena(P=16, scores=scores)
    c = compact(PolicyConfig("sink_h2o", n_sink=2, recent_frac=0.25), k, v,
                pos, sc, budget=8, t=16)
    kept = set(np.asarray(c.pos[0, 0]).tolist())
    assert {0, 1} <= kept          # sinks
    assert {5, 7} <= kept          # heavy hitters
    assert 15 in kept              # recency window (0.25 * 8 = 2 -> pos > 14)
