"""Training substrate: optimizer, schedules, data determinism, checkpointing."""

import pytest

pytestmark = pytest.mark.system

import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from _hypothesis_compat import given, settings, st

from repro import checkpoint as ckpt
from repro.data import DataConfig, batches
from repro.models import ModelConfig, init_params
from repro.training import (AdamWConfig, TrainBatch, init_opt_state,
                            schedule_lr, train_step)

CFG = ModelConfig(name="t", arch_type="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256)


def test_loss_decreases_on_learnable_task():
    """Overfit one fixed batch: the whole substrate (model+loss+AdamW) must
    drive training loss down hard (induction-head formation on fresh data
    takes thousands of steps — out of scope for a CPU unit test)."""
    params = init_params(jax.random.PRNGKey(0), CFG)
    opt = init_opt_state(params)
    ocfg = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=80,
                       weight_decay=0.0)
    dcfg = DataConfig(seq_len=64, global_batch=8, vocab_size=256)
    batch = next(batches(dcfg))
    step = jax.jit(lambda p, o, b: train_step(p, o, b, CFG, ocfg))
    losses = []
    for _ in range(80):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 1.0, (losses[0], losses[-1])


def test_remat_matches_no_remat_gradients():
    from repro.training.train_step import loss_fn
    params = init_params(jax.random.PRNGKey(1), CFG)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, 256)
    batch = TrainBatch(tokens=toks, targets=toks)
    g1 = jax.grad(lambda p: loss_fn(p, CFG, batch, remat=True)[0])(params)
    g2 = jax.grad(lambda p: loss_fn(p, CFG, batch, remat=False)[0])(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-2)


@settings(max_examples=50, deadline=None)
@given(warmup=st.integers(1, 100), total=st.integers(101, 10_000))
def test_lr_schedule_properties(warmup, total):
    cfg = AdamWConfig(lr=1e-3, warmup_steps=warmup, total_steps=total)
    lrs = [float(schedule_lr(cfg, jnp.asarray(s)))
           for s in [0, warmup // 2, warmup, (warmup + total) // 2, total]]
    assert all(lr >= 0 for lr in lrs)
    assert lrs[2] == pytest.approx(1e-3, rel=1e-5)        # peak at warmup end
    assert lrs[0] <= lrs[1] <= lrs[2] + 1e-9              # warmup monotone
    assert lrs[-1] <= lrs[2]                              # decays
    assert lrs[-1] >= cfg.lr * cfg.min_lr_frac - 1e-9     # floor


def test_data_deterministic_and_sharded():
    d1 = DataConfig(seq_len=32, global_batch=8, seed=7)
    b1 = next(batches(d1))
    b2 = next(batches(d1))
    assert (b1.tokens == b2.tokens).all()
    # shard 0 + shard 1 == full batch
    s0 = next(batches(DataConfig(seq_len=32, global_batch=8, seed=7,
                                 n_shards=2, shard_id=0)))
    s1 = next(batches(DataConfig(seq_len=32, global_batch=8, seed=7,
                                 n_shards=2, shard_id=1)))
    assert (np.concatenate([s0.tokens, s1.tokens]) == b1.tokens).all()


def test_checkpoint_roundtrip_bf16():
    params = init_params(jax.random.PRNGKey(3), CFG)
    opt = init_opt_state(params)
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 42, {"params": params, "opt": opt})
        assert ckpt.latest_step(d) == 42
        r = ckpt.restore(d, 42, {"params": params, "opt": opt})
        for a, b in zip(jax.tree.leaves(r), jax.tree.leaves(
                {"params": params, "opt": opt})):
            assert a.dtype == b.dtype
            assert (np.asarray(a, np.float32) == np.asarray(b, np.float32)).all()


def test_checkpoint_prune():
    params = {"w": jnp.ones((4,))}
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3, 4):
            ckpt.save(d, s, params)
        ckpt.prune(d, keep=2)
        assert ckpt.latest_step(d) == 4
        with pytest.raises(FileNotFoundError):
            ckpt.restore(d, 1, params)
