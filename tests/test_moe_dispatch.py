"""MoE dispatch: scatter-free path == einsum reference; drops; grads."""

import pytest

pytestmark = pytest.mark.fast

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from _hypothesis_compat import given, settings, st

from repro.models import moe as M
from repro.models.config import ModelConfig


def _cfg(E=4, K=2, cf=8.0):
    return ModelConfig(name="m", arch_type="moe", n_layers=1, d_model=32,
                       n_heads=2, n_kv_heads=2, d_ff=0, vocab_size=64,
                       n_experts=E, experts_per_tok=K, moe_d_ff=48,
                       capacity_factor=cf, dtype="float32",
                       param_dtype="float32")


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 50), E=st.sampled_from([2, 4, 8]),
       K=st.integers(1, 2))
def test_gather_dispatch_matches_einsum_no_drops(seed, E, K):
    cfg = _cfg(E=E, K=K, cf=16.0)      # capacity so large nothing drops
    p = M.init_moe(jax.random.PRNGKey(seed), cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 8, 32)) * 0.5
    o1, a1 = M.apply_moe(p, x, cfg)
    o2, a2 = M.apply_moe_einsum(p, x, cfg)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)
    np.testing.assert_allclose(float(a1), float(a2), atol=1e-6)


def test_gradients_match_einsum():
    cfg = _cfg()
    p = M.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 32)) * 0.5
    g1 = jax.grad(lambda pp, xx: M.apply_moe(
        M.MoeParams(**pp), xx, cfg)[0].sum(), argnums=(0, 1))(p._asdict(), x)
    g2 = jax.grad(lambda pp, xx: M.apply_moe_einsum(
        M.MoeParams(**pp), xx, cfg)[0].sum(), argnums=(0, 1))(p._asdict(), x)
    for k in g1[0]:
        np.testing.assert_allclose(np.asarray(g1[0][k]), np.asarray(g2[0][k]),
                                   atol=1e-4, err_msg=k)
    np.testing.assert_allclose(np.asarray(g1[1]), np.asarray(g2[1]), atol=1e-4)


def test_capacity_drops_are_bounded():
    """With tight capacity, output is finite and dropped tokens contribute 0."""
    cfg = _cfg(cf=0.25)
    p = M.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 32))
    out, aux = M.apply_moe(p, x, cfg)
    assert np.isfinite(np.asarray(out)).all()
    assert np.isfinite(float(aux))


def test_no_dispatch_dot_flops():
    """The sort/gather dispatch must add no dot FLOPs beyond the expert FFNs
    and the router (the §Perf A1 property)."""
    from repro.analysis.hlo_flops import analyze
    cfg = _cfg(E=8, K=2, cf=1.25)
    p = M.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64, 32))
    txt = jax.jit(lambda xx: M.apply_moe(p, xx, cfg)[0]) \
        .lower(x).compile().as_text()
    got = analyze(txt)["flops"]
    T, d, f, E, K = 4 * 64, 32, 48, 8, 2
    C = M.capacity(T, cfg)
    expert_flops = 2 * E * C * d * f * 3
    router_flops = 2 * T * d * E
    budget = expert_flops + router_flops
    assert got <= budget * 1.1, (got, budget)
