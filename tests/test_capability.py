"""Config-driven capability reporting + recurrent-arena / grouping units.

Fast-lane complement of tests/test_continuous_ssm.py: everything here is
pure config math or tiny jnp ops — no model params, no prefill compiles.
"""
import dataclasses
import re

import pytest

pytestmark = pytest.mark.fast

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ALL_ARCHS, get_reduced
from repro.core.allocation import recurrent_tier, total_state_bytes, uniform_plan
from repro.core.cache import (clear_state_row, insert_state_row,
                              insert_state_rows)
from repro.serving import ContinuousEngine, continuous_capability
from repro.serving.prefill import group_by_bucket


# ------------------------------------------------------------- capability
def test_capability_report_covers_every_config_family():
    """NO config family reports ok=False: frontend families (vlm/audio)
    admit through the embeds-native intake instead of being refused."""
    seen = set()
    for arch in ALL_ARCHS:
        cfg = get_reduced(arch)
        cap = continuous_capability(cfg)
        seen.add(cap.family)
        assert cap.family == cfg.arch_type
        assert cap.ok, (arch, cap.reason)
        assert cap.reason == ""
        assert cap.budgeted == cfg.has_attention
        if cfg.is_ssm_only or cfg.is_hybrid:
            assert cap.n_recurrent_layers == cfg.n_layers
            assert not cap.recurrent.is_empty
            assert cap.recurrent.bytes_per_row() > 0
        else:
            assert cap.n_recurrent_layers == 0
            assert cap.recurrent.is_empty
        if cfg.frontend is not None:
            assert cap.embeds_native
            assert cap.frontend == cfg.frontend
            assert cap.frontend_tokens == cfg.frontend_tokens > 0
            assert "intake" in cap.describe()
        else:
            assert not cap.embeds_native
        assert cap.describe().startswith(cfg.arch_type)
    assert seen == {"dense", "moe", "vlm", "audio", "ssm", "hybrid"}


def test_frontend_config_admits_and_unknown_frontend_refuses_precisely():
    """Embeds-carrying families ADMIT (the old token-prompts-only refusal
    is gone); the one refusal left is a frontend the intake has no encoder
    for, and the constructor raises it verbatim."""
    cfg = dataclasses.replace(get_reduced("qwen2-vl-7b"), frontend_tokens=16)
    cap = continuous_capability(cfg)
    assert cap.ok and cap.reason == ""
    assert "Engine.generate" not in cap.reason

    bad = dataclasses.replace(cfg, frontend="retina_v9")
    cap = continuous_capability(bad)
    assert not cap.ok
    assert "retina_v9" in cap.reason and "intake" in cap.reason
    assert "NOT admissible" in cap.describe()
    with pytest.raises(ValueError, match=re.escape(cap.reason[:40])):
        ContinuousEngine(None, bad, None, seed=0)


def test_hybrid_layer_count_must_divide_attn_period():
    """An indivisible hybrid layer count would silently drop layers in the
    stack AND mis-size the recurrent arenas — validate() rejects it, and
    the continuous engine validates before building anything."""
    cfg = dataclasses.replace(get_reduced("zamba2-2.7b"), n_layers=5)
    with pytest.raises(AssertionError):
        cfg.validate()
    with pytest.raises(AssertionError):
        ContinuousEngine(None, cfg, None, seed=0)


def test_recurrent_tier_fixed_cost_math():
    cfg = get_reduced("zamba2-2.7b")
    rt = recurrent_tier(cfg)
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    C = cfg.d_inner + 2 * cfg.ssm_state
    assert rt.state_elems == H * P * N
    assert rt.conv_elems == (cfg.ssm_conv_width - 1) * C
    per_row = cfg.n_layers * (rt.state_elems * 4 + rt.conv_elems * 2)
    assert rt.bytes_per_row() == per_row
    # total = budgeted KV + batch * fixed tier; with no plan only the tier
    assert total_state_bytes(None, rt, 3, cfg.n_kv_heads, cfg.hd) \
        == 3 * per_row
    plan = uniform_plan(2, 8)
    kv = 2 * (2 * 8) * 3 * cfg.n_kv_heads * cfg.hd * 2
    assert total_state_bytes(plan, rt, 3, cfg.n_kv_heads, cfg.hd) \
        == kv + 3 * per_row


# --------------------------------------------------- length-bucket grouping
def test_group_by_bucket_partitions_shortest_first():
    groups = group_by_bucket([5, 40, 7, 33, 8, 64], bucket=8)
    assert groups == [(8, [0, 2, 4]), (40, [1, 3]), (64, [5])]
    # every index appears exactly once
    idxs = sorted(i for _, g in groups for i in g)
    assert idxs == list(range(6))
    # zero-length prompts still land in the first bucket, never bucket 0
    assert group_by_bucket([0], 8) == [(8, [0])]


def test_group_by_bucket_single_bucket_is_one_group():
    assert group_by_bucket([3, 8, 1, 6], 8) == [(8, [0, 1, 2, 3])]


# ------------------------------------------------- recurrent-state arenas
def test_insert_state_rows_scatter_and_drop_sentinel():
    """Counterpart of the KV `insert_rows` invariants for plain state
    arrays: traced row-index vectors reuse one executable; the sentinel
    index B is dropped, never clamped onto row B-1."""
    B = 4
    arena = jnp.zeros((2, B, 3, 5), jnp.float32)
    rows_state = jnp.stack([jnp.full((2, 3, 5), 1.0),
                            jnp.full((2, 3, 5), 2.0)], axis=1)
    ins = jax.jit(insert_state_rows)
    out = ins(arena, rows_state, jnp.asarray([3, 1], jnp.int32))
    assert (np.asarray(out[:, 3]) == 1.0).all()
    assert (np.asarray(out[:, 1]) == 2.0).all()
    assert (np.asarray(out[:, 0]) == 0.0).all()
    assert (np.asarray(out[:, 2]) == 0.0).all()
    out = ins(arena, rows_state, jnp.asarray([0, 2], jnp.int32))
    assert ins._cache_size() == 1                          # no retrace
    out = ins(arena, rows_state, jnp.asarray([1, B], jnp.int32))
    assert (np.asarray(out[:, 1]) == 1.0).all()
    assert (np.asarray(out[:, B - 1]) == 0.0).all()        # dropped


def test_insert_state_row_traced_index_single_request():
    """Single-request counterpart: one executable serves every slot."""
    arena = jnp.zeros((2, 4, 3, 5), jnp.float32)
    row_state = jnp.full((2, 1, 3, 5), 7.0)
    ins = jax.jit(insert_state_row)
    out = ins(arena, row_state, 2)
    assert (np.asarray(out[:, 2]) == 7.0).all()
    assert (np.asarray(out[:, [0, 1, 3]]) == 0.0).all()
    out = ins(arena, row_state, 0)
    assert ins._cache_size() == 1                          # no retrace
    # dtype cast on insert mirrors the KV insert_row discipline
    out = insert_state_row(arena, row_state.astype(jnp.bfloat16), 1)
    assert out.dtype == arena.dtype


def test_clear_state_row_zeroes_one_row():
    arena = jnp.ones((3, 4, 2, 6), jnp.float32)
    clr = jax.jit(clear_state_row)
    out = clr(arena, 2)
    assert (np.asarray(out[:, 2]) == 0.0).all()
    assert (np.asarray(out[:, [0, 1, 3]]) == 1.0).all()
    out = clr(arena, 0)
    assert clr._cache_size() == 1                          # traced row index
