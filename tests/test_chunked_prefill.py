"""Chunked prefill co-scheduled with decode (DESIGN.md §5, ISSUE-8).

The load-bearing property: streaming a long prompt into a decode slot one
`chunk_len`-token chunk per fused block — resident rows decoding the whole
time — is token-identical to the monolithic bucketed admission AND to solo
`Engine.generate`, across dense / hybrid / ssm families, contiguous and
paged layouts.  Fast-lane units pin the pieces: the chunk planner's
boundary math, the ctor alignment contracts, the staged carry-in position
bookkeeping, and the paged `pages_needed` interaction.
"""
import pytest

import numpy as np

import jax

from repro.core import PolicyConfig
from repro.core.paging import pages_needed
from repro.models import ModelConfig, init_params
from repro.serving import (ContinuousConfig, ContinuousEngine,
                           ContinuousScheduler, Engine, EngineConfig,
                           pad_prompt)
from repro.serving.prefill import plan_chunks

DENSE = ModelConfig(name="s", arch_type="dense", n_layers=2, d_model=64,
                    n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97,
                    dtype="float32", param_dtype="float32")
HYBRID = ModelConfig(name="h", arch_type="hybrid", n_layers=4, d_model=64,
                     n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97,
                     ssm_state=8, ssm_expand=2, ssm_head_dim=32, ssm_chunk=8,
                     attn_period=2, dtype="float32", param_dtype="float32")
SSM = ModelConfig(name="m", arch_type="ssm", n_layers=2, d_model=64,
                  n_heads=1, n_kv_heads=1, head_dim=32, d_ff=0, vocab_size=97,
                  ssm_state=8, ssm_expand=2, ssm_head_dim=32, ssm_chunk=8,
                  dtype="float32", param_dtype="float32")

ECFG = EngineConfig(mode="uniform", policy=PolicyConfig("sliding_window"),
                    budget_abs=12, bucket=4, min_budget=4)


def _ccfg(**kw):
    base = dict(max_concurrency=3, prompt_bucket=8, max_prompt_len=24,
                max_new_cap=8, sync_every=2, chunked_prefill=True,
                chunk_len=8)
    base.update(kw)
    return ContinuousConfig(**base)


_PARAMS = {}


def _params(cfg):
    if cfg.name not in _PARAMS:
        _PARAMS[cfg.name] = init_params(jax.random.PRNGKey(0), cfg)
    return _PARAMS[cfg.name]


def _prompts(seed=1, lens=(6, 21, 5, 19, 9)):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, 97, (n,)).astype(np.int32) for n in lens]


def _run(cfg, ccfg, prompts, max_new=6):
    sched = ContinuousScheduler(_params(cfg), cfg, ECFG, ccfg, seed=0)
    for p in prompts:
        sched.submit(p, max_new=max_new)
    done = sched.run_until_empty()
    return {r.rid: r.tokens for r in done}, sched


# ------------------------------------------------------------ planner units
@pytest.mark.fast
def test_plan_chunks_non_divisible_boundary_math():
    # t=33, bucket=8 -> P=40; chunk_len=16 -> chunks 16/16/8
    p = np.arange(33, dtype=np.int32)
    plan = plan_chunks(p, chunk_len=16, bucket=8)
    assert plan.t == 33 and plan.total == 40
    assert plan.starts == (0, 16, 32) and plan.lens == (16, 16, 8)
    assert plan.n_chunks == 3
    # bucket-padded token stream: prompt prefix, zero pad, prefix validity
    assert np.array_equal(plan.tokens[:33], p)
    assert np.all(plan.tokens[33:] == 0)
    assert plan.valid[:33].all() and not plan.valid[33:].any()
    # the last VALID token lands in the FINAL chunk (P < t + chunk_len):
    # interior chunks are fully valid, only the final one carries padding
    assert plan.starts[-1] <= plan.t - 1
    for s, ln in zip(plan.starts[:-1], plan.lens[:-1]):
        assert plan.valid[s:s + ln].all()


@pytest.mark.fast
def test_plan_chunks_exact_multiples_and_single_chunk():
    plan = plan_chunks(np.arange(32, dtype=np.int32), chunk_len=16, bucket=8)
    assert plan.starts == (0, 16) and plan.lens == (16, 16)
    tiny = plan_chunks(np.arange(3, dtype=np.int32), chunk_len=16, bucket=8)
    assert tiny.starts == (0,) and tiny.lens == (8,) and tiny.total == 8


@pytest.mark.fast
def test_plan_chunks_validates_contracts():
    p = np.arange(20, dtype=np.int32)
    with pytest.raises(ValueError, match="multiple of"):
        plan_chunks(p, chunk_len=12, bucket=8)        # not a bucket multiple
    with pytest.raises(ValueError, match="multiple of ssm_chunk"):
        plan_chunks(p, chunk_len=16, bucket=4, ssm_chunk=8)
    with pytest.raises(ValueError, match="exceeds"):
        plan_chunks(p, chunk_len=8, bucket=8, max_len=16)


@pytest.mark.fast
def test_ctor_enforces_chunk_alignment():
    # chunk_len must be a prompt_bucket multiple
    with pytest.raises(ValueError, match="multiple of prompt_bucket"):
        ContinuousEngine(None, DENSE, ECFG, _ccfg(chunk_len=12))
    # recurrent families additionally need bucket % ssm_chunk == 0 so every
    # chunk boundary lands on the SSD chunk grid
    with pytest.raises(ValueError, match="multiple of ssm_chunk"):
        ContinuousEngine(None, SSM, ECFG,
                         _ccfg(prompt_bucket=4, chunk_len=4,
                               max_prompt_len=24))


# ----------------------------------------------- staged carry-in bookkeeping
@pytest.mark.fast
def test_chunk_staging_position_bookkeeping():
    """After each mid chunk the staging buffer holds absolute positions for
    exactly the tokens staged so far (-1 beyond), and the engine reports
    `prefilled_len < prompt_len` — the partially-prefilled contract."""
    cfg = DENSE
    core = ContinuousEngine(_params(cfg), cfg, ECFG, _ccfg())
    core.admit_many([(np.arange(5, dtype=np.int32) % 97, 2)])  # calibrate
    while core.n_occupied:
        core.decode_block()
    prompt = _prompts(seed=3, lens=(21,))[0]
    core.begin_chunked(prompt, max_new=4)        # P=24, chunks 8/8/8
    assert core.n_pending == 1 and core.pending_prefilled_len == 0
    seen = 0
    while core.n_pending:
        core.decode_block()
        if core.n_pending:                       # mid chunk landed
            seen += 8
            assert core.pending_prefilled_len == seen
            cpos = np.asarray(core.state.chunk[2])[0]
            assert np.array_equal(cpos[:seen], np.arange(seen))
            assert np.all(cpos[seen:] == -1)
    # final chunk flipped the row live inside the same fused block
    assert core.n_occupied == 1 and core.pending_prefilled_len == 0
    while core.n_occupied:
        core.decode_block()


@pytest.mark.fast
def test_begin_chunked_requires_calibrated_plan():
    core = ContinuousEngine(_params(DENSE), DENSE, ECFG, _ccfg())
    with pytest.raises(AssertionError, match="calibrated plan"):
        core.begin_chunked(np.arange(20, dtype=np.int32), max_new=4)


# ------------------------------------------------------ paged interaction
@pytest.mark.fast
def test_chunked_row_page_allocation_matches_pages_needed():
    """`begin_chunked` allocates the row's FULL `pages_needed` quota up
    front (admission headroom identical to the monolithic path), holds the
    pages unscattered through the mid chunks — the per-poll audit stays
    clean — and frees them at retirement, squeezed tail included."""
    cfg = DENSE
    ccfg = _ccfg(page_size=4, audit_pool=True)
    core = ContinuousEngine(_params(cfg), cfg, ECFG, ccfg)
    core.admit_many([(np.arange(5, dtype=np.int32), 2)])       # calibrate
    while core.n_occupied:
        core.decode_block()
    core.audit_pool(deep=True)
    free0 = core._pool.n_free
    prompt = _prompts(seed=3, lens=(21,))[0]
    mn = 4
    slot = core.begin_chunked(prompt, max_new=mn)
    plan = core.plan
    # budget squeezes the tail: the quota covers min(P, budget) live slots
    # per tier layer, NOT the full prompt
    expect = (plan.n_big * pages_needed(len(prompt), plan.b_big, mn, 4)
              + plan.n_small * pages_needed(len(prompt), plan.b_small, mn, 4))
    assert len(core._row_pages[slot]) == expect
    assert core._pool.n_free == free0 - expect
    while core.n_pending or core.n_occupied:
        core.decode_block()
        core.audit_pool(deep=True)               # pending pages stay booked
    assert core._pool.n_free == free0            # retired row freed its quota


# ------------------------------------------------------------ system identity
@pytest.mark.parametrize("cfg", [DENSE, HYBRID, SSM],
                         ids=["dense", "hybrid", "ssm"])
@pytest.mark.parametrize("layout", ["contiguous", "paged"])
def test_chunked_identical_to_monolithic_and_solo(cfg, layout):
    if layout == "paged" and cfg is SSM:
        pytest.skip("paged arenas need attention layers")
    extra = {"page_size": 4} if layout == "paged" else {}
    prompts = _prompts()
    base, _ = _run(cfg, _ccfg(chunked_prefill=False, chunk_len=0, **extra),
                   prompts)
    ch, sched = _run(cfg, _ccfg(**extra), prompts)
    # the 21-token prompt rides the FIRST burst monolithically (chunk
    # routing needs the calibrated plan, built on first admission); the
    # later 19- and 9-token arrivals exceed chunk_len=8 and stream
    # chunked: P=24 and P=16 staged tokens
    assert sched.core.chunked_admitted == 2
    assert sched.core.chunk_tokens_prefilled == 24 + 16
    for rid in base:
        assert np.array_equal(base[rid], ch[rid]), rid
    solo = Engine(_params(cfg), cfg, ECFG)
    for i, p in enumerate(prompts):
        toks, valid = pad_prompt(p, 8)
        r = solo.generate(tokens=toks, valid=valid, max_new_tokens=6)
        assert np.array_equal(np.asarray(r.tokens[0]), ch[i]), i


@pytest.mark.parametrize("packed", [False, True], ids=["bucketed", "packed"])
def test_chunked_with_short_burst_layouts(packed):
    """Shorts admitted behind a streaming long prompt (out-of-order — the
    point of chunked admission) stay identical whichever admission layout
    the burst uses.  The 22-token prompt leads the queue, so it rides the
    first (calibrating) burst monolithically; only the trailing 20-token
    prompt streams chunked."""
    cfg = HYBRID
    prompts = _prompts(seed=5, lens=(22, 6, 7, 5, 20))
    base, _ = _run(cfg, _ccfg(chunked_prefill=False, chunk_len=0,
                              packed_prefill=packed), prompts)
    ch, sched = _run(cfg, _ccfg(packed_prefill=packed), prompts)
    assert sched.core.chunked_admitted == 1
    for rid in base:
        assert np.array_equal(base[rid], ch[rid]), rid


# ------------------------------------------------------------- zero retrace
@pytest.mark.fast
def test_chunked_admission_never_retraces():
    """Repeated long-prompt traffic reuses ONE executable per
    (chunk_len, final) pair — `start`, the row index, and the page tables
    are traced operands."""
    cfg = DENSE
    # shorts lead the queue so the calibrating first burst is all-short
    # and every long prompt streams chunked
    prompts = _prompts(seed=9, lens=(6, 5, 7, 17, 21, 19, 23))
    _, sched = _run(cfg, _ccfg(), prompts)
    core = sched.core
    assert core.chunked_admitted == 4
    assert core.chunk_dispatches > len(core._chunk_fns)
    assert all(fn._cache_size() == 1 for fn in core._chunk_fns.values())
    assert core._chunk_reset_fn._cache_size() == 1
    assert all(fn._cache_size() == 1 for fn in core._block_fns.values())
