"""End-to-end behaviour tests for the SqueezeAttention serving system.

The central correctness property: a *full-cache* decode loop must produce
exactly the tokens a teacher-forced forward pass predicts — the slot arena,
tier scan, eviction bookkeeping, and RoPE-by-original-position must be
invisible when nothing is evicted.  Then: sliding-window eviction at budget
== model window must equal full cache (the window mask already hides what
the policy evicts).
"""

import pytest

pytestmark = pytest.mark.system

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import PolicyConfig
from repro.models import ModelConfig, forward, init_params
from repro.serving import Engine, EngineConfig

F32 = dict(dtype="float32", param_dtype="float32")


def _greedy_reference(params, cfg, prompt, n_new):
    """Teacher-forced greedy continuation via full forward passes."""
    toks = prompt.copy()
    out = []
    for _ in range(n_new):
        logits = forward(params, cfg, tokens=jnp.asarray(toks)).logits
        nxt = int(np.argmax(np.asarray(logits[:, -1]), -1)[0])
        out.append(nxt)
        toks = np.concatenate([toks, [[nxt]]], axis=1)
    return out


def _engine_tokens(params, cfg, prompt, n_new, mode, policy="sliding_window",
                   **ekw):
    eng = Engine(params, cfg, EngineConfig(
        mode=mode, policy=PolicyConfig(policy), max_new_tokens=n_new, **ekw))
    r = eng.generate(tokens=prompt)
    return r.tokens[0].tolist(), r


CASES = {
    "dense-gqa": ModelConfig(name="d", arch_type="dense", n_layers=3,
                             d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                             vocab_size=97, **F32),
    "dense-window": ModelConfig(name="w", arch_type="dense", n_layers=2,
                                d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                                vocab_size=97, sliding_window=8,
                                window_pattern="local_global", **F32),
    # capacity_factor high enough that no token ever drops: the equivalence
    # under test is cache/decode correctness, not router-drop timing (which
    # legitimately differs between batched prefill and per-token decode).
    "moe": ModelConfig(name="m", arch_type="moe", n_layers=2, d_model=64,
                       n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=97,
                       n_experts=4, experts_per_tok=2, moe_d_ff=96,
                       capacity_factor=8.0, **F32),
    "ssm": ModelConfig(name="s", arch_type="ssm", n_layers=2, d_model=64,
                       n_heads=1, n_kv_heads=1, d_ff=0, vocab_size=97,
                       ssm_state=16, ssm_head_dim=32, ssm_chunk=8, **F32),
    "hybrid": ModelConfig(name="h", arch_type="hybrid", n_layers=4,
                          d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                          vocab_size=97, ssm_state=16, ssm_head_dim=32,
                          ssm_chunk=8, attn_period=2, **F32),
}


@pytest.mark.parametrize("case", list(CASES))
def test_full_cache_decode_matches_forward(case):
    cfg = CASES[case]
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompt = np.random.RandomState(0).randint(
        0, cfg.vocab_size, (1, 16)).astype(np.int32)
    n_new = 6
    ref = _greedy_reference(params, cfg, prompt, n_new)
    got, _ = _engine_tokens(params, cfg, prompt, n_new, "full")
    assert got == ref, f"{case}: {got} != {ref}"


def test_sliding_budget_equals_window():
    """budget == model window -> eviction is invisible (same tokens)."""
    cfg = dataclasses.replace(CASES["dense-window"], sliding_window=8,
                              window_pattern=None)
    params = init_params(jax.random.PRNGKey(1), cfg)
    prompt = np.random.RandomState(1).randint(0, 97, (1, 24)).astype(np.int32)
    full, _ = _engine_tokens(params, cfg, prompt, 8, "full")
    evict, r = _engine_tokens(params, cfg, prompt, 8, "uniform",
                              budget_abs=8, bucket=4, min_budget=4)
    assert r.plan.b_big == 8
    assert evict == full


def test_squeeze_reduces_cache_and_stays_coherent():
    cfg = CASES["dense-gqa"]
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompt = np.random.RandomState(2).randint(0, 97, (2, 32)).astype(np.int32)
    _, r_full = _engine_tokens(params, cfg, prompt, 8, "full")
    _, r_sq = _engine_tokens(params, cfg, prompt, 8, "squeeze",
                             budget_frac=0.5, bucket=4, min_budget=4)
    assert r_sq.cache_slots < r_full.cache_slots


@pytest.mark.parametrize("policy", ["sliding_window", "streaming_llm", "h2o"])
def test_policies_generate(policy):
    cfg = CASES["dense-gqa"]
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompt = np.random.RandomState(3).randint(0, 97, (1, 32)).astype(np.int32)
    got, r = _engine_tokens(params, cfg, prompt, 6, "squeeze", policy,
                            budget_frac=0.4, bucket=4, min_budget=4)
    assert len(got) == 6
    assert r.plan.b_small < r.plan.b_big


def test_cosine_sims_show_depth_pattern():
    """Fig-2 observation: cosine similarity exists per layer and is sane."""
    cfg = dataclasses.replace(CASES["dense-gqa"], n_layers=6)
    params = init_params(jax.random.PRNGKey(4), cfg)
    toks = np.random.RandomState(4).randint(0, 97, (4, 64)).astype(np.int32)
    out = forward(params, cfg, tokens=jnp.asarray(toks))
    cs = np.asarray(out.cos_sims).mean(-1)
    assert cs.shape == (6,)
    assert (cs > -1.01).all() and (cs < 1.01).all()
    # residual stream grows with depth -> later layers change it less
    assert cs[-1] > cs[0]


def test_mrope_decode_matches_forward():
    cfg = ModelConfig(name="v", arch_type="vlm", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97,
                      mrope_sections=(4, 2, 2), **F32)
    params = init_params(jax.random.PRNGKey(5), cfg)
    prompt = np.random.RandomState(5).randint(0, 97, (1, 12)).astype(np.int32)
    ref = _greedy_reference(params, cfg, prompt, 4)
    got, _ = _engine_tokens(params, cfg, prompt, 4, "full")
    assert got == ref


def test_sink_h2o_generates():
    cfg = CASES["dense-gqa"]
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompt = np.random.RandomState(7).randint(0, 97, (1, 32)).astype(np.int32)
    got, r = _engine_tokens(params, cfg, prompt, 4, "squeeze", "sink_h2o",
                            budget_frac=0.4, bucket=4, min_budget=4)
    assert len(got) == 4
