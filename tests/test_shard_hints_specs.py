"""Sharding hints + launch specs behave sanely without a mesh (CPU paths)."""
import pytest

pytestmark = pytest.mark.fast

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.launch.specs import SHAPES, dryrun_plan
from repro.models.shard_hints import hint


def test_hint_is_noop_without_mesh():
    x = jnp.ones((8, 16))
    y = hint(x, {0: "batch", 1: "model"})
    assert (np.asarray(y) == 1).all()
    assert y.shape == x.shape


def test_hint_inside_jit_without_mesh():
    f = jax.jit(lambda x: hint(x, {0: "model"}) * 2)
    assert float(f(jnp.ones((4, 4))).sum()) == 32.0


def test_shapes_table():
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k",
                           "long_500k"}
    assert SHAPES["train_4k"].kind == "train"
    assert SHAPES["long_500k"].global_batch == 1


def test_dryrun_plans_all_archs():
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        for seq in (32_768, 524_288):
            full = dryrun_plan(cfg, seq, "full")
            sq = dryrun_plan(cfg, seq, "squeeze")
            assert full.total >= sq.total
            if cfg.has_attention and full.n_layers > 1:
                # squeeze budgets shard on the 16-way data axis (long_500k)
                assert sq.b_small % 16 == 0 and sq.b_big % 16 == 0


def test_padded_vocab_masking():
    import dataclasses
    from repro.models import ModelConfig, forward, init_params
    cfg = ModelConfig(name="pv", arch_type="dense", n_layers=1, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=50,
                      padded_vocab=64, dtype="float32", param_dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    assert params["embed"].shape[0] == 64
    toks = jnp.zeros((1, 4), jnp.int32)
    out = forward(params, cfg, tokens=toks)
    logits = np.asarray(out.logits)
    assert logits.shape[-1] == 64
    assert (logits[..., 50:] <= -1e29).all()      # pad region masked
    assert (logits[..., :50] > -1e29).all()
