"""Paged KV arenas + prefix caching under continuous batching.

The load-bearing property mirrors tests/test_continuous.py: paging is a
STORAGE-layout change, not a model or scheduling change, so per-request
outputs through the page-table engine — admit → fused decode blocks →
retire → recycle — must be token-identical to the contiguous-arena engine
and to solo `Engine.generate`, for page sizes that divide the budgets and
page sizes that do not, across dense / hybrid / ssm / multimodal families
and both prefill layouts.  On top of that sit the paged-only invariants:
zero retraces (page tables are traced data), the `pages_needed` release
bound, full pool drain at retirement, and prefix-hit admissions that skip
cached prompt chunks yet emit the same tokens.
"""
import pytest

pytestmark = pytest.mark.system

import numpy as np

import jax

from repro.core import PolicyConfig
from repro.core.paging import pages_for, pages_needed
from repro.models import ModelConfig, init_params
from repro.serving import (ContinuousConfig, ContinuousEngine,
                           ContinuousScheduler, Engine, EngineConfig,
                           ImageSegment, IntakeEncoder, MultimodalRequest,
                           TextSegment, pad_embeds, pad_prompt)

DENSE = ModelConfig(name="s", arch_type="dense", n_layers=2, d_model=64,
                    n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97,
                    dtype="float32", param_dtype="float32")
HYBRID = ModelConfig(name="h", arch_type="hybrid", n_layers=4, d_model=64,
                     n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97,
                     ssm_state=8, ssm_expand=2, ssm_head_dim=32, ssm_chunk=8,
                     attn_period=2, dtype="float32", param_dtype="float32")
SSM = ModelConfig(name="m", arch_type="ssm", n_layers=2, d_model=64,
                  n_heads=1, n_kv_heads=1, head_dim=32, d_ff=0, vocab_size=97,
                  ssm_state=8, ssm_expand=2, ssm_head_dim=32, ssm_chunk=8,
                  dtype="float32", param_dtype="float32")
VLM = ModelConfig(name="v", arch_type="vlm", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97,
                  mrope_sections=(4, 2, 2), frontend="vision_stub",
                  frontend_tokens=8, dtype="float32", param_dtype="float32")

ECFG = EngineConfig(mode="uniform", policy=PolicyConfig("sliding_window"),
                    budget_abs=12, bucket=4, min_budget=4)

SPECS = [(5, 4), (11, 7), (16, 8), (3, 1), (9, 6), (20, 5)]


def _ccfg(**kw):
    base = dict(max_concurrency=3, prompt_bucket=8, max_prompt_len=24,
                max_new_cap=8, sync_every=2)
    base.update(kw)
    return ContinuousConfig(**base)


def _params(cfg):
    return init_params(jax.random.PRNGKey(0), cfg)


def _run_stream(params, cfg, ccfg, specs, seed=0):
    """Serve one request stream; returns (core, per-request token lists)."""
    sched = ContinuousScheduler(params, cfg, ECFG, ccfg)
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, 97, (n,)).astype(np.int32) for n, _ in specs]
    rids = [sched.submit(p, max_new=mn)
            for p, (_, mn) in zip(prompts, specs)]
    done = {r.rid: r for r in sched.run_until_empty()}
    assert len(done) == len(specs)
    return sched.core, [done[rid].tokens.tolist() for rid in rids]


def _assert_pool_drained(core):
    """Retirement returned every row page; no leak survives the stream."""
    assert core._pool is not None
    assert core._pool.n_resident == (core._prefix.resident_pages
                                     if core._prefix is not None else 0)
    assert all(not pages for pages in core._row_pages)


# ------------------------------------------------------------ token identity
@pytest.mark.parametrize("psize", [4, 5], ids=["psize4", "psize5"])
def test_paged_dense_matches_contiguous_and_solo(psize):
    """Same stream through contiguous arenas and through the page pool —
    page size 4 divides the 12-slot budget, 5 tears the last page — plus
    the solo anchor.  6 requests on 3 slots force recycling through
    recycled PAGES, not just recycled rows."""
    params = _params(DENSE)
    _, contiguous = _run_stream(params, DENSE, _ccfg(), SPECS)
    core, paged = _run_stream(params, DENSE, _ccfg(page_size=psize), SPECS)
    assert paged == contiguous
    assert core._paged and core.pool_pages > 0
    _assert_pool_drained(core)
    assert core.pool_occupancy == 0.0

    solo = Engine(params, DENSE, ECFG)
    rng = np.random.default_rng(0)
    for i, (n, mn) in enumerate(SPECS):
        toks, valid = pad_prompt(rng.integers(0, 97, (n,)).astype(np.int32),
                                 8)
        ref = solo.generate(tokens=toks, valid=valid,
                            max_new_tokens=mn).tokens[0]
        assert paged[i] == ref.tolist(), i


@pytest.mark.parametrize("cfg", [HYBRID, SSM], ids=["hybrid", "ssm"])
def test_paged_recurrent_families_match_contiguous(cfg):
    """Hybrid: attention tiers page, recurrent state stays a dense row
    tensor.  Pure SSM: `page_size` is a documented no-op (no attention
    layers -> no pool), never an error."""
    params = _params(cfg)
    _, contiguous = _run_stream(params, cfg, _ccfg(), SPECS)
    core, paged = _run_stream(params, cfg, _ccfg(page_size=4), SPECS)
    assert paged == contiguous
    if cfg is SSM:
        assert not core._paged and core._pool is None
        assert core.pool_pages == 0
    else:
        assert core._paged
        _assert_pool_drained(core)


def test_paged_packed_admission_matches_bucketed():
    """Packed prefill scatters straight into pages: same tokens as the
    bucketed contiguous path (the documented packed identity scope)."""
    params = _params(DENSE)
    _, bucketed = _run_stream(params, DENSE, _ccfg(), SPECS)
    core, packed = _run_stream(
        params, DENSE, _ccfg(packed_prefill=True, pack_len=24, page_size=4),
        SPECS)
    assert packed == bucketed
    assert core._paged
    _assert_pool_drained(core)


def test_paged_multimodal_matches_solo():
    """Embeds-native admission (vlm) through the page pool: identical to
    solo generate on the same stub embeds; embeds prompts page like token
    prompts (only the PREFIX CACHE is token-keyed and skips them)."""
    params = _params(VLM)
    ccfg = _ccfg(max_prompt_len=40, page_size=4)
    sched = ContinuousScheduler(params, VLM, ECFG, ccfg)
    rng = np.random.default_rng(0)
    specs = [(9, 5, 4), (4, 11, 7), (16, 8, 8)]
    reqs = [MultimodalRequest(
        (ImageSegment(nf),
         TextSegment(rng.integers(0, 97, (nt,)).astype(np.int32))),
        max_new=mn, seed=100 + i) for i, (nf, nt, mn) in enumerate(specs)]
    rids = [sched.submit_multimodal(r) for r in reqs]
    done = {r.rid: r for r in sched.run_until_empty()}
    assert sched.core._paged
    _assert_pool_drained(sched.core)

    enc = IntakeEncoder(params, VLM)
    solo = Engine(params, VLM, ECFG)
    for rid, req in zip(rids, reqs):
        emb, valid = pad_embeds([enc.encode_request(req)], 8)
        ref = solo.generate(embeds=emb, valid=valid,
                            max_new_tokens=req.max_new).tokens[0]
        assert done[rid].tokens.tolist() == ref.tolist(), rid


# -------------------------------------------------- zero retrace + recycling
def test_paged_admission_never_retraces_and_recycles_pages():
    """Page tables are DATA: requests landing on different slots with
    different page-id lists (mixed prompt lengths and max_new => different
    `pages_needed` counts, recycled ids on the second wave) reuse one
    compiled executable per (batch, prompt-bucket) key and per block
    length."""
    params = _params(DENSE)
    core, _ = _run_stream(params, DENSE, _ccfg(page_size=4),
                          SPECS + [(7, 3), (13, 2), (8, 4)], seed=1)
    assert core.admitted == 9
    assert set(core._block_fns) <= set(range(1, 3))
    assert all(fn._cache_size() == 1 for fn in core._block_fns.values())
    assert core._clear_fn._cache_size() == 1
    assert all(fn._cache_size() == 1 for fn in core._admit_fns.values())
    assert core.admit_dispatches < core.admitted
    # every slot recycled, every page back in the pool
    assert sorted(core._free) == list(range(3))
    assert (np.asarray(core.state.dec.tiers[0].pos) == -1).all()
    _assert_pool_drained(core)


def test_pages_needed_release_bound_holds_in_flight():
    """Mid-flight residency equals the `pages_needed` bound — strictly
    below the per-layer quota: sequence-wise squeezing RELEASED the tail
    pages at admission instead of parking them on the row."""
    params = _params(DENSE)
    sched = ContinuousScheduler(params, DENSE, ECFG, _ccfg(page_size=4))
    t, mn = 3, 4
    sched.submit(np.arange(t, dtype=np.int32) + 1, max_new=mn)
    sched.poll()                           # admit + first decode block only
    core = sched.core
    assert core.n_occupied == 1
    per_layer = pages_needed(t, ECFG.budget_abs, mn, 4)
    assert per_layer < pages_for(ECFG.budget_abs, 4)       # a real release
    assert core._pool.n_resident == DENSE.n_layers * per_layer
    assert sum(len(p) for p in core._row_pages) == core._pool.n_resident
    sched.run_until_empty()
    _assert_pool_drained(core)


# ----------------------------------------------------------- prefix caching
def test_prefix_hit_admission_matches_solo():
    """Two waves sharing an 8-token system prefix: wave 1 is cold (tree is
    empty), wave 2 admits through context prefill — cached chunks are
    REFERENCED, only suffixes run the transformer — and every request in
    both waves still matches its solo reference token-for-token.  The ctx
    admit, the KV-insert scatter, and the decode blocks each stay one
    compiled executable."""
    params = _params(DENSE)
    sched = ContinuousScheduler(params, DENSE, ECFG,
                                _ccfg(page_size=4, prefix_cache=True))
    core = sched.core
    rng = np.random.default_rng(7)
    shared = rng.integers(0, 97, (8,)).astype(np.int32)
    tails = [rng.integers(0, 97, (n,)).astype(np.int32)
             for n in (4, 6, 9, 5, 12, 3)]
    prompts = [np.concatenate([shared, t]) for t in tails]
    max_news = [4, 7, 5, 8, 3, 6]

    done, rids = {}, []
    for wave in (prompts[:3], prompts[3:]):              # 3 rows per wave
        offset = len(rids)
        rids += [sched.submit(p, max_new=mn)
                 for p, mn in zip(wave, max_news[offset:offset + 3])]
        done.update({r.rid: r for r in sched.run_until_empty()})
    assert len(done) == 6

    # wave 1 missed (cold tree), wave 2 hit the shared 2-chunk prefix
    assert core.prefix_hits == 3
    assert core.prompt_tokens_referenced == 3 * len(shared)
    assert core._prefix.n_nodes > 0 and core.prefix_insert_dispatches > 0
    # identity: hits and misses alike
    solo = Engine(params, DENSE, ECFG)
    for rid, p, mn in zip(rids, prompts, max_news):
        toks, valid = pad_prompt(p, 8)
        ref = solo.generate(tokens=toks, valid=valid,
                            max_new_tokens=mn).tokens[0]
        assert done[rid].tokens.tolist() == ref.tolist(), rid
    # zero retrace across plain admits, ctx admits, inserts, decode blocks
    assert all(fn._cache_size() == 1 for fn in core._admit_fns.values())
    assert any(k[0] == "ctx" for k in core._admit_fns)
    assert all(fn._cache_size() == 1 for fn in core._insert_fns.values())
    assert all(fn._cache_size() == 1 for fn in core._block_fns.values())
    # rows drained; only the tree's refcounted residency remains
    assert sorted(core._free) == list(range(3))
    _assert_pool_drained(core)
    assert core._pool.n_resident == core._prefix.resident_pages > 0


def test_prefix_cache_gating_errors():
    """Unsupported combinations fail LOUDLY at engine construction, not
    silently mid-serve."""
    params = _params(DENSE)
    with pytest.raises(ValueError, match="page_size"):
        ContinuousEngine(params, DENSE, ECFG, _ccfg(page_size=-1))
    with pytest.raises(ValueError, match="prefix_cache requires page_size"):
        ContinuousEngine(params, DENSE, ECFG, _ccfg(prefix_cache=True))
    with pytest.raises(ValueError, match="packed_prefill"):
        ContinuousEngine(params, DENSE, ECFG,
                         _ccfg(page_size=4, prefix_cache=True,
                               packed_prefill=True, pack_len=24))
    with pytest.raises(ValueError, match="attention-only"):
        ContinuousEngine(_params(HYBRID), HYBRID, ECFG,
                         _ccfg(page_size=4, prefix_cache=True))
    with pytest.raises(ValueError, match="non-accumulating"):
        ContinuousEngine(params, DENSE,
                         EngineConfig(mode="uniform",
                                      policy=PolicyConfig("h2o"),
                                      budget_abs=12, bucket=4, min_budget=4),
                         _ccfg(page_size=4, prefix_cache=True))
