"""Optional-`hypothesis` shim so tier-1 collection never needs the extra.

Property-based tests import ``given / settings / st`` from here instead of
from ``hypothesis`` directly.  When the extra is installed (see
pyproject.toml ``[project.optional-dependencies] hypothesis``) the real
decorators pass straight through; without it the decorated tests collect as
explicit skips instead of failing the whole module at import time.
"""
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Accepts any `st.<strategy>(...)` call made inside @given(...)."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def settings(*a, **k):
        return lambda f: f

    def given(*a, **k):
        def deco(f):
            @pytest.mark.skip(reason="property test needs the "
                              "'hypothesis' extra")
            def skipped():
                pass
            skipped.__name__ = f.__name__
            skipped.__doc__ = f.__doc__
            return skipped
        return deco
