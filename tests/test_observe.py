"""Observation study: token x layer cosine matrix (paper Fig 2 / A.3)."""
import pytest

pytestmark = pytest.mark.fast

import dataclasses

import jax
import numpy as np

from repro.analysis.observe import cos_sim_matrix, important_set, task_stability
from repro.configs import get_reduced
from repro.models import init_params


def test_cos_sim_matrix_shape_and_trend():
    cfg = dataclasses.replace(get_reduced("llama2-7b"), n_layers=6)
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, 24)).astype(np.int32)
    mat = cos_sim_matrix(params, cfg, toks)
    assert mat.shape == (6, 24)
    assert np.isfinite(mat).all()
    per_layer = mat.mean(-1)
    assert per_layer[-1] > per_layer[0]     # depth pattern (Fig 2)
    imp = important_set(per_layer)
    assert 0 < len(imp) < 6


def test_task_stability_runs():
    cfg = dataclasses.replace(get_reduced("mistral-7b"), n_layers=4)
    params = init_params(jax.random.PRNGKey(1), cfg)
    sets = task_stability(params, cfg, n_tasks=2, seq=24)
    assert len(sets) == 2 and all(isinstance(s, set) for s in sets)
