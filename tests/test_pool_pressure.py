"""Overcommitted paged serving: the degradation ladder end to end.

Fast-lane units cover the host-side pieces in isolation — watermark math,
the O(1) deque free list, `RuntimeError` lifecycle guards (they must
survive ``python -O``), forced-failure fault injection, the scripted
`PoolFaultInjector`, victim selection, the pool-accounting audit, and
overcommitted pool sizing.

System-lane tests drive the whole ladder through the scheduler: preempted
requests resume TOKEN-IDENTICALLY across dense / hybrid / ssm families and
both prefill layouts, and an overcommitted pool under fault injection
serves the same tokens as a worst-case-sized one with the per-poll audit
on.  Identity scope (DESIGN.md §5): a resumed request re-prefills
``prompt + generated``, so exactness requires that length to stay within
the cache budget (all specs here keep ``plen + max_new <= budget``).
"""
import time

import pytest

import numpy as np

import jax

from repro.core import PolicyConfig
from repro.core.allocation import plan_page_quota, plan_pool_pages, \
    uniform_plan
from repro.core.paging import (PagePool, PoolFaultInjector,
                               audit_pool_accounting)
from repro.models import ModelConfig, init_params
from repro.serving import (ContinuousConfig, ContinuousEngine,
                           ContinuousScheduler, EngineConfig)
from repro.serving.scheduler import select_victim

fast = pytest.mark.fast
system = pytest.mark.system


# =========================================================== fast-lane units
@fast
def test_watermark_validation_and_predicates():
    pool = PagePool(11)                   # 10 usable pages
    for lo, hi in ((-1, 2), (3, 2), (2, 11), (11, 11)):
        with pytest.raises(ValueError):
            pool.set_watermarks(lo, hi)
    pool.set_watermarks(2, 5)
    assert not pool.below_low() and pool.above_high()       # free = 10
    a = pool.alloc(8)                                       # free = 2
    assert pool.below_low() and not pool.above_high()
    # reclaimable headroom counts as effectively free
    assert not pool.below_low(extra_free=1)
    assert pool.above_high(extra_free=4)
    pool.free(a)
    assert pool.above_high()
    # watermarks are advisory: alloc itself never consults them
    b = pool.alloc(10)
    assert b.size == 10


@fast
def test_free_list_is_constant_time_at_scale():
    """10k-page alloc/free cycles: the deque free list keeps this well
    under a second; the old `list.pop(0)` free list is O(pages) per alloc
    and blows far past it."""
    pool = PagePool(10_001)
    t0 = time.perf_counter()
    for _ in range(5):
        ids = pool.alloc(10_000)
        pool.free(ids)
    assert time.perf_counter() - t0 < 1.0
    # FIFO recycling keeps ids in deterministic order
    assert pool.alloc(3).tolist() == [1, 2, 3]


@fast
def test_lifecycle_guards_raise_runtime_error():
    """Double free and unknown ids must raise `RuntimeError`, not rely on
    `assert` — the guards hold under ``python -O``."""
    pool = PagePool(6)
    a = pool.alloc(2)
    pool.free(a)
    with pytest.raises(RuntimeError, match="double free"):
        pool.decref(a)
    with pytest.raises(RuntimeError, match="unknown page"):
        pool.decref(np.asarray([0], np.int32))      # null page is reserved
    with pytest.raises(RuntimeError, match="unknown page"):
        pool.decref(np.asarray([6], np.int32))      # past the pool
    with pytest.raises(RuntimeError, match="unknown page"):
        pool.incref(np.asarray([-3], np.int32))


@fast
def test_try_alloc_and_forced_failures():
    pool = PagePool(4)
    assert pool.try_alloc(5) is None                # over capacity: no raise
    pool.forced_failures = 2
    assert pool.try_alloc(1) is None                # consumed one debt each
    assert pool.forced_failures == 1
    a = pool.alloc(1)                               # raising alloc is exempt
    assert a.size == 1 and pool.forced_failures == 1
    assert pool.try_alloc(1) is None
    got = pool.try_alloc(2)                         # debt paid: real pages
    assert got is not None and got.size == 2


@fast
def test_fault_injector_scripts_are_deterministic():
    evictions = []

    def run():
        pool = PagePool(9)
        pool.evict_hook = lambda: (evictions.append(1), False)[1]
        inj = PoolFaultInjector({0: [("steal", 3)],
                                 1: [("fail_alloc", 2)],
                                 2: [("release", 2), ("evict_storm", 3)],
                                 3: [("release", -1)]})
        log = []
        for _ in range(5):
            inj.tick(pool)
            log.append((pool.n_free, pool.forced_failures,
                        inj.stolen_pages.tolist()))
        return pool, inj, log

    p1, i1, log1 = run()
    p2, i2, log2 = run()
    assert log1 == log2                             # scripted, not sampled
    assert log1[0] == (5, 0, [1, 2, 3])             # steal holds real pages
    assert log1[1][1] == 2                          # fail_alloc owes debt
    assert log1[2][2] == [3]                        # partial release, FIFO
    assert log1[3][2] == []                         # release -1 drains
    assert p1.n_free == 8 - 0                       # all stolen pages back
    assert len(evictions) == 2                      # storm stops on False
    i1.release_all(p1)                              # idempotent when empty
    with pytest.raises(ValueError, match="unknown fault action"):
        PoolFaultInjector({0: [("melt", 1)]}).tick(p1)
    # steals audit as a first-class owner
    i3 = PoolFaultInjector({0: [("steal", 4)]})
    i3.tick(p2)
    audit_pool_accounting(p2, {"injected": [i3.stolen_pages]})


@fast
def test_select_victim_prefers_fewest_decoded_then_lowest_slot():
    assert select_victim([]) is None
    assert select_victim([(3, 5), (1, 2), (2, 8)]) == 1
    assert select_victim([(3, 2), (1, 2), (2, 1)]) == 2     # fewest decoded
    assert select_victim([(4, 2), (2, 2)]) == 2             # tie: lowest slot


@fast
def test_audit_detects_each_violation_class():
    pool = PagePool(8)
    rows = pool.alloc(3)
    cache = pool.alloc(2)
    owners = {"rows": [rows], "cache": [cache]}
    audit_pool_accounting(pool, owners)             # balanced books pass

    with pytest.raises(AssertionError, match="leaked"):
        audit_pool_accounting(pool, {"rows": [rows]})   # cache pages orphaned
    with pytest.raises(AssertionError, match="refcount"):
        audit_pool_accounting(pool, {"rows": [rows, rows[:1]],
                                     "cache": [cache]})  # claim > refcount
    pool.incref(rows[:1])                           # now the share is real
    audit_pool_accounting(pool, {"rows": [rows, rows[:1]], "cache": [cache]})
    pool.decref(rows[:1])
    with pytest.raises(AssertionError, match="invalid id"):
        audit_pool_accounting(pool, {"rows": [np.asarray([0], np.int32)]})
    # deep check: device tables may reference only owned pages (0 and the
    # drop sentinel are layout values, not references)
    tbl = np.asarray([[0, int(rows[0]), pool.sentinel]], np.int32)
    audit_pool_accounting(pool, owners, [tbl])
    free_id = pool.n_pages - 1                      # never allocated above
    with pytest.raises(AssertionError, match="unowned"):
        audit_pool_accounting(
            pool, owners, [np.asarray([[free_id]], np.int32)])
    # free-list corruption classes
    pool._free.append(int(rows[0]))                 # resident id marked free
    with pytest.raises(AssertionError, match="nonzero refcount"):
        audit_pool_accounting(pool, owners)
    pool._free.pop()
    pool._free.append(pool._free[0])
    with pytest.raises(AssertionError, match="duplicate"):
        audit_pool_accounting(pool, owners)


@fast
def test_plan_pool_pages_overcommit_math_and_liveness_floor():
    plan = uniform_plan(4, 16)
    quota = plan_page_quota(plan, 4)                # 16 pages per row
    assert plan_pool_pages(plan, 8, 4) == 1 + 8 * quota
    assert plan_pool_pages(plan, 8, 4, overcommit=0.5) == 1 + 4 * quota
    # the row region never shrinks below ONE full quota: a lone request
    # can always eventually admit no matter how aggressive the overcommit
    assert plan_pool_pages(plan, 8, 4, overcommit=0.001) == 1 + quota
    assert plan_pool_pages(plan, 8, 4, prefix_pages=7,
                           overcommit=0.5) == 1 + 4 * quota + 7
    with pytest.raises(ValueError, match="overcommit"):
        plan_pool_pages(plan, 8, 4, overcommit=0.0)


# ======================================================== system-lane ladder
DENSE = ModelConfig(name="s", arch_type="dense", n_layers=2, d_model=64,
                    n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97,
                    dtype="float32", param_dtype="float32")
HYBRID = ModelConfig(name="h", arch_type="hybrid", n_layers=4, d_model=64,
                     n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97,
                     ssm_state=8, ssm_expand=2, ssm_head_dim=32, ssm_chunk=8,
                     attn_period=2, dtype="float32", param_dtype="float32")
SSM = ModelConfig(name="m", arch_type="ssm", n_layers=2, d_model=64,
                  n_heads=1, n_kv_heads=1, head_dim=32, d_ff=0, vocab_size=97,
                  ssm_state=8, ssm_expand=2, ssm_head_dim=32, ssm_chunk=8,
                  dtype="float32", param_dtype="float32")

ECFG = EngineConfig(mode="uniform", policy=PolicyConfig("sliding_window"),
                    budget_abs=12, bucket=4, min_budget=4)

# every spec keeps plen + max_new <= budget_abs: a preempted request's
# re-prefill window then never overflows the cache, the scope where
# preempt-resume is token-exact (see module docstring)
SPECS_FIT = [(5, 4), (8, 4), (3, 2), (7, 5), (4, 8), (6, 6), (5, 7)]

LAYOUTS = {"bucketed": {}, "packed": dict(packed_prefill=True, pack_len=24)}


def _ccfg(**kw):
    base = dict(max_concurrency=3, prompt_bucket=8, max_prompt_len=24,
                max_new_cap=8, sync_every=2, page_size=4)
    base.update(kw)
    return ContinuousConfig(**base)


def _params(cfg):
    return init_params(jax.random.PRNGKey(0), cfg)


def _run(params, cfg, ccfg, specs, preempt_at=None, injector=None):
    """Serve one stream; optionally force a preemption at poll index
    `preempt_at`.  Returns (scheduler, per-request token lists,
    {rid: tokens carried at preemption})."""
    sched = ContinuousScheduler(params, cfg, ECFG, ccfg, injector=injector)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 97, (n,)).astype(np.int32) for n, _ in specs]
    rids = [sched.submit(p, max_new=mn)
            for p, (_, mn) in zip(prompts, specs)]
    done, polls, preempted = [], 0, {}
    while sched.queue or sched.core.n_occupied:
        if polls == preempt_at:
            victim = sched._victim_slot()
            if victim is not None:
                req = sched.preempt_slot(victim)
                preempted[req.rid] = req.generated.tolist()
        done.extend(sched.poll())
        polls += 1
        assert polls < 500, "pressure stream failed to drain"
    d = {r.rid: r for r in done}
    assert len(d) == len(specs)
    return sched, [d[r].tokens.tolist() for r in rids], \
        {rid: (rids.index(rid), toks) for rid, toks in preempted.items()}


@system
@pytest.mark.parametrize("layout", list(LAYOUTS), ids=list(LAYOUTS))
@pytest.mark.parametrize("cfg", [DENSE, HYBRID, SSM],
                         ids=["dense", "hybrid", "ssm"])
def test_preempt_resume_identity_scope(cfg, layout):
    """A forced mid-flight preemption (clear row, release pages, requeue as
    prompt + generated) must be invisible in the token stream — with the
    documented family scope (DESIGN.md §5).  Rows are independent under
    greedy decoding, so only the PREEMPTED request can possibly change:

      * attention-only families: bit-exact — the resumed re-prefill
        rebuilds the same position-based cache window;
      * recurrent families (hybrid / ssm): the carried pre-preemption
        tokens are exact (host-copied) and the request completes at full
        length, but the chunked-rescan state is mathematically — not
        bitwise — the stepwise decode state, so post-resume tokens may
        drift (verified against the solo engine: `prefill(p + g)` itself
        differs from `prefill(p)` + `g` decode steps).

    SSM has no attention pool, so this also proves preemption is not a
    paging-only feature."""
    params = _params(cfg)
    ccfg = _ccfg(**LAYOUTS[layout])
    _, ref, _ = _run(params, cfg, ccfg, SPECS_FIT)
    sched, out, pre = _run(params, cfg, ccfg, SPECS_FIT, preempt_at=1)
    assert sched.core.preemptions == 1 and sched.core.requeues == 1
    assert len(pre) == 1
    (idx, carried), = pre.values()
    assert [len(t) for t in out] == [mn for _, mn in SPECS_FIT]
    # untouched rows: preemption elsewhere is pure scheduling
    assert all(o == r for i, (o, r) in enumerate(zip(out, ref)) if i != idx)
    # the carried tokens survive the requeue verbatim
    assert out[idx][:len(carried)] == carried == ref[idx][:len(carried)]
    if cfg.arch_type == "dense":
        assert out == ref                           # bit-exact scope
    if sched.core._paged:
        sched.core.audit_pool(deep=True)


@system
def test_overcommitted_stream_matches_worst_case_sizing():
    """The tentpole end to end: half-sized pool, watermark backpressure,
    organic preemption, scripted fault injection, per-poll deep audit —
    and the exact tokens of the worst-case-sized run."""
    params = _params(DENSE)
    base = dict(max_concurrency=6, prompt_bucket=8, max_prompt_len=24,
                max_new_cap=8, sync_every=2, page_size=4)
    _, ref, _ = _run(params, DENSE, ContinuousConfig(**base), SPECS_FIT)

    pressed = ContinuousConfig(**base, overcommit=0.5, watermark_low=0.05,
                               watermark_high=0.2, preempt_after=2,
                               audit_pool=True)
    inj = PoolFaultInjector({1: [("steal", 20), ("fail_alloc", 2)],
                             4: [("release", -1)]})
    sched, out, _ = _run(params, DENSE, pressed, SPECS_FIT, injector=inj)
    core = sched.core
    assert out == ref, "token divergence under pool pressure"
    assert core.stall_polls >= 1 and core.watermark_hits >= 1
    assert core.preemptions >= 1 and core.requeues >= 1
    assert core.pool_pages < 6 * plan_page_quota(core.plan, 4)
    inj.release_all(core._pool)
    core.audit_pool(deep=True)                      # books balance after


@system
def test_backpressure_holds_admissions_until_high_watermark():
    """With the whole pool stolen, admission stalls (no raise, no admit);
    hysteresis keeps it stalled until free pages recover PAST the high
    mark, then the queue drains normally."""
    params = _params(DENSE)
    ccfg = _ccfg(max_concurrency=2, overcommit=0.9, watermark_low=0.1,
                 watermark_high=0.3, preempt_after=50, audit_pool=True)
    inj = PoolFaultInjector({1: [("steal", 10_000)],
                             5: [("release", -1)]})
    sched, out, _ = _run(params, DENSE, ccfg, SPECS_FIT[:4], injector=inj)
    core = sched.core
    assert core.stall_polls >= 1 and core.watermark_hits >= 1
    assert core.preemptions == 0                    # backpressure sufficed
    assert [len(t) for t in out] == [mn for _, mn in SPECS_FIT[:4]]
    # the trace can drain (rows retiring past the high mark) before the
    # scripted release tick arrives — end-of-trace cleanup handles both
    inj.release_all(core._pool)
    core.audit_pool(deep=True)


@system
def test_pressure_config_validation_and_submit_cap():
    params = _params(DENSE)
    with pytest.raises(ValueError, match="overcommit"):
        ContinuousEngine(params, DENSE, ECFG, _ccfg(overcommit=-0.5))
    with pytest.raises(ValueError, match="watermark"):
        ContinuousEngine(params, DENSE, ECFG,
                         _ccfg(watermark_low=0.5, watermark_high=0.2))
    with pytest.raises(ValueError, match="watermark"):
        ContinuousEngine(params, DENSE, ECFG, _ccfg(watermark_high=1.0))
    with pytest.raises(ValueError, match="preempt_after"):
        ContinuousEngine(params, DENSE, ECFG, _ccfg(preempt_after=0))
    with pytest.raises(ValueError, match="page_size"):
        ContinuousEngine(params, DENSE, ECFG,
                         ContinuousConfig(max_concurrency=3, prompt_bucket=8,
                                          max_prompt_len=24, max_new_cap=8,
                                          overcommit=0.5))
    # the engine-side cap is relaxed so RESUMED prompts fit; the scheduler
    # still enforces the user-facing max_prompt_len at submit time
    sched = ContinuousScheduler(params, DENSE, ECFG, _ccfg())
    with pytest.raises(ValueError, match="max_prompt_len"):
        sched.submit(np.zeros(25, np.int32), max_new=2)
