"""Roofline/HLO analysis: loop-aware FLOPs, collective parsing, term math."""

import pytest

pytestmark = pytest.mark.fast

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hlo import collective_bytes
from repro.analysis.hlo_flops import analyze
from repro.analysis.roofline import (HBM_BW, ICI_BW, PEAK_FLOPS, Roofline,
                                     wire_bytes)


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_trip_count_flops():
    L, M = 5, 128
    w = jnp.ones((L, M, M), jnp.float32)
    x = jnp.ones((M, M), jnp.float32)

    def f(x, w):
        return jax.lax.scan(lambda c, wi: (c @ wi, None), x, w)[0]

    res = analyze(_compile_text(f, x, w))
    assert res["flops"] == pytest.approx(2 * L * M ** 3, rel=0.01)


def test_nested_scan_flops():
    Lo, Li, M = 3, 4, 64
    w = jnp.ones((Lo, Li, M, M), jnp.float32)
    x = jnp.ones((M, M), jnp.float32)

    def inner(c, wi):
        return jax.lax.scan(lambda a, b: (a @ b, None), c, wi)[0]

    def f(x, w):
        return jax.lax.scan(lambda c, wi: (inner(c, wi), None), x, w)[0]

    res = analyze(_compile_text(f, x, w))
    assert res["flops"] == pytest.approx(2 * Lo * Li * M ** 3, rel=0.01)


def test_cond_weights_branches():
    M = 128
    x = jnp.ones((M, M), jnp.float32)

    def g(x, i):
        return jax.lax.cond(i > 0, lambda x: x @ x, lambda x: x + 1.0, x)

    res = analyze(_compile_text(g, x, jnp.int32(1)))
    assert res["flops"] == pytest.approx(M ** 3, rel=0.01)   # 2*M^3 * 1/2


def test_collective_parse_on_psum():
    import os
    mesh = jax.make_mesh((1,), ("d",))
    from jax.sharding import NamedSharding, PartitionSpec as P
    from functools import partial
    from jax.experimental.shard_map import shard_map

    @partial(shard_map, mesh=mesh, in_specs=P("d"), out_specs=P())
    def f(x):
        return jax.lax.psum(x, "d")

    x = jnp.ones((8, 128), jnp.float32)
    txt = jax.jit(f).lower(x).compile().as_text()
    colls = collective_bytes(txt)
    assert colls.get("all-reduce", 0) > 0
    assert wire_bytes(colls) >= colls["total"]   # all-reduce 2x accounted


def test_roofline_terms():
    rl = Roofline(arch="a", shape="s", mesh="single", chips=256,
                  flops_global=256 * PEAK_FLOPS,        # exactly 1 s compute
                  bytes_global=256 * HBM_BW * 2,        # 2 s memory
                  wire_bytes_global=256 * ICI_BW * 0.5, # 0.5 s collective
                  model_flops=256 * PEAK_FLOPS / 2)
    assert rl.t_compute == pytest.approx(1.0)
    assert rl.t_memory == pytest.approx(2.0)
    assert rl.t_collective == pytest.approx(0.5)
    assert rl.bottleneck == "memory"
    assert rl.useful_flop_ratio == pytest.approx(0.5)
    assert rl.mfu_bound == pytest.approx(0.25)


def test_dryrun_plan_two_tiers():
    from repro.configs import get_config
    from repro.launch.specs import dryrun_plan
    for arch in ("gemma2-27b", "qwen3-moe-235b-a22b", "zamba2-2.7b"):
        cfg = get_config(arch)
        plan = dryrun_plan(cfg, 32768, "squeeze")
        assert plan.n_small > 0 and plan.n_big > 0
        assert plan.b_small < plan.b_big
        assert plan.b_small % 128 == 0 and plan.b_big % 128 == 0
        assert plan.total <= plan.n_layers * plan.b_init
        full = dryrun_plan(cfg, 32768, "full")
        assert plan.total < full.total            # squeeze actually shrinks
